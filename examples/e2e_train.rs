//! End-to-end validation driver (DESIGN.md E10): REAL multi-model training
//! through all three layers.
//!
//!   * L1: the Pallas flash-attention/layernorm/AdamW kernels inside...
//!   * L2: ...the AOT-compiled GPT-mini train_step HLO, executed by...
//!   * L3: ...the Rust coordinator: Trial-Runner probes, joint solve,
//!     multi-lane execution, loss-curve logging.
//!
//! Trains a model-selection grid (3 learning rates) of GPT-mini for a few
//! hundred steps on the synthetic WikiText-like token stream and prints
//! the loss curves; results are recorded in EXPERIMENTS.md §E10.
//!
//! Run: `cargo run --release --example e2e_train -- [--model tiny|small]
//!       [--steps 200] [--lanes 2] [--compare-sequential]`

use anyhow::Result;
use saturn::coordinator::{real_grid, Coordinator};
use saturn::util::cli::Args;

fn main() -> Result<()> {
    saturn::util::logging::init();
    let args = Args::from_env();
    let model = args.str_or("model", "tiny");
    let steps = args.u64_or("steps", 200);
    let lanes = args.usize_or("lanes", 2);
    let lrs: Vec<f32> = vec![1e-3, 3e-3, 1e-4];

    println!("=== e2e_train: {model} x {} LRs x {steps} steps on {lanes} lanes ===",
             lrs.len());
    let coord = Coordinator::new(lanes)?;
    let jobs = real_grid(&[(model.as_str(), 8)], &lrs, steps);
    let report = coord.run_model_selection(&jobs, 42)?;

    println!("\n{:<22} {:>9} {:>9} {:>11} {:>6}", "job", "loss[0]",
             "loss[T]", "ms/step", "lane");
    for o in &report.outcomes {
        println!("{:<22} {:>9.4} {:>9.4} {:>11.1} {:>6}", o.job.name(),
                 o.first_loss, o.final_loss, o.mean_step_ms, o.lane);
    }
    println!("\nbest config: {} (final loss {:.4})",
             report.outcomes[report.best].job.name(),
             report.outcomes[report.best].final_loss);
    println!("makespan     : {:.1} s", report.makespan_s);
    println!("profiling    : {:.2} s ({:.2}% of makespan)",
             report.profiling_s,
             100.0 * report.profiling_s / report.makespan_s);
    println!("solver       : {:.4} s ({:.4}% of makespan)", report.solver_s,
             100.0 * report.solver_s / report.makespan_s);

    if args.bool_or("compare-sequential", false) {
        // "current practice": one job at a time on a single lane
        let seq = Coordinator::new(1)?;
        let r2 = seq.run_model_selection(&jobs, 42)?;
        println!("\nsequential (1 lane) makespan: {:.1} s -> saturn speedup {:.2}x",
                 r2.makespan_s, r2.makespan_s / report.makespan_s);
    }

    // loss-curve sanity: the winner must have actually learned
    let best = &report.outcomes[report.best];
    let ln_vocab = (512f32).ln(); // ~6.24 = uniform-prediction loss
    if best.final_loss < ln_vocab - 1.0 {
        println!("\nOK: winner's loss {:.3} is well below uniform {:.3}",
                 best.final_loss, ln_vocab);
        Ok(())
    } else {
        anyhow::bail!("winner failed to learn: loss {:.3} vs uniform {:.3}",
                      best.final_loss, ln_vocab)
    }
}
