//! Quickstart: walk Saturn's Figure 1A dataflow end to end on a simulated
//! single p4d node.
//!
//!   workload (Table 1 grid) -> Parallelism Library -> Trial Runner
//!   -> joint Solver -> execution engine -> makespan report
//!
//! Run: `cargo run --release --example quickstart`

use saturn::cluster::ClusterSpec;
use saturn::parallelism::default_library;
use saturn::saturn::solver::{solve_joint, SolverMode};
use saturn::saturn::SaturnPolicy;
use saturn::sim::engine::{simulate, SimConfig};
use saturn::trials::profile_analytic;
use saturn::workload::wikitext_workload;

fn main() {
    saturn::util::logging::init();

    // 1. The multi-job: a model-selection grid (paper Table 1, WikiText).
    let jobs = wikitext_workload();
    println!("multi-job: {} fine-tuning jobs", jobs.len());
    for j in jobs.iter().take(3) {
        println!("  {} ({:.1}B params, {} steps)", j.name,
                 j.model.params / 1e9, j.total_steps());
    }
    println!("  ...");

    // 2. The Parallelism Library (Figure 1B): four registered techniques.
    let library = default_library();
    println!("\nparallelism library: {:?}", library.names());

    // 3. The Trial Runner profiles every (job, technique, GPU count).
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_analytic(&jobs, &library, &cluster);
    println!("trial runner: {} feasible profiles (simulated probe cost: {:.0}s)",
             profiles.len(), profiles.profiling_cost_s);

    // 4. The Solver: joint MILP over parallelism x allocation x schedule.
    let remaining: Vec<(usize, u64)> =
        jobs.iter().map(|j| (j.id, j.total_steps())).collect();
    let (plan, stats) = solve_joint(&remaining, &profiles, &cluster,
                                    SolverMode::Joint);
    println!("\njoint plan ({} B&B nodes in {:.0} ms):", stats.milp_nodes,
             stats.wall_s * 1e3);
    for p in &plan.choices {
        println!("  {:<24} -> {:<8} x{} GPUs ({:.1} h)",
                 jobs[p.job_id].name, library.get(p.tech).name(), p.gpus,
                 p.runtime_s / 3600.0);
    }

    // 5. Execute under the engine (with introspection) and report.
    let mut policy = SaturnPolicy::paper_default();
    let result = simulate(&jobs, &profiles, &cluster, &mut policy,
                          &SimConfig::default());
    println!("\nmakespan: {:.2} h (predicted {:.2} h, lower bound {:.2} h)",
             result.makespan_s / 3600.0, plan.predicted_makespan_s / 3600.0,
             plan.lower_bound_s / 3600.0);
    println!("gpu utilization: {:.0}% | launches: {} | preemptions: {}",
             result.gpu_utilization * 100.0, result.launches,
             result.preemptions);
}
