//! Extending the Parallelism Library (paper Figure 1B): register a custom
//! user technique — Megatron-style tensor parallelism — next to the four
//! built-ins and watch the Solver adopt it where it wins.
//!
//! This is the paper's headline API affordance: techniques are black boxes
//! behind `search`/`execute`, reusable across sessions and users.
//!
//! Run: `cargo run --release --example custom_parallelism`

use saturn::cluster::ClusterSpec;
use saturn::models::ModelSpec;
use saturn::parallelism::{default_library, Library, Parallelism, StepEstimate};
use saturn::saturn::solver::{solve_joint, SolverMode};
use saturn::trials::profile_analytic;
use saturn::workload::wikitext_workload;

/// Megatron-LM tensor parallelism (Shoeybi et al. 2019), simplified:
/// every matmul shards across g GPUs; two all-reduces per layer per pass.
struct TensorParallel {
    mfu: f64,
}

impl Parallelism for TensorParallel {
    fn name(&self) -> &str {
        "megatron-tp"
    }

    fn search(&self, model: &ModelSpec, cluster: &ClusterSpec, gpus: u32,
              batch: u32) -> Option<StepEstimate> {
        if gpus == 0 || gpus > cluster.gpus_per_node() {
            return None; // TP stays inside the NVLink domain
        }
        if model.hidden % gpus != 0 {
            return None;
        }
        let mem = model.state_bytes() / gpus as f64
            + model.act_bytes_per_sample * batch as f64; // acts replicated
        if mem > cluster.gpu().usable_bytes() {
            return None;
        }
        let compute = model.flops_per_step(batch)
            / (gpus as f64 * cluster.gpu().peak_flops * self.mfu);
        // 4 all-reduces/layer (fwd+bwd) over activations
        let act_bytes = model.act_bytes_per_sample * batch as f64
            / model.layers as f64;
        let comm = if gpus == 1 {
            0.0
        } else {
            4.0 * model.layers as f64 * 2.0 * (gpus as f64 - 1.0)
                / gpus as f64 * act_bytes / cluster.intra_bw()
        };
        let step = compute + 0.5 * comm;
        Some(StepEstimate { step_time_s: step, mem_per_gpu: mem,
                            mfu: self.mfu * compute / step })
    }
}

fn plan_with(library: &Library) -> (f64, Vec<(String, u32)>) {
    let jobs = wikitext_workload();
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_analytic(&jobs, library, &cluster);
    let remaining: Vec<(usize, u64)> =
        jobs.iter().map(|j| (j.id, j.total_steps())).collect();
    let (plan, _) = solve_joint(&remaining, &profiles, &cluster,
                                SolverMode::Joint);
    let picks = plan
        .choices
        .iter()
        .map(|p| (library.get(p.tech).name().to_string(), p.gpus))
        .collect();
    (plan.predicted_makespan_s, picks)
}

fn main() {
    saturn::util::logging::init();

    let baseline = default_library();
    let (m0, _) = plan_with(&baseline);
    println!("built-in library {:?}", baseline.names());
    println!("  predicted makespan: {:.2} h", m0 / 3600.0);

    // registerParallelism(technique) — two functions and you're in.
    let mut extended = default_library();
    extended.register(Box::new(TensorParallel { mfu: 0.42 }));
    let (m1, picks) = plan_with(&extended);
    println!("\nextended library {:?}", extended.names());
    println!("  predicted makespan: {:.2} h", m1 / 3600.0);

    let tp_uses = picks.iter().filter(|(n, _)| n == "megatron-tp").count();
    println!("  jobs assigned to megatron-tp: {tp_uses}/12");
    println!("\nmakespan delta from one registered technique: {:+.1}%",
             100.0 * (m1 - m0) / m0);
    if tp_uses > 0 {
        println!("the solver adopted the user technique where it wins — no \
                  scheduler changes required.");
    }
}
