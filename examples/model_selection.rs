//! Model-selection at paper scale (simulated): run one Table 1 workload
//! under all five systems and print the Table 2 comparison, plus the
//! per-job allocations Saturn chose (the paper's "unintuitive" plans).
//!
//! Run: `cargo run --release --example model_selection --
//!       [--workload wikitext|imagenet] [--nodes 1]`

use saturn::cluster::ClusterSpec;
use saturn::exp;
use saturn::parallelism::default_library;
use saturn::saturn::solver::{solve_joint, SolverMode};
use saturn::trials::profile_analytic;
use saturn::util::cli::Args;

fn main() {
    saturn::util::logging::init();
    let args = Args::from_env();
    let workload = args.str_or("workload", "wikitext");
    let nodes = args.usize_or("nodes", 1) as u32;
    let seed = args.u64_or("seed", 0);

    println!("=== model selection: {workload} on {nodes} p4d node(s) ===\n");
    println!("{:<18} {:>12} {:>10} {:>8} {:>12}", "system", "makespan(h)",
             "util(%)", "preempt", "solve(s)");
    let mut rows = Vec::new();
    for sys in exp::SYSTEMS {
        let cell = exp::run_cell(&workload, nodes, sys, seed);
        println!("{:<18} {:>12.2} {:>10.0} {:>8} {:>12.3}", sys,
                 cell.makespan_h, cell.result.gpu_utilization * 100.0,
                 cell.result.preemptions, cell.result.policy_decision_s);
        rows.push((sys, cell.makespan_h));
    }
    let cp = rows[0].1;
    let sat = rows[4].1;
    println!("\nsaturn vs current practice: {:.2}x speedup ({:.0}% reduction)",
             cp / sat, 100.0 * (1.0 - sat / cp));
    println!("paper reports 1.64-1.96x (39-48%) across workloads/nodes\n");

    // show the chosen per-job plans (the paper's qualitative claim)
    let jobs = exp::workload_by_name(&workload);
    let cluster = ClusterSpec::p4d(nodes);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, &cluster);
    let remaining: Vec<(usize, u64)> =
        jobs.iter().map(|j| (j.id, j.total_steps())).collect();
    let (plan, _) = solve_joint(&remaining, &profiles, &cluster,
                                SolverMode::Joint);
    println!("saturn's joint plan (note the mixed, 'unintuitive' splits):");
    for p in &plan.choices {
        println!("  {:<26} {:<8} x{:<2} ({:>7.2} h)", jobs[p.job_id].name,
                 lib.get(p.tech).name(), p.gpus, p.runtime_s / 3600.0);
    }
}
