//! The streaming scenario family end to end (DESIGN.md §Online):
//! multi-tenant HPO grids arrive over virtual time (Poisson or bursty),
//! ASHA rungs early-stop the worst fraction of each grid, and the online
//! schedulers react — online-Saturn re-solving the joint MILP (warm-
//! started from the previous plan) at every arrival/departure event.
//!
//! Knobs: --seed N, --multijobs N, --rate-per-hour X, --burst N,
//!        --tenants N, --kill-fraction F, --nodes N
//!
//! Run: `cargo run --release --example online_stream -- --seed 42`

use saturn::cluster::ClusterSpec;
use saturn::exp;
use saturn::online::{profile_trace, run_trace, warm_cold_probe,
                     ONLINE_SYSTEMS};
use saturn::saturn::solver::SolverMode;
use saturn::sim::engine::RungConfig;
use saturn::util::cli::Args;
use saturn::workload::{generate_trace, ArrivalProcess, TraceConfig};

fn main() {
    saturn::util::logging::init();
    let args = Args::from_env();
    let burst = args.usize_or("burst", 0);
    let cfg = TraceConfig {
        seed: args.u64_or("seed", 42),
        multijobs: args.usize_or("multijobs", 4),
        process: if burst > 0 {
            ArrivalProcess::Burst {
                rate_per_hour: args.f64_or("rate-per-hour", 1.0),
                burst_size: burst,
            }
        } else {
            ArrivalProcess::Poisson {
                rate_per_hour: args.f64_or("rate-per-hour", 2.0),
            }
        },
        grid_lrs: 2,
        grid_batches: 2,
        epochs: 1,
        tenants: args.usize_or("tenants", 2),
        deadline_slack_s: Some(24.0 * 3600.0),
        burst_stagger_s: args.f64_or("burst-stagger-s", 0.0).max(0.0),
    };
    let trace = generate_trace(&cfg);
    let rungs = RungConfig {
        fractions: vec![0.25, 0.5],
        kill_fraction: args.f64_or("kill-fraction", 0.5).clamp(0.0, 0.95),
    };

    // 1. The stream: who shows up when, and how urgent they are.
    println!("=== online stream: {} multi-jobs / {} jobs, seed {} ===",
             trace.groups, trace.jobs.len(), cfg.seed);
    for g in 0..trace.groups {
        let members: Vec<_> =
            trace.jobs.iter().filter(|j| j.group == g).collect();
        let first = members[0];
        println!("  t={:>7.0}s  grid {} ({} jobs, {}, priority {:.0})",
                 first.arrival_s, g, members.len(), first.job.model.name,
                 first.priority);
    }

    // 2. Every online system on the identical trace.
    let nodes = args.usize_or("nodes", 1) as u32;
    let cluster = ClusterSpec::p4d(nodes);
    let profiles = profile_trace(&trace, &cluster);
    let mut metrics = Vec::new();
    for sys in ONLINE_SYSTEMS {
        let (_, m) = run_trace(&trace, Some(&rungs), &profiles, &cluster,
                               sys, SolverMode::Joint);
        metrics.push(m);
    }
    println!();
    print!("{}", exp::format_online_row(&metrics));

    // 3. Why event-rate re-solving is affordable: warm vs cold.
    let p = warm_cold_probe(&trace, &profiles, &cluster);
    println!("\nwarm-started re-solve on the last arrival \
              ({} -> {} jobs):", p.jobs_before, p.jobs_after);
    println!("  cold: {:>8.2} ms, {:>6} B&B nodes",
             p.cold.wall_s * 1e3, p.cold.milp_nodes);
    println!("  warm: {:>8.2} ms, {:>6} B&B nodes (same plan quality: \
              {:.1}s vs {:.1}s predicted makespan)",
             p.warm.wall_s * 1e3, p.warm.milp_nodes, p.warm_makespan_s,
             p.cold_makespan_s);
}
