//! Offline in-repo substitute for the `anyhow` crate (the build farm has
//! no crates.io access — see DESIGN.md §2). Implements the subset the
//! repo uses: `Result`/`Error`, the `anyhow!`/`bail!` macros, and the
//! `Context` extension trait, with `{:#}` printing the full cause chain.
//!
//! The cause chain is stored as rendered strings (outermost message plus
//! causes from outer to inner), which keeps `Error: Send + Sync` for free
//! and avoids trait-object juggling; nothing in this repo downcasts.

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A rendered error chain: `msg` is the outermost context, `causes` the
/// remaining chain from outer to inner.
pub struct Error {
    msg: String,
    causes: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), causes: Vec::new() }
    }

    /// Construct from a standard error, capturing its source chain.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut causes = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = error.source();
        while let Some(s) = cur {
            causes.push(s.to_string());
            cur = s.source();
        }
        Error { msg: error.to_string(), causes }
    }

    /// Wrap this error in one more layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        let inner = std::mem::replace(&mut self.msg, context.to_string());
        self.causes.insert(0, inner);
        self
    }

    /// The cause chain from outermost message inward (diagnostics).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str())
            .chain(self.causes.iter().map(|s| s.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for c in &self.causes {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for c in &self.causes {
            write!(f, "\n\nCaused by:\n    {c}")?;
        }
        Ok(())
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`; that is
// what keeps the blanket `From` below coherent (same trick as real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

mod ext {
    use super::Error;

    /// Sealed conversion helper so `Context` works both on standard errors
    /// and on `anyhow::Result` itself (mirrors anyhow's `ext::StdError`).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::new(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// `Result` extension adding human context to the error chain.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| ext::IntoError::into_error(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| ext::IntoError::into_error(e).context(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_anyhow_result_chains() {
        let r: Result<()> = Err(anyhow!("base {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: base 7");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn bail_and_with_context() {
        fn f(trigger: bool) -> Result<u32> {
            if trigger {
                bail!("tripped at {}", 42);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        let e = f(true).with_context(|| "calling f").unwrap_err();
        assert_eq!(format!("{e:#}"), "calling f: tripped at 42");
    }
}
