//! Offline in-repo substitute for the `log` facade crate (the build farm
//! has no crates.io access — see DESIGN.md §2). Implements the subset the
//! repo uses: the five level macros, `Log`/`Record`/`Metadata`, and the
//! global `set_logger`/`set_max_level` plumbing.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

#[derive(Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> fmt::Arguments<'a> {
        self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: filter by the global level and dispatch to the logger.
#[doc(hidden)]
pub fn __log<'a>(level: Level, target: &'a str, args: fmt::Arguments<'a>) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }

        fn log(&self, record: &Record) {
            let line = format!("[{:?} {}] {}", record.level(),
                               record.target(), record.args());
            assert!(line.contains("log"));
            HITS.fetch_add(1, Ordering::Relaxed);
        }

        fn flush(&self) {}
    }

    #[test]
    fn levels_filter_and_dispatch() {
        static C: Counter = Counter;
        let _ = set_logger(&C);
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
        info!("hello {}", 1);
        debug!("filtered {}", 2);
        let hits = HITS.load(Ordering::Relaxed);
        assert!(hits >= 1, "info! did not reach the logger");
        error!("also logged");
        assert!(HITS.load(Ordering::Relaxed) > hits);
    }
}
