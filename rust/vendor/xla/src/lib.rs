//! API-compatible stub of the PJRT-backed `xla` bindings used by
//! `saturn::runtime` (the build farm has no crates.io access and no PJRT
//! plugin — see DESIGN.md §2 and §7).
//!
//! Host-side `Literal` containers are fully functional (construct,
//! reshape, read back), so checkpoint and data-path code round-trips.
//! Everything that would need a real PJRT client (`PjRtClient::cpu`,
//! compilation, execution) returns an "unavailable" error; runtime tests
//! detect this and skip. Swap this crate for the real bindings in
//! `rust/Cargo.toml` to run the AOT artifacts.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error {
        msg: "PJRT backend unavailable: built against the in-repo xla stub \
              (rust/vendor/xla); point rust/Cargo.toml at the real PJRT \
              bindings and run `make artifacts` to execute HLO"
            .to_string(),
    }
}

/// Elements a `Literal` can hold. Values are stored widened to f64; the
/// repo only round-trips f32/i32 host buffers, where this is lossless.
pub trait NativeType: Copy + 'static {
    fn to_f64(self) -> f64;
    fn from_f64(x: f64) -> Self;
}

macro_rules! native {
    ($($t:ty),*) => {
        $(impl NativeType for $t {
            fn to_f64(self) -> f64 {
                self as f64
            }

            fn from_f64(x: f64) -> Self {
                x as Self
            }
        })*
    };
}

native!(f32, f64, i8, i16, i32, i64, u8, u16, u32, u64);

/// Host-side tensor of widened elements + dims (stub, but functional).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: vec![v.to_f64()], dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            data: v.iter().map(|x| x.to_f64()).collect(),
            dims: vec![v.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error {
                msg: format!("reshape {:?} -> {dims:?}: element count mismatch",
                             self.dims),
            });
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f64(x)).collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.data
            .first()
            .map(|&x| T::from_f64(x))
            .ok_or_else(|| Error { msg: "empty literal".to_string() })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let _ = path;
        Err(unavailable())
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        let _ = proto;
        XlaComputation { _private: () }
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, computation: &XlaComputation)
        -> Result<PjRtLoadedExecutable> {
        let _ = computation;
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(&self, args: &[L])
        -> Result<Vec<Vec<PjRtBuffer>>> {
        let _ = args;
        Err(unavailable())
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_roundtrip() {
        let l = Literal::vec1(&[1.5f32, -2.0, 3.25]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.5, -2.0, 3.25]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.5);
        let s = Literal::scalar(7i32);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[0i32; 12]);
        assert!(l.reshape(&[3, 4]).is_ok());
        assert!(l.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }
}
