//! Property-based equivalence suite for the solver rebuild, via the
//! in-repo `util::prop` framework:
//!
//!  * the bounded-variable revised simplex (`solver::lp`) and the seed
//!    dense tableau (`solver::dense`) agree on STATUS and OBJECTIVE
//!    (within 1e-6) across seeded random LPs with mixed constraint
//!    senses and first-class bounds;
//!  * warm-basis dual-simplex re-solves after branch-style bound changes
//!    are equivalent to cold solves of the modified problem;
//!  * the rebuilt branch-and-bound (`MilpEngine::Revised`) matches the
//!    preserved seed engine (`MilpEngine::DenseReference`) on random
//!    binary programs, and its answer is identical for every thread
//!    count.

use saturn::cluster::ClusterSpec;
use saturn::parallelism::default_library;
use saturn::saturn::solver::{plan_selection_colgen, plan_selection_probe,
                             sharded_probe, solve_joint, SolverMode};
use saturn::solver::dense;
use saturn::solver::lp::{self, Cmp, Lp, LpResult, Simplex};
use saturn::solver::milp::{solve_with_stats, MilpEngine, MilpOptions,
                           MilpResult};
use saturn::trials::{profile_analytic, ProfileTable};
use saturn::util::prop::{forall, Strategy};
use saturn::util::rng::Rng;
use saturn::workload::toy_workload;

/// Seeded random LP instances (the seed is the value; the LP is rebuilt
/// deterministically from it so shrinking stays trivial).
struct RandomLpSeed;

impl Strategy for RandomLpSeed {
    type Value = i64;

    fn generate(&self, rng: &mut Rng) -> i64 {
        rng.range(0, 1_000_000)
    }
}

/// Mirror of the generator cross-validated against scipy/HiGHS while
/// prototyping this rebuild: integer data, mixed senses, ~20% unbounded
/// columns, occasional conflicting bounds.
fn build_lp(seed: i64, all_bounded: bool) -> Lp {
    let mut rng = Rng::new(seed as u64 + 17);
    let n = 2 + rng.usize(5);
    let mut lp = Lp::new(n);
    for j in 0..n {
        lp.set_obj(j, rng.range(-5, 6) as f64);
        if all_bounded || rng.f64() < 0.8 {
            lp.bound_le(j, rng.range(1, 9) as f64);
        }
        if rng.f64() < 0.3 {
            lp.bound_ge(j, rng.range(0, 3) as f64);
        }
    }
    let m = 1 + rng.usize(6);
    for _ in 0..m {
        let coeffs: Vec<(usize, f64)> = (0..n)
            .filter_map(|j| {
                if rng.f64() < 0.8 {
                    let v = rng.range(-3, 4);
                    if v != 0 {
                        return Some((j, v as f64));
                    }
                }
                None
            })
            .collect();
        if coeffs.is_empty() {
            continue;
        }
        let cmp = match rng.usize(4) {
            0 | 1 => Cmp::Le,
            2 => Cmp::Ge,
            _ => Cmp::Eq,
        };
        lp.add(coeffs, cmp, rng.range(-5, 11) as f64);
    }
    lp
}

#[test]
fn prop_revised_simplex_matches_dense_tableau() {
    forall(71, 120, &RandomLpSeed, |&seed| {
        let lp = build_lp(seed, false);
        let revised = lp::solve(&lp);
        let reference = dense::solve(&lp);
        match (&revised, &reference) {
            (
                LpResult::Optimal { objective: a, x },
                LpResult::Optimal { objective: b, .. },
            ) => {
                let tol = 1e-6 * b.abs().max(1.0);
                if (a - b).abs() > tol {
                    return Err(format!(
                        "objective mismatch: revised {a} vs dense {b}"));
                }
                // the revised vertex must satisfy its own model
                for j in 0..lp.n {
                    if x[j] < lp.lower[j] - 1e-7
                        || x[j] > lp.upper[j] + 1e-7
                    {
                        return Err(format!("x[{j}]={} out of bounds", x[j]));
                    }
                }
                for c in &lp.constraints {
                    let lhs: f64 =
                        c.coeffs.iter().map(|&(j, v)| v * x[j]).sum();
                    let ok = match c.cmp {
                        Cmp::Le => lhs <= c.rhs + 1e-6,
                        Cmp::Ge => lhs >= c.rhs - 1e-6,
                        Cmp::Eq => (lhs - c.rhs).abs() <= 1e-6,
                    };
                    if !ok {
                        return Err(format!(
                            "constraint violated: {lhs} vs {}", c.rhs));
                    }
                }
                Ok(())
            }
            (LpResult::Infeasible, LpResult::Infeasible) => Ok(()),
            (LpResult::Unbounded, LpResult::Unbounded) => Ok(()),
            (a, b) => Err(format!("status mismatch: revised {a:?} vs {b:?}")),
        }
    });
}

#[test]
fn prop_warm_dual_resolve_equals_cold_solve_on_bound_flips() {
    forall(72, 100, &RandomLpSeed, |&seed| {
        let lp = build_lp(seed, true);
        let sx = Simplex::new(&lp);
        let root = sx.solve_cold(&lp.lower, &lp.upper);
        let LpResult::Optimal { x, .. } = &root.result else {
            return Ok(()); // warm restarts only exist for optimal parents
        };
        let Some(basis) = &root.basis else {
            return Ok(()); // redundant-row bases are legitimately refused
        };
        // branch-style tightenings on every variable in turn
        let mut rng = Rng::new(seed as u64 ^ 0xABCD);
        for j in 0..lp.n {
            let mut lower = lp.lower.clone();
            let mut upper = lp.upper.clone();
            if rng.f64() < 0.5 {
                upper[j] = x[j].floor();
            } else {
                lower[j] = x[j].floor() + 1.0;
            }
            if lower[j] > upper[j] {
                continue;
            }
            let cold = sx.solve_cold(&lower, &upper);
            let Some(warm) = sx.solve_warm(&lower, &upper, basis) else {
                continue; // refusal is allowed; silently-wrong is not
            };
            match (&cold.result, &warm.result) {
                (
                    LpResult::Optimal { objective: a, .. },
                    LpResult::Optimal { objective: b, .. },
                ) => {
                    let tol = 1e-6 * a.abs().max(1.0);
                    if (a - b).abs() > tol {
                        return Err(format!(
                            "var {j}: warm {b} vs cold {a}"));
                    }
                }
                (LpResult::Infeasible, LpResult::Infeasible) => {}
                (a, b) => {
                    return Err(format!(
                        "var {j}: status mismatch cold {a:?} warm {b:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_strong_branching_agrees_on_incumbents() {
    // root-node strong branching (MilpOptions::strong_branch_k) may
    // reshape the tree but never the answer: random binary programs
    // must yield the same objective as the default revised engine AND
    // the preserved seed engine
    forall(74, 30, &RandomLpSeed, |&seed| {
        let mut rng = Rng::new(seed as u64 + 11);
        let n = 3 + rng.usize(6);
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_obj(j, rng.range(-20, 8) as f64);
            lp.bound_le(j, 1.0);
        }
        lp.add(
            (0..n).map(|j| (j, rng.range(1, 10) as f64)).collect(),
            Cmp::Le,
            rng.range(5, 30) as f64,
        );
        let ints: Vec<usize> = (0..n).collect();
        let (base, _) =
            solve_with_stats(&lp, &ints, &MilpOptions::default());
        let (reference, _) = solve_with_stats(&lp, &ints, &MilpOptions {
            engine: MilpEngine::DenseReference,
            ..Default::default()
        });
        for k in [2usize, 4] {
            let (strong, _) = solve_with_stats(&lp, &ints, &MilpOptions {
                strong_branch_k: k,
                ..Default::default()
            });
            for (tag, other) in [("revised", &base), ("seed", &reference)]
            {
                match (&strong, other) {
                    (
                        MilpResult::Solved { objective: a, .. },
                        MilpResult::Solved { objective: b, .. },
                    ) => {
                        if (a - b).abs() > 1e-6 * b.abs().max(1.0) {
                            return Err(format!(
                                "k={k} vs {tag}: {a} vs {b}"));
                        }
                    }
                    (MilpResult::Infeasible, MilpResult::Infeasible) => {}
                    (a, b) => {
                        return Err(format!(
                            "k={k} vs {tag}: status {a:?} vs {b:?}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ft_warm_chains_equal_cold_solves() {
    // Forrest–Tomlin stress: CHAINS of warm re-solves, each reusing the
    // previous step's basis, must keep agreeing with cold solves as the
    // eta file accumulates across the chain. Also pins the factor
    // accounting: every pivot records exactly one product-form eta, and
    // every warm entry refactors at least once.
    forall(75, 60, &RandomLpSeed, |&seed| {
        let lp = build_lp(seed, true);
        let sx = Simplex::new(&lp);
        let root = sx.solve_cold(&lp.lower, &lp.upper);
        if root.info.eta_updates != root.info.pivots {
            return Err(format!(
                "cold: {} etas for {} pivots",
                root.info.eta_updates, root.info.pivots));
        }
        let LpResult::Optimal { x, .. } = &root.result else {
            return Ok(());
        };
        let Some(mut basis) = root.basis.clone() else {
            return Ok(()); // redundant-row bases are legitimately refused
        };
        let mut x = x.clone();
        let mut lower = lp.lower.clone();
        let mut upper = lp.upper.clone();
        let mut rng = Rng::new(seed as u64 ^ 0x5EED);
        for step in 0..4 {
            let j = rng.usize(lp.n);
            if rng.f64() < 0.5 {
                upper[j] = x[j].floor().max(lower[j]);
            } else {
                lower[j] = (x[j].floor() + 1.0).min(upper[j]);
            }
            let cold = sx.solve_cold(&lower, &upper);
            let Some(warm) = sx.solve_warm(&lower, &upper, &basis) else {
                return Ok(()); // refusal is allowed; wrong answers are not
            };
            if warm.info.refactorizations < 1 {
                return Err(format!(
                    "step {step}: warm entry never refactored"));
            }
            if warm.info.eta_updates != warm.info.pivots {
                return Err(format!(
                    "step {step}: {} etas for {} pivots",
                    warm.info.eta_updates, warm.info.pivots));
            }
            match (&cold.result, &warm.result) {
                (
                    LpResult::Optimal { objective: a, .. },
                    LpResult::Optimal { objective: b, x: wx },
                ) => {
                    if (a - b).abs() > 1e-6 * a.abs().max(1.0) {
                        return Err(format!(
                            "step {step}: warm {b} vs cold {a}"));
                    }
                    x.copy_from_slice(&wx[..lp.n]);
                }
                (LpResult::Infeasible, LpResult::Infeasible) => {
                    return Ok(());
                }
                (a, b) => {
                    return Err(format!(
                        "step {step}: status cold {a:?} warm {b:?}"));
                }
            }
            match warm.basis {
                Some(b) => basis = b,
                None => return Ok(()),
            }
        }
        Ok(())
    });
}

fn toy_instance(n: usize, cluster: &ClusterSpec)
    -> (Vec<(usize, u64)>, ProfileTable) {
    let jobs = toy_workload(n);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, cluster);
    (jobs.iter().map(|j| (j.id, j.total_steps())).collect(), profiles)
}

#[test]
fn prop_colgen_matches_full_grid_objective() {
    // the restricted master + pricing + reduced-cost widening must land
    // on the same optimum as solving over the full candidate grid, for
    // varying fleet shapes and job counts
    forall(76, 10, &RandomLpSeed, |&seed| {
        let mut rng = Rng::new(seed as u64 + 3);
        let n = 6 + rng.usize(19);
        let cluster = match rng.usize(3) {
            0 => ClusterSpec::p4d(1),
            1 => ClusterSpec::p4d(2),
            _ => ClusterSpec::hetero(1, 1),
        };
        let (remaining, profiles) = toy_instance(n, &cluster);
        let full = plan_selection_probe(&remaining, &profiles, &cluster,
                                        MilpEngine::Revised);
        let colgen = plan_selection_colgen(&remaining, &profiles, &cluster);
        match (full, colgen) {
            (Some((f, _)), Some((c, st))) => {
                let rel = (c - f).abs() / f.abs().max(1.0);
                if rel > 1e-6 {
                    return Err(format!(
                        "n={n}: colgen {c} vs full grid {f} (rel {rel:e}, \
                         {} columns priced)", st.columns_priced));
                }
                Ok(())
            }
            (None, None) => Ok(()),
            (f, c) => Err(format!(
                "n={n}: solvability mismatch: full {} vs colgen {}",
                f.is_some(), c.is_some())),
        }
    });
}

#[test]
fn prop_sharded_respects_capacity_and_thread_count() {
    // cell decomposition must emit exactly one placeable plan per job
    // (valid class, gpus within that class) and its merged objective
    // must be bit-identical for every worker count — scope_map preserves
    // submission order, so parallelism can never leak into the answer
    forall(77, 6, &RandomLpSeed, |&seed| {
        let mut rng = Rng::new(seed as u64 + 7);
        let n = 32 + rng.usize(49);
        let cluster = if rng.f64() < 0.5 {
            ClusterSpec::p4d(4)
        } else {
            ClusterSpec::hetero(2, 2)
        };
        let cell_size = 8 + rng.usize(25);
        let (remaining, profiles) = toy_instance(n, &cluster);
        let (plan, stats) = solve_joint(
            &remaining, &profiles, &cluster,
            SolverMode::Sharded { cell_size });
        if plan.choices.len() != remaining.len() {
            return Err(format!(
                "n={n}: {} choices for {} jobs",
                plan.choices.len(), remaining.len()));
        }
        for p in &plan.choices {
            if p.class >= cluster.classes.len() {
                return Err(format!(
                    "job {}: class {} out of range", p.job_id, p.class));
            }
            if p.gpus == 0 || p.gpus > cluster.class_gpus(p.class) {
                return Err(format!(
                    "job {}: {} gpus exceeds class {} capacity {}",
                    p.job_id, p.gpus, p.class,
                    cluster.class_gpus(p.class)));
            }
        }
        let want_cells = n.div_ceil(cell_size);
        if stats.cells != want_cells {
            return Err(format!(
                "n={n}, cell_size={cell_size}: {} cells, want \
                 {want_cells}", stats.cells));
        }
        if stats.shard_gap < 0.0 {
            return Err(format!("negative shard gap {}", stats.shard_gap));
        }
        let mut reference: Option<(f64, usize)> = None;
        for threads in [1usize, 2, 8] {
            let Some((obj, st)) = sharded_probe(
                &remaining, &profiles, &cluster, cell_size, threads)
            else {
                return Err(format!("threads={threads}: probe failed"));
            };
            match reference {
                None => reference = Some((obj, st.cells)),
                Some((r, cells)) => {
                    if obj.to_bits() != r.to_bits() {
                        return Err(format!(
                            "threads={threads} changed the objective: \
                             {obj} vs {r}"));
                    }
                    if st.cells != cells {
                        return Err(format!(
                            "threads={threads} changed the partition: \
                             {} vs {cells} cells", st.cells));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_milp_engines_and_thread_counts_agree() {
    forall(73, 40, &RandomLpSeed, |&seed| {
        // random binary programs with a knapsack row and an occasional
        // covering row
        let mut rng = Rng::new(seed as u64 + 5);
        let n = 3 + rng.usize(6);
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_obj(j, rng.range(-20, 8) as f64);
            lp.bound_le(j, 1.0);
        }
        lp.add(
            (0..n).map(|j| (j, rng.range(1, 10) as f64)).collect(),
            Cmp::Le,
            rng.range(5, 30) as f64,
        );
        if rng.f64() < 0.4 {
            lp.add((0..n).map(|j| (j, 1.0)).collect(), Cmp::Ge,
                   rng.range(1, (n / 2 + 2) as i64) as f64);
        }
        let ints: Vec<usize> = (0..n).collect();

        let (revised, stats) =
            solve_with_stats(&lp, &ints, &MilpOptions::default());
        let (reference, _) = solve_with_stats(&lp, &ints, &MilpOptions {
            engine: MilpEngine::DenseReference,
            ..Default::default()
        });
        match (&revised, &reference) {
            (
                MilpResult::Solved { objective: a, .. },
                MilpResult::Solved { objective: b, .. },
            ) => {
                if (a - b).abs() > 1e-6 * b.abs().max(1.0) {
                    return Err(format!(
                        "engines disagree: revised {a} vs dense {b}"));
                }
            }
            (MilpResult::Infeasible, MilpResult::Infeasible) => {}
            (a, b) => {
                return Err(format!(
                    "engine status mismatch: {a:?} vs {b:?}"));
            }
        }
        // warm-basis dual-simplex must carry real traffic when branching
        if stats.nodes > 1 && stats.warm_hit_rate() == 0.0 {
            return Err("branching search never reused a basis".into());
        }
        // thread count must not change the answer OR the search
        for threads in [2usize, 3] {
            let (par, par_stats) = solve_with_stats(&lp, &ints, &MilpOptions {
                threads,
                ..Default::default()
            });
            if par != revised {
                return Err(format!("threads={threads} changed the result"));
            }
            if par_stats.nodes != stats.nodes {
                return Err(format!(
                    "threads={threads} changed node count: {} vs {}",
                    par_stats.nodes, stats.nodes));
            }
        }
        Ok(())
    });
}
