//! Property-based invariants for heterogeneous (multi-class) fleets, via
//! the in-repo `util::prop` framework:
//!
//!  * per-class placement never exceeds the class's capacity, and jobs
//!    never spill across classes;
//!  * `FreeState::place`/`release` round-trip per class under random
//!    interleavings;
//!  * a single-class (all-A100) fleet routed through the per-class solver
//!    reproduces the homogeneous (pooled) formulation's objective exactly
//!    (the ISSUE 3 degenerate-fleet acceptance bar, ≤ 1e-6);
//!  * full mixed-fleet solve → list-schedule replay keeps every class
//!    within its own capacity at every event time.

use saturn::cluster::ClusterSpec;
use saturn::parallelism::default_library;
use saturn::saturn::plan::JobPlan;
use saturn::saturn::solver::{plan_selection_probe,
                             plan_selection_probe_pooled, solve_joint,
                             SolverMode};
use saturn::sim::placement::FreeState;
use saturn::solver::milp::MilpEngine;
use saturn::trials::profile_analytic;
use saturn::util::prop::{forall, IntRange, PairOf, Strategy, VecOf};
use saturn::util::rng::Rng;
use saturn::workload::toy_workload;

// ---------------------------------------------------------------------------
// placement: class capacity + round-trip
// ---------------------------------------------------------------------------

/// Random (class, gpus) placement requests.
struct RandomRequests;

impl Strategy for RandomRequests {
    type Value = Vec<(i64, i64)>;

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (0..rng.usize(24) + 1)
            .map(|_| (rng.range(0, 2), rng.range(1, 17)))
            .collect()
    }
}

#[test]
fn prop_per_class_placement_never_exceeds_class_capacity() {
    forall(71, 100, &RandomRequests, |reqs| {
        let cluster = ClusterSpec::hetero(2, 1); // 16 + 8 GPUs
        let mut free = FreeState::new(&cluster);
        let caps: Vec<u32> =
            (0..2).map(|ci| free.class_capacity(ci)).collect();
        let mut used = vec![0u32; 2];
        for &(ci, g) in reqs {
            let (ci, g) = (ci as usize, g as u32);
            if let Some(pl) = free.place(ci, g) {
                // grants stay inside the requested class and sum to g
                if pl.iter().any(|p| p.class != ci) {
                    return Err(format!("grant crossed classes: {pl:?}"));
                }
                if pl.iter().map(|p| p.gpus).sum::<u32>() != g {
                    return Err(format!("grant != request for {g} GPUs"));
                }
                used[ci] += g;
                if used[ci] > caps[ci] {
                    return Err(format!(
                        "class {ci} oversubscribed: {} > {}",
                        used[ci], caps[ci]));
                }
            }
            for ci in 0..2 {
                if free.class_free(ci) + used[ci] != caps[ci] {
                    return Err(format!("class {ci} accounting leak"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_place_release_round_trips_per_class() {
    forall(72, 100,
           &VecOf { inner: PairOf(IntRange(0, 1), IntRange(1, 16)),
                    min_len: 1, max_len: 16 },
           |reqs| {
        let cluster = ClusterSpec::hetero(1, 2);
        let mut free = FreeState::new(&cluster);
        let snapshot = free.clone();
        let mut placed = Vec::new();
        for &(ci, g) in reqs {
            if let Some(p) = free.place(ci as usize, g as u32) {
                placed.push(p);
            }
        }
        // release in reverse order; the free state must be restored
        // EXACTLY (same per-node counts, not just totals)
        for p in placed.iter().rev() {
            free.release(p);
        }
        if free != snapshot {
            return Err(format!(
                "round-trip mismatch: {free:?} vs {snapshot:?}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// degenerate single-class fleet == homogeneous solver
// ---------------------------------------------------------------------------

#[test]
fn prop_single_class_fleet_reproduces_homogeneous_objective() {
    forall(73, 6, &PairOf(IntRange(2, 8), IntRange(1, 2)), |&(n, nodes)| {
        let jobs = toy_workload(n as usize);
        let cluster = ClusterSpec::p4d(nodes as u32);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let (per_class, _) = plan_selection_probe(&rem, &profiles, &cluster,
                                                  MilpEngine::Revised)
            .ok_or("per-class probe failed")?;
        let (pooled, _) = plan_selection_probe_pooled(
            &rem, &profiles, &cluster, MilpEngine::Revised)
            .ok_or("pooled probe failed")?;
        if (per_class - pooled).abs() > 1e-6 * pooled.abs().max(1.0) {
            return Err(format!(
                "degenerate fleet diverged: per-class {per_class} vs \
                 pooled {pooled}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// mixed-fleet solve: replay with per-class accounting
// ---------------------------------------------------------------------------

/// Replay a plan's list schedule tracking per-class GPU usage; errors on
/// any class exceeding its capacity.
fn replay_per_class(choices: &[JobPlan], cluster: &ClusterSpec)
    -> Result<(), String> {
    let caps: Vec<u32> = (0..cluster.n_classes())
        .map(|ci| cluster.class_gpus(ci))
        .collect();
    let mut free = FreeState::new(cluster);
    let mut used = vec![0u32; cluster.n_classes()];
    let mut running: Vec<(f64, Vec<saturn::sim::Placement>, usize, u32)> =
        Vec::new();
    let mut pending: Vec<&JobPlan> = choices.iter().collect();
    pending.sort_by(|a, b| b.runtime_s.partial_cmp(&a.runtime_s).unwrap());
    let mut now = 0.0f64;
    while !pending.is_empty() || !running.is_empty() {
        pending.retain(|p| {
            if let Some(pl) = free.place(p.class, p.gpus) {
                used[p.class] += p.gpus;
                running.push((now + p.runtime_s, pl, p.class, p.gpus));
                false
            } else {
                true
            }
        });
        for (ci, (&u, &cap)) in used.iter().zip(&caps).enumerate() {
            if u > cap {
                return Err(format!("class {ci}: {u} GPUs in use (> {cap})"));
            }
        }
        if running.is_empty() {
            return Err(format!("{} jobs can never be placed", pending.len()));
        }
        let (i, _) = running
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .unwrap();
        let (fin, pl, ci, g) = running.swap_remove(i);
        now = fin;
        used[ci] -= g;
        free.release(&pl);
    }
    Ok(())
}

#[test]
fn prop_mixed_fleet_plans_respect_class_capacity_at_every_event() {
    forall(74, 8, &PairOf(IntRange(2, 10), IntRange(0, 1)), |&(n, big)| {
        let jobs = toy_workload(n as usize);
        let cluster = if big == 1 {
            ClusterSpec::hetero(2, 1)
        } else {
            ClusterSpec::hetero(1, 1)
        };
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        for mode in [SolverMode::Joint, SolverMode::Heuristic] {
            let (plan, _) = solve_joint(&rem, &profiles, &cluster, mode);
            if plan.choices.len() != jobs.len() {
                return Err(format!("{mode:?}: missing plans"));
            }
            for p in &plan.choices {
                if p.gpus > cluster.class_gpus(p.class) {
                    return Err(format!(
                        "{mode:?}: job {} wants {} GPUs of class {} (cap {})",
                        p.job_id, p.gpus, p.class,
                        cluster.class_gpus(p.class)));
                }
                if profiles
                    .step_time(p.job_id, p.tech, p.gpus, p.class)
                    .is_none()
                {
                    return Err(format!(
                        "{mode:?}: infeasible (job={}, tech={}, g={}, \
                         class={})",
                        p.job_id, p.tech, p.gpus, p.class));
                }
            }
            replay_per_class(&plan.choices, &cluster)?;
        }
        Ok(())
    });
}

#[test]
fn prop_online_mixed_fleet_peaks_within_fleet_capacity() {
    use saturn::online::{profile_trace, run_trace, ONLINE_SYSTEMS};
    use saturn::sim::engine::RungConfig;
    use saturn::workload::{generate_trace, TraceConfig};

    forall(75, 4, &IntRange(0, 500), |&seed| {
        let trace = generate_trace(&TraceConfig {
            seed: seed as u64,
            multijobs: 2,
            grid_lrs: 2,
            grid_batches: 1,
            epochs: 1,
            tenants: 2,
            ..Default::default()
        });
        let cluster = ClusterSpec::hetero(1, 1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        for sys in ONLINE_SYSTEMS {
            let (r, m) = run_trace(&trace, Some(&rungs), &profiles, &cluster,
                                   sys, SolverMode::Joint);
            if r.peak_gpus > cluster.total_gpus() {
                return Err(format!("{sys}: peak {} > fleet", r.peak_gpus));
            }
            if m.completed + m.early_stopped != trace.jobs.len() {
                return Err(format!("{sys}: job conservation violated"));
            }
            if r.gpu_utilization > 1.0 + 1e-9 {
                return Err(format!("{sys}: utilization {}",
                                   r.gpu_utilization));
            }
        }
        Ok(())
    });
}
