//! Property-based invariants for the online scheduling subsystem and the
//! solver's schedule-level guarantees, via the in-repo `util::prop`
//! framework:
//!
//!  * a returned plan never exceeds cluster GPU capacity at any event
//!    time (independent list-schedule replay with explicit accounting);
//!  * makespan >= max(longest-job, total-area/G) — the LP lower bounds;
//!  * online runs: every job departs exactly once, peak GPU usage stays
//!    within the fleet, JCTs respect physical floors, and replays are
//!    deterministic.

use saturn::cluster::ClusterSpec;
use saturn::online::{profile_trace, run_trace, ONLINE_SYSTEMS};
use saturn::parallelism::default_library;
use saturn::saturn::plan::JobPlan;
use saturn::saturn::solver::{solve_joint, SolverMode};
use saturn::sim::engine::RungConfig;
use saturn::sim::placement::FreeState;
use saturn::trials::profile_analytic;
use saturn::util::prop::{forall, Strategy};
use saturn::util::rng::Rng;
use saturn::workload::{generate_trace, toy_workload, ArrivalProcess,
                       TraceConfig};

// ---------------------------------------------------------------------------
// solver: capacity at every event time + LP lower bounds
// ---------------------------------------------------------------------------

/// Independent replay of a plan's list schedule with explicit GPU
/// accounting; errors on any oversubscription, returns the realized
/// makespan.
fn replay_list_schedule(choices: &[JobPlan], cluster: &ClusterSpec)
    -> Result<f64, String> {
    let total = cluster.total_gpus();
    let mut free = FreeState::new(cluster);
    let mut running: Vec<(f64, Vec<saturn::sim::Placement>, u32)> = Vec::new();
    let mut pending: Vec<&JobPlan> = choices.iter().collect();
    pending.sort_by(|a, b| b.runtime_s.partial_cmp(&a.runtime_s).unwrap());
    let mut now = 0.0f64;
    let mut makespan = 0.0f64;
    let mut in_use = 0u32;
    let mut overflow = false;
    while !pending.is_empty() || !running.is_empty() {
        pending.retain(|p| {
            if let Some(pl) = free.place(p.class, p.gpus) {
                in_use += p.gpus;
                if in_use > total {
                    overflow = true;
                }
                let fin = now + p.runtime_s;
                makespan = makespan.max(fin);
                running.push((fin, pl, p.gpus));
                false
            } else {
                true
            }
        });
        if overflow {
            return Err(format!("{in_use} GPUs in use at t={now} (> {total})"));
        }
        if running.is_empty() {
            return Err(format!("{} jobs can never be placed", pending.len()));
        }
        let (i, _) = running
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .unwrap();
        let (fin, pl, g) = running.swap_remove(i);
        now = fin;
        in_use -= g;
        free.release(&pl);
    }
    Ok(makespan)
}

/// Random (n_jobs, nodes) instances.
struct RandomInstance;

impl Strategy for RandomInstance {
    type Value = (i64, i64);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (rng.range(1, 11), rng.range(1, 3))
    }
}

#[test]
fn prop_plan_respects_capacity_at_every_event_time() {
    forall(52, 12, &RandomInstance, |&(n, nodes)| {
        let jobs = toy_workload(n as usize);
        let cluster = ClusterSpec::p4d(nodes as u32);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let remaining: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        for mode in [SolverMode::Joint, SolverMode::Heuristic] {
            let (plan, _) = solve_joint(&remaining, &profiles, &cluster, mode);
            let realized = replay_list_schedule(&plan.choices, &cluster)?;
            // the realized schedule is what the plan predicted
            if (realized - plan.predicted_makespan_s).abs()
                > 1e-6 * plan.predicted_makespan_s.max(1.0) {
                return Err(format!(
                    "replay {realized} != predicted {}",
                    plan.predicted_makespan_s));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_dominates_lp_lower_bounds() {
    forall(53, 12, &RandomInstance, |&(n, nodes)| {
        let jobs = toy_workload(n as usize);
        let cluster = ClusterSpec::p4d(nodes as u32);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let remaining: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let g_total = cluster.total_gpus() as f64;
        for mode in [SolverMode::Joint, SolverMode::Heuristic] {
            let (plan, _) = solve_joint(&remaining, &profiles, &cluster, mode);
            let longest = plan
                .choices
                .iter()
                .map(|p| p.runtime_s)
                .fold(0.0f64, f64::max);
            let area: f64 =
                plan.choices.iter().map(|p| p.gpus as f64 * p.runtime_s).sum();
            let bound = longest.max(area / g_total);
            if plan.predicted_makespan_s < bound - 1e-6 * bound.max(1.0) {
                return Err(format!(
                    "makespan {} below LP bound {bound}",
                    plan.predicted_makespan_s));
            }
            if plan.lower_bound_s > plan.predicted_makespan_s + 1e-6 {
                return Err("reported lower bound exceeds makespan".into());
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// online runs: conservation, capacity, JCT floors, determinism
// ---------------------------------------------------------------------------

/// Random streaming scenarios: (seed, multijobs, bursty).
struct RandomTrace;

impl Strategy for RandomTrace {
    type Value = (i64, i64, i64);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (rng.range(0, 1000), rng.range(1, 4), rng.range(0, 2))
    }
}

fn build_trace(seed: i64, multijobs: i64, bursty: i64)
    -> saturn::workload::Trace {
    generate_trace(&TraceConfig {
        seed: seed as u64,
        multijobs: multijobs as usize,
        process: if bursty == 1 {
            ArrivalProcess::Burst { rate_per_hour: 1.5, burst_size: 2 }
        } else {
            ArrivalProcess::Poisson { rate_per_hour: 3.0 }
        },
        grid_lrs: 2,
        grid_batches: 1,
        epochs: 1,
        tenants: 2,
        deadline_slack_s: None,
        burst_stagger_s: 0.0,
    })
}

#[test]
fn prop_online_every_job_departs_exactly_once_within_capacity() {
    forall(54, 8, &RandomTrace, |&(seed, mj, bursty)| {
        let trace = build_trace(seed, mj, bursty);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        for sys in ONLINE_SYSTEMS {
            let (r, m) = run_trace(&trace, Some(&rungs), &profiles, &cluster,
                                   sys, SolverMode::Joint);
            let mut ids: Vec<usize> =
                r.finish_times.iter().map(|&(id, _)| id).collect();
            ids.sort();
            if ids != (0..trace.jobs.len()).collect::<Vec<_>>() {
                return Err(format!("{sys}: departures {ids:?}"));
            }
            if m.completed + m.early_stopped != trace.jobs.len() {
                return Err(format!("{sys}: job conservation violated"));
            }
            if r.peak_gpus > cluster.total_gpus() {
                return Err(format!("{sys}: peak {} > fleet", r.peak_gpus));
            }
            if r.gpu_utilization > 1.0 + 1e-9 {
                return Err(format!("{sys}: utilization {}",
                                   r.gpu_utilization));
            }
            // no departure precedes its own arrival
            for &(id, fin) in &r.finish_times {
                if fin + 1e-9 < trace.jobs[id].arrival_s {
                    return Err(format!("{sys}: job {id} departed pre-arrival"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_online_jct_and_makespan_respect_physical_floors() {
    forall(55, 6, &RandomTrace, |&(seed, mj, bursty)| {
        let trace = build_trace(seed, mj, bursty);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let g_total = cluster.total_gpus() as f64;
        // no early stopping here: every job runs to completion, so the
        // classic LP bounds apply to the realized online schedule
        let (r, _) = run_trace(&trace, None, &profiles, &cluster,
                               "online-current-practice", SolverMode::Joint);
        let mut min_area_total = 0.0f64;
        let mut arrival_floor = 0.0f64;
        for oj in &trace.jobs {
            let plans = profiles.pareto_plans(oj.job.id, 0);
            let steps = oj.job.total_steps() as f64;
            let fastest = plans
                .iter()
                .map(|&(_, _, t)| t * steps)
                .fold(f64::INFINITY, f64::min);
            let min_area = plans
                .iter()
                .map(|&(_, g, t)| g as f64 * t * steps)
                .fold(f64::INFINITY, f64::min);
            min_area_total += min_area;
            arrival_floor = arrival_floor.max(oj.arrival_s + fastest);
            let jct = r.jct_s[oj.job.id].1;
            if jct < fastest * 0.999 {
                return Err(format!(
                    "job {} JCT {jct} below fastest runtime {fastest}",
                    oj.job.id));
            }
        }
        let bound = arrival_floor.max(min_area_total / g_total);
        if r.makespan_s < bound * 0.999 {
            return Err(format!(
                "makespan {} below physical floor {bound}", r.makespan_s));
        }
        Ok(())
    });
}

#[test]
fn prop_online_saturn_replay_is_deterministic() {
    forall(56, 5, &RandomTrace, |&(seed, mj, bursty)| {
        let trace = build_trace(seed, mj, bursty);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        let run = || {
            run_trace(&trace, Some(&rungs), &profiles, &cluster,
                      "online-saturn", SolverMode::Joint)
                .0
        };
        let (a, b) = (run(), run());
        if a.finish_times != b.finish_times || a.jct_s != b.jct_s
            || a.early_stopped != b.early_stopped
            || a.launches != b.launches {
            return Err("online-saturn replay diverged".into());
        }
        Ok(())
    });
}
