//! Flight-recorder properties (DESIGN.md §4.6): tracing must observe
//! without perturbing. Trace-off and trace-on replays are bit-identical
//! for every online system, journals round-trip through JSONL, spans
//! pair and carry re-solve causes, solver phase spans account for the
//! solve wall time, and the offline summarizer reconstructs the
//! decision-latency tail from the journal alone.

use saturn::cluster::ClusterSpec;
use saturn::objective::JobTerms;
use saturn::obs::metrics::Histogram;
use saturn::obs::summary;
use saturn::obs::trace::{chrome_trace, paired_spans, parse_jsonl,
                         validate, write_jsonl, Tracer};
use saturn::online::{profile_trace, run_trace_sim, ONLINE_SYSTEMS};
use saturn::perf::PerfModel;
use saturn::saturn::solver::{solve_joint_traced, SolverMode};
use saturn::sim::engine::{OnlineSimResult, RungConfig, SimConfig};
use saturn::trials::ProfileTable;
use saturn::util::stats::percentile;
use saturn::workload::{generate_trace, Trace, TraceConfig};

fn setup(seed: u64, multijobs: usize)
    -> (Trace, ProfileTable, ClusterSpec) {
    let trace = generate_trace(&TraceConfig {
        seed,
        multijobs,
        ..Default::default()
    });
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    (trace, profiles, cluster)
}

fn run_with(trace: &Trace, profiles: &ProfileTable,
            cluster: &ClusterSpec, system: &str, tracer: Tracer)
    -> OnlineSimResult {
    let mut perf = PerfModel::exact(profiles);
    let cfg = SimConfig { trace: tracer, ..SimConfig::default() };
    let rungs = RungConfig::halving();
    let (r, _) = run_trace_sim(trace, Some(&rungs), &mut perf, cluster,
                               system, SolverMode::Joint, None, &cfg);
    r
}

#[test]
fn tracing_off_and_on_are_bit_identical_for_every_system() {
    let (trace, profiles, cluster) = setup(42, 3);
    for sys in ONLINE_SYSTEMS {
        let off = run_with(&trace, &profiles, &cluster, sys,
                           Tracer::off());
        let tracer = Tracer::deterministic();
        let on = run_with(&trace, &profiles, &cluster, sys,
                          tracer.clone());
        assert_eq!(off.finish_times, on.finish_times, "{sys}");
        assert_eq!(off.jct_s, on.jct_s, "{sys}");
        assert_eq!(off.early_stopped, on.early_stopped, "{sys}");
        assert_eq!(off.launches, on.launches, "{sys}");
        let events = tracer.events();
        assert!(!events.is_empty(), "{sys} recorded nothing");
        validate(&events).unwrap_or_else(|e| panic!("{sys}: {e}"));
    }
}

#[test]
fn journal_round_trips_through_jsonl() {
    let (trace, profiles, cluster) = setup(7, 2);
    let tracer = Tracer::on();
    let _ = run_with(&trace, &profiles, &cluster, "online-saturn",
                     tracer.clone());
    let events = tracer.events();
    let text = write_jsonl(&events);
    let parsed = parse_jsonl(&text).expect("journal parses back");
    assert_eq!(events, parsed);
    // wall stamps survive the round trip (Tracer::on records them)
    assert!(parsed.iter().any(|e| e.wall_s.is_some()));
}

#[test]
fn spans_pair_and_every_resolve_carries_a_cause() {
    let (trace, profiles, cluster) = setup(42, 3);
    let tracer = Tracer::deterministic();
    let _ = run_with(&trace, &profiles, &cluster, "online-saturn",
                     tracer.clone());
    let events = tracer.events();
    validate(&events).expect("journal validates");
    let spans = paired_spans(&events).expect("spans pair");
    let resolves: Vec<_> = spans
        .iter()
        .filter(|s| s.cat == "solver" && s.name == "resolve")
        .collect();
    assert!(!resolves.is_empty(), "no re-solve episodes recorded");
    const CAUSES: [&str; 7] = ["initial", "arrival", "departure",
                               "introspection", "idle", "tick",
                               "drift-alarm"];
    for r in &resolves {
        let cause = r
            .args
            .get("cause")
            .and_then(|c| c.as_str())
            .unwrap_or_else(|| panic!("resolve without cause: {:?}",
                                      r.args));
        assert!(CAUSES.contains(&cause), "unknown cause '{cause}'");
    }
    // the arrival cause must appear: the trace streams multi-jobs in
    assert!(resolves.iter().any(|r| {
        r.args.get("cause").and_then(|c| c.as_str())
            == Some("arrival")
    }));
    // lifecycle instants all present
    for name in ["arrival", "launch", "complete"] {
        assert!(events.iter().any(|e| e.cat == "job" && e.name == name),
                "no job/{name} events");
    }
}

#[test]
fn solver_phase_spans_account_for_the_solve_wall_time() {
    let (trace, profiles, cluster) = setup(9, 3);
    let remaining: Vec<(usize, u64)> = trace
        .jobs
        .iter()
        .map(|o| (o.job.id, o.job.total_steps()))
        .collect();
    let terms: Vec<JobTerms> = remaining
        .iter()
        .map(|&(id, _)| JobTerms::neutral(id))
        .collect();
    let tracer = Tracer::on();
    let (_, stats) = solve_joint_traced(
        &remaining, &profiles, &cluster, SolverMode::Joint, 1.0, None,
        saturn::objective::Objective::Makespan, &terms, &tracer);
    let spans = paired_spans(&tracer.events()).expect("spans pair");
    let solve = spans
        .iter()
        .find(|s| s.cat == "solver" && s.name == "solve")
        .expect("solver/solve span");
    let solve_wall = solve.wall_dur_s().expect("wall-stamped");
    let phases = ["candidates", "plan_selection", "schedule",
                  "local_search"];
    let phase_sum: f64 = spans
        .iter()
        .filter(|s| s.cat == "solver"
            && phases.contains(&s.name.as_str()))
        .filter_map(|s| s.wall_dur_s())
        .sum();
    // acceptance: per-phase spans account for the solve span (and the
    // reported SolverStats::wall_s). The tolerance is loose (20% +
    // 10ms) because scheduler noise between spans on a loaded runner
    // inflates the gaps; the invariant that matters is coverage, not
    // an exact sum.
    let tol = 0.20 * solve_wall + 1e-2;
    assert!((solve_wall - phase_sum).abs() <= tol,
            "phases {phase_sum}s vs solve {solve_wall}s");
    assert!((solve_wall - stats.wall_s).abs() <= tol,
            "solve span {solve_wall}s vs stats.wall_s {}", stats.wall_s);
}

#[test]
fn summarizer_reconstructs_tails_from_the_journal_alone() {
    let (trace, profiles, cluster) = setup(42, 3);
    let tracer = Tracer::on();
    let mut perf = PerfModel::exact(&profiles);
    let cfg = SimConfig { trace: tracer.clone(), ..SimConfig::default() };
    let rungs = RungConfig::halving();
    let (_, m) = run_trace_sim(&trace, Some(&rungs), &mut perf, &cluster,
                               "online-saturn", SolverMode::Joint, None,
                               &cfg);
    // decision-latency tail surfaces in the metrics row...
    assert!(m.decision_p50_s > 0.0);
    assert!(m.decision_p99_s >= m.decision_p50_s);
    // ...and is independently recoverable from the journal
    let events = tracer.events();
    let s = summary::summarize(&events).expect("summarize");
    assert!(s.decision.count() > 0.0, "no sched/plan spans in journal");
    assert!(s.lifecycle.iter().any(|(n, c)| n == "complete" && *c > 0));
    let report = summary::render(&s);
    assert!(report.contains("p99"), "no tail table:\n{report}");
    assert!(report.contains("arrival"), "no cause rows:\n{report}");
    // chrome export carries the mandatory traceEvents array
    let chrome = chrome_trace(&events);
    assert!(chrome.get("traceEvents").is_some());
}

#[test]
fn histogram_tails_match_exact_percentiles_within_bucket_error() {
    // deterministic pseudo-spread over ~3 decades
    let xs: Vec<f64> = (0..600)
        .map(|i| 1e-4 * (1.0 + ((i * i) % 997) as f64))
        .collect();
    let mut h = Histogram::new();
    for &x in &xs {
        h.observe(x);
    }
    let mut sorted = xs.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for q in [0.5, 0.9, 0.95, 0.99] {
        let exact = percentile(&sorted, q);
        let approx = h.percentile(q);
        // 2^(1/8) log buckets: <= ~9% relative error per lookup
        assert!((approx - exact).abs() <= 0.10 * exact,
                "q={q}: approx {approx} vs exact {exact}");
    }
    assert_eq!(h.count(), xs.len() as f64);
}
