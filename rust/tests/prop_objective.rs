//! Property/integration suite for the pluggable scheduling objectives
//! (DESIGN.md §4.5), via the in-repo `util::prop` framework:
//!
//!  * **Behavior preservation** — under `Objective::Makespan` every
//!    online system replays bit-identically through the objective
//!    plumbing (the acceptance bar the `bench_objective` makespan arm
//!    holds against BENCH_online at 1e-6);
//!  * **Degeneracy** — `WeightedTardiness` with no deadlines and the
//!    `alpha = 1` endpoint of `WeightedJct` produce the pure-makespan
//!    plan bit for bit, across random workload sizes and weights;
//!  * **Endpoints** — `alpha = 0` tracks the pure priority-weighted-JCT
//!    lower bound (every job near its fastest plan), and the solver
//!    improves its own tardiness currency against the makespan plan on
//!    deadline-tight instances.

use saturn::cluster::ClusterSpec;
use saturn::objective::{JobTerms, Objective};
use saturn::online::{profile_trace, run_trace, run_trace_obj,
                     ONLINE_SYSTEMS};
use saturn::parallelism::default_library;
use saturn::perf::PerfModel;
use saturn::saturn::solver::{solve_joint, solve_joint_obj, SolverMode};
use saturn::sim::engine::RungConfig;
use saturn::trials::{profile_analytic, ProfileTable};
use saturn::util::prop::{forall, IntRange};
use saturn::workload::{generate_trace, toy_workload, TraceConfig};

fn setup(n: usize)
    -> (Vec<(usize, u64)>, ProfileTable, ClusterSpec) {
    let jobs = toy_workload(n);
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_analytic(&jobs, &default_library(), &cluster);
    let rem = jobs.iter().map(|j| (j.id, j.total_steps())).collect();
    (rem, profiles, cluster)
}

// ---------------------------------------------------------------------------
// behavior preservation: makespan objective == the historical path
// ---------------------------------------------------------------------------

#[test]
fn prop_makespan_objective_replays_every_system_bit_identically() {
    forall(201, 5, &IntRange(0, 1000), |&seed| {
        let trace = generate_trace(&TraceConfig {
            seed: seed as u64,
            multijobs: 3,
            deadline_slack_s: Some(6.0 * 3600.0),
            ..Default::default()
        });
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        for sys in ONLINE_SYSTEMS {
            let (a, ma) = run_trace(&trace, Some(&rungs), &profiles,
                                    &cluster, sys, SolverMode::Joint);
            let mut perf = PerfModel::exact(&profiles);
            let (b, mb) = run_trace_obj(&trace, Some(&rungs), &mut perf,
                                        &cluster, sys, SolverMode::Joint,
                                        None, Objective::Makespan);
            if a.finish_times != b.finish_times {
                return Err(format!("{sys}: finish times diverged"));
            }
            if a.jct_s != b.jct_s || a.early_stopped != b.early_stopped {
                return Err(format!("{sys}: departures diverged"));
            }
            if ma.makespan_s.to_bits() != mb.makespan_s.to_bits() {
                return Err(format!("{sys}: makespan bits diverged"));
            }
            // tardiness metrics exist on both paths and agree
            if ma.weighted_tardiness_s.to_bits()
                != mb.weighted_tardiness_s.to_bits()
            {
                return Err(format!("{sys}: tardiness metric diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn wjct_alpha_one_replays_like_makespan_for_every_system() {
    // the alpha = 1 endpoint degenerates everywhere: the solver builds
    // the makespan LP and EVERY policy (Saturn, Optimus, FIFO) keeps
    // its historical queue ordering — so whole replays are identical
    let trace = generate_trace(&TraceConfig {
        seed: 31,
        multijobs: 3,
        deadline_slack_s: Some(4.0 * 3600.0),
        ..Default::default()
    });
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();
    for sys in ONLINE_SYSTEMS {
        let mut perf_a = PerfModel::exact(&profiles);
        let (a, _) = run_trace_obj(&trace, Some(&rungs), &mut perf_a,
                                   &cluster, sys, SolverMode::Joint, None,
                                   Objective::Makespan);
        let mut perf_b = PerfModel::exact(&profiles);
        let (b, _) = run_trace_obj(&trace, Some(&rungs), &mut perf_b,
                                   &cluster, sys, SolverMode::Joint, None,
                                   Objective::WeightedJct { alpha: 1.0 });
        assert_eq!(a.finish_times, b.finish_times, "{sys}");
        assert_eq!(a.jct_s, b.jct_s, "{sys}");
        assert_eq!(a.early_stopped, b.early_stopped, "{sys}");
    }
}

#[test]
fn objective_arms_complete_identical_streams() {
    // non-makespan objectives still depart every job and stay within
    // capacity; weighted tardiness is finite and non-negative
    let trace = generate_trace(&TraceConfig {
        seed: 17,
        multijobs: 3,
        deadline_slack_s: Some(2.0 * 3600.0),
        ..Default::default()
    });
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();
    for objective in [
        Objective::WeightedTardiness { deadline_weight: 1.0 },
        Objective::WeightedJct { alpha: 0.5 },
        Objective::WeightedJct { alpha: 0.0 },
    ] {
        for sys in ONLINE_SYSTEMS {
            let mut perf = PerfModel::exact(&profiles);
            let (r, m) = run_trace_obj(&trace, Some(&rungs), &mut perf,
                                       &cluster, sys, SolverMode::Joint,
                                       None, objective);
            assert_eq!(r.finish_times.len(), trace.jobs.len(),
                       "{sys}/{}", objective.name());
            assert!(r.peak_gpus <= cluster.total_gpus());
            assert!(m.weighted_tardiness_s.is_finite());
            assert!(m.weighted_tardiness_s >= 0.0);
            assert!(m.total_tardiness_s >= m.weighted_tardiness_s - 1e-9,
                    "weighted mean cannot exceed the raw sum");
        }
    }
}

#[test]
fn objective_replays_are_bit_identical() {
    // determinism holds on the new code paths too
    let trace = generate_trace(&TraceConfig {
        seed: 23,
        multijobs: 3,
        deadline_slack_s: Some(3.0 * 3600.0),
        ..Default::default()
    });
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();
    for objective in [
        Objective::WeightedTardiness { deadline_weight: 1.0 },
        Objective::WeightedJct { alpha: 0.3 },
    ] {
        let run = || {
            let mut perf = PerfModel::exact(&profiles);
            run_trace_obj(&trace, Some(&rungs), &mut perf, &cluster,
                          "online-saturn", SolverMode::Joint, None,
                          objective)
                .0
        };
        let (a, b) = (run(), run());
        assert_eq!(a.finish_times, b.finish_times, "{}",
                   objective.name());
        assert_eq!(a.jct_s, b.jct_s);
        assert_eq!(a.total_tardiness_s.to_bits(),
                   b.total_tardiness_s.to_bits());
    }
}

// ---------------------------------------------------------------------------
// degeneracy: the makespan-equivalent corners are bit-identical
// ---------------------------------------------------------------------------

#[test]
fn prop_tardiness_without_deadlines_degenerates_to_makespan() {
    forall(202, 8, &IntRange(0, 1000), |&seed| {
        let n = 4 + (seed as usize % 8);
        let (rem, profiles, cluster) = setup(n);
        let (mk, _) =
            solve_joint(&rem, &profiles, &cluster, SolverMode::Joint);
        let terms: Vec<JobTerms> = rem
            .iter()
            .map(|&(id, _)| JobTerms {
                weight: 1.0 + ((seed as usize + id) % 4) as f64,
                ..JobTerms::neutral(id)
            })
            .collect();
        let dw = 0.5 + (seed % 7) as f64;
        let (td, _) = solve_joint_obj(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::WeightedTardiness { deadline_weight: dw }, &terms);
        if mk.choices != td.choices {
            return Err(format!("n={n}: choices diverged"));
        }
        if mk.predicted_makespan_s.to_bits()
            != td.predicted_makespan_s.to_bits()
        {
            return Err(format!("n={n}: makespan bits diverged"));
        }
        Ok(())
    });
}

#[test]
fn prop_wjct_alpha_one_degenerates_to_makespan() {
    forall(203, 8, &IntRange(0, 1000), |&seed| {
        let n = 4 + (seed as usize % 8);
        let (rem, profiles, cluster) = setup(n);
        let (mk, _) =
            solve_joint(&rem, &profiles, &cluster, SolverMode::Joint);
        let terms: Vec<JobTerms> = rem
            .iter()
            .map(|&(id, _)| JobTerms {
                weight: 1.0 + (id % 3) as f64,
                due_in_s: Some(3600.0),
                ..JobTerms::neutral(id)
            })
            .collect();
        let (wj, _) = solve_joint_obj(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::WeightedJct { alpha: 1.0 }, &terms);
        if mk.choices != wj.choices {
            return Err(format!("n={n}: choices diverged"));
        }
        if mk.predicted_makespan_s.to_bits()
            != wj.predicted_makespan_s.to_bits()
        {
            return Err(format!("n={n}: makespan bits diverged"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// endpoints: alpha = 0 is pure weighted JCT
// ---------------------------------------------------------------------------

#[test]
fn prop_wjct_alpha_zero_tracks_the_weighted_jct_bound() {
    forall(204, 6, &IntRange(0, 1000), |&seed| {
        let n = 4 + (seed as usize % 6);
        let (rem, profiles, cluster) = setup(n);
        let terms: Vec<JobTerms> = rem
            .iter()
            .map(|&(id, _)| JobTerms {
                weight: 1.0 + ((seed as usize + id) % 4) as f64,
                ..JobTerms::neutral(id)
            })
            .collect();
        let (wj, _) = solve_joint_obj(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::WeightedJct { alpha: 0.0 }, &terms);
        let w_of = |id: usize| {
            terms.iter().find(|t| t.job_id == id).unwrap().weight
        };
        let w_sum: f64 = terms.iter().map(|t| t.weight).sum();
        let chosen: f64 = wj
            .choices
            .iter()
            .map(|p| w_of(p.job_id) / w_sum * p.runtime_s)
            .sum();
        let bound: f64 = rem
            .iter()
            .map(|&(id, steps)| {
                let fastest = profiles
                    .candidate_plans(id)
                    .into_iter()
                    .map(|(_, _, _, s)| s * steps as f64)
                    .fold(f64::INFINITY, f64::min);
                w_of(id) / w_sum * fastest
            })
            .sum();
        if chosen > bound * 1.02 + 1.0 {
            return Err(format!(
                "n={n}: alpha=0 strayed from the wjct bound: \
                 {chosen} vs {bound}"));
        }
        Ok(())
    });
}
