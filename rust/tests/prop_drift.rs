//! Property-based invariants for the estimate-vs-truth split
//! (DESIGN.md §4.4), via the in-repo `util::prop` framework:
//!
//!  * **Strict generalization** — a zero-drift run through the perf
//!    machinery is bit-identical to the plain `simulate_online` path
//!    (whose behavior the pre-split tier-1 tests pin down), for every
//!    online system over random traces;
//!  * **Learning** — under stationary drift (static mis-calibration,
//!    no ramps, no interference) the estimate error is non-increasing:
//!    per-cell convergence is monotone, and a correcting run's mean
//!    |ln(observed/estimated)| never exceeds the frozen-estimate run's;
//!  * **Trigger** — the drift-triggered re-solve fires iff the
//!    observed/estimated ratio crosses the threshold (unit-level iff,
//!    plus policy-level: zero/low drift never fires, heavy drift with
//!    persistent mismatch does).

use saturn::cluster::ClusterSpec;
use saturn::online::{profile_trace, run_trace, run_trace_perf};
use saturn::parallelism::default_library;
use saturn::perf::{DriftConfig, EstimateModel, Observation, PerfModel};
use saturn::saturn::introspect::drift_resolve_due;
use saturn::saturn::solver::SolverMode;
use saturn::sim::engine::RungConfig;
use saturn::trials::{profile_analytic, ProfileTable};
use saturn::util::prop::{forall, IntRange, Strategy};
use saturn::util::rng::Rng;
use saturn::workload::{generate_trace, toy_workload, TraceConfig};

fn trace_of_seed(seed: u64) -> saturn::workload::Trace {
    generate_trace(&TraceConfig {
        seed,
        multijobs: 3,
        ..Default::default()
    })
}

/// A profiled table whose job 0 (ResNet-200) definitely has a 1-GPU cell.
fn toy_profiles() -> ProfileTable {
    let jobs = toy_workload(4);
    profile_analytic(&jobs, &default_library(), &ClusterSpec::p4d(1))
}

// ---------------------------------------------------------------------------
// strict generalization: zero drift == the plain path, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn prop_zero_drift_is_bit_identical_to_the_plain_simulator() {
    forall(101, 6, &IntRange(0, 1000), |&seed| {
        let trace = trace_of_seed(seed as u64);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        for sys in ["online-current-practice", "online-optimus",
                    "online-saturn"] {
            let (a, ma) = run_trace(&trace, Some(&rungs), &profiles,
                                    &cluster, sys, SolverMode::Joint);
            let mut perf = PerfModel::with_drift(&profiles,
                                                 DriftConfig::none(), true);
            let (b, mb) = run_trace_perf(&trace, Some(&rungs), &mut perf,
                                         &cluster, sys, SolverMode::Joint,
                                         None);
            if a.finish_times != b.finish_times {
                return Err(format!("{sys}: finish times diverged"));
            }
            if a.jct_s != b.jct_s || a.early_stopped != b.early_stopped {
                return Err(format!("{sys}: departures diverged"));
            }
            if ma.makespan_s.to_bits() != mb.makespan_s.to_bits() {
                return Err(format!("{sys}: makespan bits diverged"));
            }
            if mb.estimate_mae != 0.0 {
                return Err(format!(
                    "{sys}: zero drift produced estimate error {}",
                    mb.estimate_mae));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// learning: estimate error non-increasing under stationary drift
// ---------------------------------------------------------------------------

/// Stationary drift: a static per-(job, class) mis-calibration, no ramps
/// and no interference — the truth is constant in time.
fn stationary(seed: u64, noise: f64) -> DriftConfig {
    DriftConfig {
        seed,
        ramp_magnitude: 0.0,
        ramp_tau_s: 7200.0,
        interference_per_hour: 0.0,
        interference_mult: 1.0,
        interference_s: 0.0,
        cell_noise: noise,
        tenant_spread: 0.0,
    }
}

/// Random constant-ratio observation streams for one profiled cell.
struct RatioStream;

impl Strategy for RatioStream {
    type Value = (i64, i64); // (ratio in percent 50..200, observations)

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (rng.range(50, 200), rng.range(2, 20))
    }
}

#[test]
fn prop_percell_convergence_is_monotone() {
    let profiles = toy_profiles();
    let (tech, base) = profiles.best_at(0, 1, 0).expect("cell profiled");
    forall(102, 60, &RatioStream, |&(pct, n)| {
        let ratio = pct as f64 / 100.0;
        if (ratio - 1.0).abs() < 1e-9 {
            return Ok(());
        }
        let mut m = EstimateModel::new(profiles.clone(), true);
        let mut last = f64::INFINITY;
        for k in 0..n {
            m.observe(&Observation {
                job_id: 0,
                tech,
                gpus: 1,
                class: 0,
                steps: 8.0,
                step_time_s: base * ratio,
                at_s: k as f64,
            });
            let est = m.step_time(0, tech, 1, 0).unwrap();
            let err = (base * ratio / est).ln().abs();
            if err > last + 1e-12 {
                return Err(format!(
                    "error rose from {last} to {err} at obs {k}"));
            }
            last = err;
        }
        Ok(())
    });
}

#[test]
fn prop_correction_never_raises_stationary_estimate_error() {
    forall(103, 5, &IntRange(0, 1000), |&seed| {
        let trace = trace_of_seed(11);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        let drift = stationary(seed as u64, 0.15);
        let run = |correction: bool| {
            let mut perf =
                PerfModel::with_drift(&profiles, drift.clone(), correction);
            let (r, _) = run_trace_perf(&trace, Some(&rungs), &mut perf,
                                        &cluster, "online-saturn",
                                        SolverMode::Joint, None);
            r
        };
        let on = run(true);
        let off = run(false);
        if on.observations == 0 || off.observations == 0 {
            return Err("no observations under stationary drift".into());
        }
        // the frozen model's mean error IS the stationary drift level;
        // correction converges toward zero, so its run-mean must not
        // exceed the frozen level (small slack: the first observation
        // of a job is always a full surprise)
        if on.estimate_mae > off.estimate_mae * 1.10 + 0.02 {
            return Err(format!(
                "correction raised the estimate error: {} vs frozen {}",
                on.estimate_mae, off.estimate_mae));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// per-tenant drift profiles (DriftConfig::tenant_spread)
// ---------------------------------------------------------------------------

#[test]
fn prop_zero_tenant_spread_is_bit_identical() {
    // the zero-spread arm with tenant classes attached must replay the
    // plain drift run bit for bit, for every online system
    forall(105, 4, &IntRange(0, 1000), |&seed| {
        let trace = trace_of_seed(seed as u64);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        let mut cfg = DriftConfig::uniform(seed as u64 + 1, 0.2);
        cfg.tenant_spread = 0.0;
        let tenants: Vec<f64> =
            trace.jobs.iter().map(|o| o.priority - 1.0).collect();
        for sys in ["online-current-practice", "online-optimus",
                    "online-saturn"] {
            let mut plain =
                PerfModel::with_drift(&profiles, cfg.clone(), true);
            let (a, ma) = run_trace_perf(&trace, Some(&rungs), &mut plain,
                                         &cluster, sys, SolverMode::Joint,
                                         None);
            let mut spread0 = PerfModel::with_drift_tenants(
                &profiles, cfg.clone(), true, tenants.clone());
            let (b, mb) = run_trace_perf(&trace, Some(&rungs),
                                         &mut spread0, &cluster, sys,
                                         SolverMode::Joint, None);
            if a.finish_times != b.finish_times {
                return Err(format!("{sys}: finish times diverged"));
            }
            if ma.estimate_mae.to_bits() != mb.estimate_mae.to_bits() {
                return Err(format!("{sys}: estimate MAE bits diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn tenant_spread_changes_the_drifted_schedule() {
    // a positive spread must actually reshape the truth: the run with
    // per-tenant ramps diverges from the shared-magnitude run
    let trace = trace_of_seed(42);
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();
    // alternate tenant classes by job id so the spread is guaranteed
    // to bite regardless of the trace's tenant draw
    let tenants: Vec<f64> =
        trace.jobs.iter().map(|o| (o.job.id % 2) as f64).collect();
    let run = |spread: f64| {
        let mut cfg = DriftConfig::uniform(7, 0.2);
        cfg.tenant_spread = spread;
        let mut perf = PerfModel::with_drift_tenants(
            &profiles, cfg, true, tenants.clone());
        run_trace_perf(&trace, Some(&rungs), &mut perf, &cluster,
                       "online-saturn", SolverMode::Joint, None)
            .0
    };
    let base = run(0.0);
    let spread = run(1.5);
    assert!(base.finish_times != spread.finish_times
                || (base.makespan_s - spread.makespan_s).abs() > 1e-9,
            "tenant spread 1.5 left the schedule untouched");
}

// ---------------------------------------------------------------------------
// trigger: drift-triggered re-solve fires iff the threshold is crossed
// ---------------------------------------------------------------------------

#[test]
fn prop_trigger_fires_iff_ratio_crosses_threshold() {
    let profiles = toy_profiles();
    let (tech, base) = profiles.best_at(0, 1, 0).expect("cell profiled");
    forall(104, 80, &RatioStream, |&(pct, _)| {
        let ratio = pct as f64 / 100.0;
        let threshold = 0.10f64;
        let mut m = EstimateModel::new(profiles.clone(), false);
        let before = m.obs_seen();
        m.observe(&Observation {
            job_id: 0,
            tech,
            gpus: 1,
            class: 0,
            steps: 4.0,
            step_time_s: base * ratio,
            at_s: 1.0,
        });
        let fired = drift_resolve_due(Some(threshold), before, m.obs_seen(),
                                      m.drift_alarm());
        let crossed = ratio.ln().abs() > threshold;
        if fired != crossed {
            return Err(format!(
                "ratio {ratio:.2}: |ln|={:.3} vs th={threshold}, fired={fired}",
                ratio.ln().abs()));
        }
        // without NEW observations the trigger must never fire, no
        // matter how loud the alarm
        if drift_resolve_due(Some(threshold), m.obs_seen(), m.obs_seen(),
                             m.drift_alarm()) {
            return Err("fired without new observations".into());
        }
        // a disabled threshold never fires
        if drift_resolve_due(None, before, m.obs_seen(), m.drift_alarm()) {
            return Err("fired with threshold disabled".into());
        }
        Ok(())
    });
}

#[test]
fn drift_resolves_zero_below_threshold_positive_above() {
    let trace = trace_of_seed(42);
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();
    let run = |drift: DriftConfig, correction: bool| {
        let mut perf = PerfModel::with_drift(&profiles, drift, correction);
        let (_, m) = run_trace_perf(&trace, Some(&rungs), &mut perf,
                                    &cluster, "online-saturn",
                                    SolverMode::Joint, None);
        m.drift_resolves.expect("saturn reports drift re-solves")
    };
    // zero drift: the alarm is exactly 0.0 and can never cross
    assert_eq!(run(DriftConfig::none(), true), 0);
    // tiny stationary drift: |ln| stays far below the 0.10 default
    // (sigma 0.005 bounds the worst mismatch well under the threshold)
    assert_eq!(run(stationary(1, 0.005), true), 0);
    // heavy drift with correction OFF keeps the mismatch at the drift
    // level, so introspection-checkpoint observations must trigger
    let fired = run(DriftConfig::uniform(1, 0.3), false);
    assert!(fired > 0, "30% drift never fired the drift trigger");
}
