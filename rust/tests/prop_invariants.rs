//! Property-based invariants over the solver, scheduler, cost models and
//! substrates, using the in-repo `util::prop` framework.

use saturn::cluster::ClusterSpec;
use saturn::parallelism::default_library;
use saturn::saturn::solver::{solve_joint, SolverMode};
use saturn::sim::engine::{simulate, SimConfig};
use saturn::sim::placement::FreeState;
use saturn::solver::lp::{solve as lp_solve, Cmp, Lp, LpResult};
use saturn::solver::milp::{solve as milp_solve, MilpOptions};
use saturn::trials::profile_analytic;
use saturn::util::json::Json;
use saturn::util::prop::{forall, IntRange, PairOf, Strategy, VecOf};
use saturn::util::rng::Rng;
use saturn::workload::{toy_workload, Job};
use saturn::models::{DatasetSpec, ModelSpec};

// ---------------------------------------------------------------------------
// LP / MILP
// ---------------------------------------------------------------------------

/// Random bounded-feasible LP: min c'x, x <= ub, a'x <= b with a,ub >= 0.
struct RandomLp;

impl Strategy for RandomLp {
    type Value = (Vec<i64>, Vec<i64>, Vec<i64>, i64); // c, ub, a, b

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = 2 + rng.usize(4);
        let c: Vec<i64> = (0..n).map(|_| rng.range(-5, 6)).collect();
        let ub: Vec<i64> = (0..n).map(|_| rng.range(1, 8)).collect();
        let a: Vec<i64> = (0..n).map(|_| rng.range(0, 5)).collect();
        let b = rng.range(1, 30);
        (c, ub, a, b)
    }
}

fn build_lp(v: &(Vec<i64>, Vec<i64>, Vec<i64>, i64)) -> Lp {
    let (c, ub, a, b) = v;
    let mut lp = Lp::new(c.len());
    for j in 0..c.len() {
        lp.set_obj(j, c[j] as f64);
        lp.bound_le(j, ub[j] as f64);
    }
    lp.add(a.iter().cloned().enumerate()
            .map(|(j, x)| (j, x as f64)).collect(), Cmp::Le, *b as f64);
    lp
}

#[test]
fn prop_lp_solution_is_feasible_and_beats_random_points() {
    forall(42, 60, &RandomLp, |v| {
        let lp = build_lp(v);
        let LpResult::Optimal { x, objective } = lp_solve(&lp) else {
            return Err("bounded feasible LP must be optimal".into());
        };
        // feasibility of the returned vertex
        let (c, ub, a, b) = v;
        for j in 0..x.len() {
            if x[j] < -1e-7 || x[j] > ub[j] as f64 + 1e-7 {
                return Err(format!("x[{j}]={} violates bounds", x[j]));
            }
        }
        let lhs: f64 = x.iter().zip(a).map(|(xi, ai)| xi * *ai as f64).sum();
        if lhs > *b as f64 + 1e-6 {
            return Err("capacity violated".into());
        }
        // optimality vs sampled feasible points
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let y: Vec<f64> = ub.iter().map(|&u| rng.f64() * u as f64).collect();
            let cap: f64 = y.iter().zip(a).map(|(yi, ai)| yi * *ai as f64).sum();
            if cap <= *b as f64 {
                let val: f64 = y.iter().zip(c).map(|(yi, ci)| yi * *ci as f64).sum();
                if val < objective - 1e-6 {
                    return Err(format!(
                        "sampled point {val} beats 'optimal' {objective}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_milp_matches_bruteforce_on_random_binary_programs() {
    forall(43, 30, &RandomLp, |v| {
        let (c, _, a, b) = v;
        let n = c.len();
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_obj(j, c[j] as f64);
            lp.bound_le(j, 1.0);
        }
        lp.add(a.iter().cloned().enumerate()
                .map(|(j, x)| (j, x as f64)).collect(), Cmp::Le, *b as f64);
        let ints: Vec<usize> = (0..n).collect();
        let res = milp_solve(&lp, &ints, &MilpOptions::default());
        let Some((_, got)) = res.solution() else {
            return Err("binary program with x=0 feasible must solve".into());
        };
        // brute force
        let mut best = f64::INFINITY;
        for mask in 0u32..(1 << n) {
            let (mut val, mut cap) = (0.0, 0.0);
            for j in 0..n {
                if mask & (1 << j) != 0 {
                    val += c[j] as f64;
                    cap += a[j] as f64;
                }
            }
            if cap <= *b as f64 {
                best = best.min(val);
            }
        }
        if (got - best).abs() > 1e-6 {
            return Err(format!("milp {got} != brute {best}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// scheduler / simulator invariants
// ---------------------------------------------------------------------------

/// Random toy multi-jobs: (n_jobs, seed).
struct RandomWorkload;

impl Strategy for RandomWorkload {
    type Value = (i64, i64);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (rng.range(1, 10), rng.range(0, 1000))
    }
}

fn vary(jobs: &mut [Job], seed: i64) {
    let mut rng = Rng::new(seed as u64);
    let models = [ModelSpec::resnet200(), ModelSpec::gpt2_xl(),
                  ModelSpec::vit_g(), ModelSpec::gpt_j()];
    for j in jobs.iter_mut() {
        j.model = models[rng.usize(models.len())].clone();
        j.batch = *rng.choice(&[16u32, 32, 64]);
        j.dataset = DatasetSpec { name: "rand".into(),
                                  samples: 512 + rng.range(0, 4096) as u64 };
    }
}

#[test]
fn prop_all_policies_finish_every_job_exactly_once() {
    forall(44, 12, &RandomWorkload, |&(n, seed)| {
        let mut jobs = toy_workload(n as usize);
        vary(&mut jobs, seed);
        let cluster = ClusterSpec::p4d(1 + (seed % 2) as u32);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        for sys in saturn::exp::SYSTEMS {
            let cell = saturn::exp::run_cell_with(&jobs, &profiles, &cluster,
                                                  sys, seed as u64);
            let mut ids: Vec<usize> =
                cell.result.finish_times.iter().map(|(id, _)| *id).collect();
            ids.sort();
            if ids != (0..jobs.len()).collect::<Vec<_>>() {
                return Err(format!("{sys}: jobs finished {ids:?}"));
            }
            if cell.result.gpu_utilization > 1.0 + 1e-9 {
                return Err(format!("{sys}: oversubscribed GPUs util={}",
                                   cell.result.gpu_utilization));
            }
            // makespan >= best possible single-job runtime (sanity floor)
            let floor = jobs
                .iter()
                .map(|j| {
                    profiles
                        .pareto_plans(j.id, 0)
                        .last()
                        .map(|p| p.2 * j.total_steps() as f64)
                        .unwrap_or(0.0)
                })
                .fold(0.0f64, f64::max);
            if cell.result.makespan_s < floor * 0.999 {
                return Err(format!("{sys}: makespan below physical floor"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_solver_never_plans_infeasible_combinations() {
    forall(45, 15, &RandomWorkload, |&(n, seed)| {
        let mut jobs = toy_workload(n as usize);
        vary(&mut jobs, seed);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let remaining: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        for mode in [SolverMode::Joint, SolverMode::Heuristic] {
            let (plan, _) = solve_joint(&remaining, &profiles, &cluster, mode);
            for p in &plan.choices {
                if profiles
                    .step_time(p.job_id, p.tech, p.gpus, p.class)
                    .is_none()
                {
                    return Err(format!(
                        "plan uses infeasible (job={}, tech={}, g={}, cls={})",
                        p.job_id, p.tech, p.gpus, p.class));
                }
                if p.gpus > cluster.class_gpus(p.class) {
                    return Err("plan exceeds its class".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pareto_runtime_monotone_in_gpus() {
    let jobs = toy_workload(8);
    let cluster = ClusterSpec::p4d(2);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, &cluster);
    for j in &jobs {
        let plans = profiles.pareto_plans(j.id, 0);
        for w in plans.windows(2) {
            assert!(w[1].1 > w[0].1 && w[1].2 < w[0].2,
                    "pareto set not monotone for {}", j.name);
        }
    }
}

#[test]
fn prop_placement_conserves_gpus() {
    forall(46, 200, &VecOf { inner: IntRange(1, 16), min_len: 1, max_len: 10 },
           |sizes| {
        let cluster = ClusterSpec::p4d(2);
        let mut free = FreeState::new(&cluster);
        let total = free.total_free();
        let mut placed = Vec::new();
        let mut used = 0;
        for &g in sizes {
            if let Some(p) = free.place(0, g as u32) {
                used += g as u32;
                placed.push(p);
            }
        }
        if free.total_free() + used != total {
            return Err("GPU accounting leak".into());
        }
        for p in &placed {
            free.release(p);
        }
        if free.total_free() != total {
            return Err("release did not restore".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// substrates
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip_for_random_trees() {
    struct RandomJson;
    impl Strategy for RandomJson {
        type Value = String;
        fn generate(&self, rng: &mut Rng) -> String {
            fn gen(rng: &mut Rng, depth: usize) -> Json {
                match if depth > 2 { rng.usize(4) } else { rng.usize(6) } {
                    0 => Json::Null,
                    1 => Json::Bool(rng.bool(0.5)),
                    2 => Json::Num((rng.range(-1000, 1000) as f64) / 8.0),
                    3 => Json::Str(format!("s{}", rng.next_u64() % 1000)),
                    4 => Json::arr((0..rng.usize(4)).map(|_| gen(rng, depth + 1))),
                    _ => Json::Obj(
                        (0..rng.usize(4))
                            .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                            .collect(),
                    ),
                }
            }
            gen(rng, 0).to_string()
        }
    }
    forall(47, 200, &RandomJson, |s| {
        let a = Json::parse(s).map_err(|e| e.to_string())?;
        let b = Json::parse(&a.to_string()).map_err(|e| e.to_string())?;
        if a != b {
            return Err(format!("roundtrip mismatch: {a} vs {b}"));
        }
        Ok(())
    });
}

#[test]
fn prop_simulation_deterministic_for_fixed_seed() {
    forall(48, 8, &PairOf(IntRange(2, 8), IntRange(0, 99)), |&(n, seed)| {
        let jobs = toy_workload(n as usize);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let run = || {
            let mut p = saturn::baselines::RandomPolicy::new(seed as u64);
            simulate(&jobs, &profiles, &cluster, &mut p, &SimConfig::default())
                .makespan_s
        };
        if run() != run() {
            return Err("nondeterministic simulation".into());
        }
        Ok(())
    });
}
