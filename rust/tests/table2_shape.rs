//! E1/E2/E6: the headline reproduction — Table 2's *shape* must hold on
//! the simulated testbed (who wins, by roughly what factor, and the
//! ordering between baselines). Absolute hours differ from the authors'
//! physical cluster by design (see EXPERIMENTS.md).

use saturn::exp;

fn row(workload: &str) -> Vec<(f64, f64)> {
    exp::run_row(workload, 0)
        .into_iter()
        .map(|(a, b)| (a.makespan_h, b.makespan_h))
        .collect()
}

#[test]
fn table2_wikitext_shape() {
    let r = row("wikitext");
    let (cp, rnd, opt, od, sat) = (r[0], r[1], r[2], r[3], r[4]);
    // Saturn strictly fastest on 1 node; best-or-within-5% on 2 nodes
    // (the 2-node imagenet cell saturates: all efficient systems converge,
    // see EXPERIMENTS.md E1/E2 discussion)
    for (name, other) in [("cp", cp), ("random", rnd), ("optimus", opt),
                          ("optimus-dynamic", od)] {
        assert!(sat.0 < other.0, "1-node: saturn {:.2} !< {name} {:.2}",
                sat.0, other.0);
        assert!(sat.1 < other.1 * 1.05, "2-node: saturn {:.2} !~< {name} {:.2}",
                sat.1, other.1);
    }
    // paper band: 1.64-1.96x vs current practice; accept a generous
    // 1.3-2.8x on the simulated substrate
    let speedup1 = cp.0 / sat.0;
    let speedup2 = cp.1 / sat.1;
    assert!((1.3..2.8).contains(&speedup1), "1-node speedup {speedup1:.2}");
    assert!((1.3..2.8).contains(&speedup2), "2-node speedup {speedup2:.2}");
    // Random is the worst or near-worst (paper: clearly worst)
    assert!(rnd.0 >= cp.0 * 0.9 && rnd.0 >= od.0,
            "random unexpectedly good: {rnd:?}");
    // Optimus-Dynamic improves on Optimus (paper row ordering)
    assert!(od.0 <= opt.0 * 1.02 && od.1 <= opt.1 * 1.02);
}

#[test]
fn table2_imagenet_shape() {
    let r = row("imagenet");
    let (cp, _rnd, opt, od, sat) = (r[0], r[1], r[2], r[3], r[4]);
    for (name, other) in [("cp", cp), ("optimus", opt), ("od", od)] {
        assert!(sat.0 < other.0, "saturn !< {name}");
        assert!(sat.1 < other.1 * 1.05, "2-node: saturn !~< {name}");
    }
    let speedup = cp.0 / sat.0;
    assert!((1.25..2.8).contains(&speedup),
            "imagenet 1-node speedup {speedup:.2} outside band");
}

#[test]
fn table2_two_nodes_scale_all_systems() {
    for workload in ["wikitext", "imagenet"] {
        for (one, two) in row(workload) {
            assert!(two < one, "{workload}: 2-node {two:.2} !< 1-node {one:.2}");
            assert!(two > one * 0.35, "{workload}: superlinear scaling?");
        }
    }
}

#[test]
fn reduction_percentages_in_paper_range() {
    // paper §3: "training time reductions of 39-48%". On the simulated
    // substrate we accept 15-65%: the weakest cell (imagenet 2-node, 16%)
    // is efficiency-saturated — see EXPERIMENTS.md E6.
    for workload in ["wikitext", "imagenet"] {
        let r = row(workload);
        for idx in [0usize, 1] {
            let cp = if idx == 0 { r[0].0 } else { r[0].1 };
            let sat = if idx == 0 { r[4].0 } else { r[4].1 };
            let reduction = 100.0 * (1.0 - sat / cp);
            assert!((15.0..65.0).contains(&reduction),
                    "{workload} node-config {idx}: reduction {reduction:.0}%");
        }
    }
}
