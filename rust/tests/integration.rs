//! Integration tests: cross-module flows through the whole L3 stack, plus
//! failure injection on the artifact boundary.

use std::collections::HashMap;

use saturn::cluster::ClusterSpec;
use saturn::coordinator::{real_grid, Coordinator};
use saturn::exp;
use saturn::parallelism::default_library;
use saturn::runtime::{Engine, Manifest};
use saturn::saturn::solver::{solve_joint, SolverMode};
use saturn::saturn::SaturnPolicy;
use saturn::sim::engine::{simulate, SimConfig};
use saturn::trials::{profile_analytic, profile_empirical};
use saturn::workload::{imagenet_workload, wikitext_workload};

// ---------------------------------------------------------------------------
// full pipeline over every (workload, nodes, system) combination
// ---------------------------------------------------------------------------

#[test]
fn all_systems_complete_all_workloads() {
    for workload in ["wikitext", "imagenet"] {
        for nodes in [1u32, 2] {
            for sys in exp::SYSTEMS {
                let cell = exp::run_cell(workload, nodes, sys, 1);
                assert!(cell.makespan_h > 0.0,
                        "{sys}/{workload}/{nodes}n produced zero makespan");
                assert_eq!(cell.result.finish_times.len(), 12);
                assert!(cell.result.gpu_utilization <= 1.0 + 1e-9);
            }
        }
    }
}

#[test]
fn saturn_beats_every_baseline_on_both_workloads() {
    for workload in ["wikitext", "imagenet"] {
        let sat = exp::run_cell(workload, 1, "saturn", 0).makespan_h;
        for sys in &exp::SYSTEMS[..4] {
            let other = exp::run_cell(workload, 1, sys, 0).makespan_h;
            assert!(sat < other,
                    "{workload}: saturn {sat:.2}h !< {sys} {other:.2}h");
        }
    }
}

#[test]
fn profiles_internally_consistent_across_node_counts() {
    let jobs = wikitext_workload();
    let lib = default_library();
    let p1 = profile_analytic(&jobs, &lib, &ClusterSpec::p4d(1));
    let p2 = profile_analytic(&jobs, &lib, &ClusterSpec::p4d(2));
    // single-node estimates must be identical regardless of fleet size
    for j in &jobs {
        for t in 0..p1.n_techniques {
            for g in [1u32, 2, 4, 8] {
                assert_eq!(p1.step_time(j.id, t, g, 0),
                           p2.step_time(j.id, t, g, 0),
                           "job {} tech {t} g{g}", j.name);
            }
        }
    }
}

#[test]
fn empirical_profiles_flow_into_solver() {
    let jobs = imagenet_workload();
    let lib = default_library();
    let cluster = ClusterSpec::p4d(1);
    let mut measured = HashMap::new();
    for j in &jobs {
        measured.insert(j.id, 0.5 + j.id as f64 * 0.01);
    }
    let profiles = profile_empirical(&jobs, &lib, &cluster, &measured);
    let remaining: Vec<(usize, u64)> =
        jobs.iter().map(|j| (j.id, j.total_steps())).collect();
    let (plan, _) = solve_joint(&remaining, &profiles, &cluster,
                                SolverMode::Joint);
    assert_eq!(plan.choices.len(), 12);
}

#[test]
fn introspection_interval_sweep_is_stable() {
    let jobs = wikitext_workload();
    let lib = default_library();
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_analytic(&jobs, &lib, &cluster);
    let mut makespans = Vec::new();
    for interval in [None, Some(1800.0), Some(3600.0)] {
        let mut p = SaturnPolicy::new(SolverMode::Joint, interval);
        let r = simulate(&jobs, &profiles, &cluster, &mut p,
                         &SimConfig::default());
        makespans.push(r.makespan_s);
    }
    let base = makespans[0];
    for m in &makespans {
        assert!((m - base).abs() / base < 0.25,
                "introspection destabilized a static workload: {makespans:?}");
    }
}

// ---------------------------------------------------------------------------
// runtime boundary (requires `make artifacts`)
// ---------------------------------------------------------------------------

#[test]
fn runtime_end_to_end_two_jobs_real_training() {
    let coord = match Coordinator::new(2) {
        Ok(c) => c,
        Err(e) => {
            // PJRT stub / missing artifacts: skip instead of failing
            eprintln!("skipping runtime e2e test: {e:#}");
            return;
        }
    };
    let jobs = real_grid(&[("tiny", 8)], &[3e-3, 1e-4], 8);
    let r = coord.run_model_selection(&jobs, 11).unwrap();
    assert_eq!(r.outcomes.len(), 2);
    // higher LR learns faster from random init on this tiny budget
    let by_lr: HashMap<String, f32> = r
        .outcomes
        .iter()
        .map(|o| (format!("{:.0e}", o.job.lr), o.final_loss))
        .collect();
    assert!(by_lr["3e-3"] < by_lr["1e-4"],
            "3e-3 {} should beat 1e-4 {}", by_lr["3e-3"], by_lr["1e-4"]);
}

#[test]
fn corrupt_manifest_is_rejected_cleanly() {
    let dir = std::env::temp_dir().join("saturn_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("json"),
            "unexpected error: {err:#}");
}

#[test]
fn missing_artifact_file_fails_at_load_not_at_parse() {
    let dir = std::env::temp_dir().join("saturn_missing_artifact");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version":1,"artifacts":[{"name":"ghost","file":"ghost.hlo.txt",
            "kind":"train","model":"ghost","batch":8,"seq":64,"vocab":512,
            "param_count":10,"padded_params":2048,"flops_per_step":1.0,
            "inputs":[],"outputs":[]}]}"#,
    )
    .unwrap();
    let m = Manifest::load(&dir).unwrap();
    let spec = m.train("ghost", 8).unwrap();
    let Ok(engine) = Engine::cpu() else {
        // PJRT stub: loading any artifact errors trivially; skip
        eprintln!("skipping: PJRT backend unavailable");
        return;
    };
    assert!(engine.load_artifact(spec).is_err());
}

#[test]
fn manifest_missing_required_field_errors() {
    let dir = std::env::temp_dir().join("saturn_bad_field");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"),
                   r#"{"artifacts":[{"name":"x"}]}"#).unwrap();
    assert!(Manifest::load(&dir).is_err());
}
