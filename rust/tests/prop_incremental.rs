//! Property-based invariants for the incremental re-solve path
//! (DESIGN.md §4.9), via the in-repo `util::prop` framework:
//!
//!  * with budgets off, a delta re-solve is objective-identical
//!    (<= 1e-6 relative) to a from-scratch solve across random
//!    arrival/departure mixes — in both threading regimes (the
//!    single-threaded colgen master at <= 64 jobs and the 4-thread
//!    sharded cells above it);
//!  * a budget-capped solve is never worse than the greedy fallback
//!    (the anytime floor);
//!  * incremental online runs conserve jobs, stay within capacity, and
//!    replay deterministically; with the knobs off they are
//!    bit-identical to the plain replay;
//!  * a staggered burst under a coalescing window folds events without
//!    losing jobs.

use saturn::cluster::ClusterSpec;
use saturn::objective::Objective;
use saturn::obs::trace::Tracer;
use saturn::online::{profile_trace, run_trace, run_trace_knobs,
                     OnlineKnobs};
use saturn::parallelism::default_library;
use saturn::perf::PerfModel;
use saturn::saturn::solver::{plan_selection_probe, solve_joint,
                             solve_joint_budgeted, SolveBudget,
                             SolverMode};
use saturn::saturn::IncrementalSolver;
use saturn::sim::engine::{RungConfig, SimConfig};
use saturn::solver::milp::MilpEngine;
use saturn::trials::{profile_analytic, ProfileTable};
use saturn::util::prop::{forall, Strategy};
use saturn::util::rng::Rng;
use saturn::workload::{generate_trace, toy_workload, ArrivalProcess,
                       TraceConfig};

fn profile_n(n: usize, cluster: &ClusterSpec)
    -> (Vec<(usize, u64)>, ProfileTable) {
    let jobs = toy_workload(n);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, cluster);
    let roster: Vec<(usize, u64)> =
        jobs.iter().map(|j| (j.id, j.total_steps())).collect();
    (roster, profiles)
}

/// Seed an incremental solver from a full solve of `before`, replay the
/// event to `after` as a delta, and check the tight-gap parity of the
/// state-seeded probe against the from-scratch probe.
fn check_delta_parity(before: &[(usize, u64)], after: &[(usize, u64)],
                      profiles: &ProfileTable, cluster: &ClusterSpec,
                      mode: SolverMode) -> Result<(), String> {
    let (plan, _) = solve_joint_budgeted(
        before, profiles, cluster, mode, 1.0, None, Objective::Makespan,
        &[], &Tracer::off(), None, SolveBudget::default());
    let mut inc = IncrementalSolver::new();
    inc.note_full(before, &plan, Objective::Makespan, None);
    if !inc.wants_delta(after, Objective::Makespan, false, None) {
        return Err(format!(
            "heuristic declined a {}->{} job event", before.len(),
            after.len()));
    }
    // a failure cause must always route through the full path
    if inc.wants_delta(after, Objective::Makespan, true, None) {
        return Err("heuristic accepted a failure-cause event".into());
    }
    let delta = inc.solve_delta(after, profiles, cluster, 1.0, None,
                                Objective::Makespan, &[], &Tracer::off(),
                                None, SolveBudget::default());
    if delta.is_none() {
        return Err("delta re-solve failed on a plain event".into());
    }
    let (seeded, _) = inc
        .parity_probe(after, profiles, cluster)
        .ok_or("seeded parity probe failed")?;
    let (scratch, _) =
        plan_selection_probe(after, profiles, cluster, MilpEngine::Revised)
            .ok_or("from-scratch probe failed")?;
    let rel = (seeded - scratch).abs() / scratch.abs().max(1.0);
    if rel > 1e-6 {
        return Err(format!(
            "seeded probe {seeded} vs scratch {scratch}: rel {rel}"));
    }
    Ok(())
}

/// Random event mixes: (n jobs total, departures k, arrivals a, nodes),
/// constrained so the churn heuristic accepts (4 * (k + a) <= before).
struct RandomEvent;

impl Strategy for RandomEvent {
    type Value = (i64, i64, i64, i64);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.range(9, 15); // before-roster size
        let k = rng.range(0, 3); // departures
        let a = rng.range(0, 3); // arrivals
        (n, k, a, rng.range(1, 3))
    }
}

#[test]
fn prop_delta_resolve_matches_full_probe_across_event_mixes() {
    forall(57, 8, &RandomEvent, |&(n, k, a, nodes)| {
        let (n, k, a) = (n as usize, k as usize, a as usize);
        if 4 * (k + a) > n {
            return Ok(()); // churn above the heuristic's budget
        }
        let cluster = ClusterSpec::p4d(nodes as u32);
        let (roster, profiles) = profile_n(n + a, &cluster);
        // before: the first n jobs; after: k of them departed plus the
        // a new arrivals appended at the end of the id space
        let before = &roster[..n];
        let after: Vec<(usize, u64)> = roster[k..].to_vec();
        check_delta_parity(before, &after, &profiles, &cluster,
                           SolverMode::Joint)
    });
}

#[test]
fn delta_parity_holds_in_the_sharded_regime() {
    // 72 jobs sits above DELTA_UNSHARDED_MAX (64): the delta path runs
    // the 4-thread sharded cells instead of the single colgen master
    let cluster = ClusterSpec::p4d(2);
    let (roster, profiles) = profile_n(72, &cluster);
    let before = &roster[..68];
    let after: Vec<(usize, u64)> = roster[2..].to_vec();
    check_delta_parity(before, &after, &profiles, &cluster,
                       SolverMode::Sharded { cell_size: 64 })
        .expect("sharded-regime delta parity");
}

/// Random plain instances: (n jobs, nodes).
struct RandomInstance;

impl Strategy for RandomInstance {
    type Value = (i64, i64);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (rng.range(2, 12), rng.range(1, 3))
    }
}

#[test]
fn prop_budgeted_solve_is_never_worse_than_greedy() {
    forall(58, 10, &RandomInstance, |&(n, nodes)| {
        let cluster = ClusterSpec::p4d(nodes as u32);
        let (roster, profiles) = profile_n(n as usize, &cluster);
        let (greedy, _) = solve_joint(&roster, &profiles, &cluster,
                                      SolverMode::Heuristic);
        // the tightest possible node budget: the anytime floor must
        // still return at least the greedy incumbent
        let budget = SolveBudget { deadline_ms: None, node_budget: Some(1) };
        let (capped, _) = solve_joint_budgeted(
            &roster, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::Makespan, &[], &Tracer::off(), None, budget);
        let bound = greedy.predicted_makespan_s;
        if capped.predicted_makespan_s > bound * (1.0 + 1e-9) {
            return Err(format!(
                "budgeted makespan {} above greedy floor {bound}",
                capped.predicted_makespan_s));
        }
        Ok(())
    });
}

/// Random streaming scenarios: (seed, multijobs, incremental flag).
struct RandomStream;

impl Strategy for RandomStream {
    type Value = (i64, i64, i64);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (rng.range(0, 1000), rng.range(2, 4), rng.range(0, 2))
    }
}

fn stream_trace(seed: i64, multijobs: i64, stagger_s: f64)
    -> saturn::workload::Trace {
    generate_trace(&TraceConfig {
        seed: seed as u64,
        multijobs: multijobs as usize,
        process: ArrivalProcess::Burst { rate_per_hour: 1.5, burst_size: 2 },
        grid_lrs: 2,
        grid_batches: 1,
        epochs: 1,
        tenants: 2,
        deadline_slack_s: None,
        burst_stagger_s: stagger_s,
    })
}

#[test]
fn prop_incremental_runs_conserve_jobs_and_replay_deterministically() {
    forall(59, 5, &RandomStream, |&(seed, mj, inc_flag)| {
        let trace = stream_trace(seed, mj, 0.0);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        let knobs = OnlineKnobs {
            incremental: inc_flag == 1,
            ..OnlineKnobs::default()
        };
        let run = || {
            let mut perf = PerfModel::exact(&profiles);
            run_trace_knobs(&trace, Some(&rungs), &mut perf, &cluster,
                            "online-saturn", SolverMode::Joint, None,
                            &SimConfig::default(), knobs)
        };
        let (a, am) = run();
        let (b, _) = run();
        if am.completed + am.early_stopped != trace.jobs.len() {
            return Err("job conservation violated".into());
        }
        if a.peak_gpus > cluster.total_gpus() {
            return Err(format!("peak {} > fleet", a.peak_gpus));
        }
        if a.finish_times != b.finish_times || a.jct_s != b.jct_s
            || a.launches != b.launches {
            return Err("incremental replay diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_knobs_off_is_bit_identical_to_plain_replay() {
    forall(60, 5, &RandomStream, |&(seed, mj, _)| {
        let trace = stream_trace(seed, mj, 0.0);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        let (plain, _) = run_trace(&trace, Some(&rungs), &profiles,
                                   &cluster, "online-saturn",
                                   SolverMode::Joint);
        let mut perf = PerfModel::exact(&profiles);
        let (off, _) = run_trace_knobs(&trace, Some(&rungs), &mut perf,
                                       &cluster, "online-saturn",
                                       SolverMode::Joint, None,
                                       &SimConfig::default(),
                                       OnlineKnobs::default());
        if plain.finish_times != off.finish_times
            || plain.jct_s != off.jct_s
            || plain.launches != off.launches
            || off.coalesced_events != 0 {
            return Err("knobs-off replay differs from plain replay".into());
        }
        Ok(())
    });
}

#[test]
fn staggered_burst_under_a_window_coalesces_without_losing_jobs() {
    let trace = stream_trace(7, 4, 2.0);
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();
    let mut perf = PerfModel::exact(&profiles);
    let cfg = SimConfig { coalesce_window_s: 30.0, ..SimConfig::default() };
    let knobs = OnlineKnobs { incremental: true, ..OnlineKnobs::default() };
    let (r, m) = run_trace_knobs(&trace, Some(&rungs), &mut perf, &cluster,
                                 "online-saturn", SolverMode::Joint, None,
                                 &cfg, knobs);
    assert!(r.coalesced_events > 0,
            "staggered siblings 2 s apart must fold under a 30 s window");
    assert_eq!(m.coalesced_events, r.coalesced_events);
    assert_eq!(m.completed + m.early_stopped, trace.jobs.len());
    assert!(r.peak_gpus <= cluster.total_gpus());
}

#[test]
fn budget_capped_online_run_still_completes_every_job() {
    let trace = stream_trace(11, 3, 0.0);
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();
    let mut perf = PerfModel::exact(&profiles);
    let knobs = OnlineKnobs {
        incremental: true,
        resolve_budget_ms: None, // wall budgets are timing-dependent
        node_budget: Some(1),
    };
    let (_, m) = run_trace_knobs(&trace, Some(&rungs), &mut perf, &cluster,
                                 "online-saturn", SolverMode::Joint, None,
                                 &SimConfig::default(), knobs);
    assert_eq!(m.completed + m.early_stopped, trace.jobs.len(),
               "a node-budget cap must degrade quality, not liveness");
}
