//! Property-based invariants for the fault-injection layer (DESIGN.md
//! §4.7), via the in-repo `util::prop` framework:
//!
//!  * **Strict generalization** — a faults-disabled run through the
//!    fault-aware engine is bit-identical to the plain path for every
//!    online system, with all fault counters at zero and goodput equal
//!    to utilization bit for bit;
//!  * **Progress conservation** — crash kills never lose banked
//!    checkpoints: continuous checkpointing (interval 0) loses zero
//!    work, and every job still departs under any crash hazard;
//!  * **Capacity** — the pre-drawn outage windows are ascending,
//!    disjoint, and finite, and a faulted run never grants more GPUs
//!    than the fleet owns;
//!  * **Determinism** — a faulted run replays bit-identically, traced
//!    (deterministic journal) or untraced;
//!  * **Attribution** — every node-death instant in the journal pairs
//!    with a same-instant `sched/plan` span whose cause is `failure`,
//!    and the policy's `solver/resolve` spans carry the cause too.

use saturn::cluster::ClusterSpec;
use saturn::faults::{FaultConfig, FaultModel};
use saturn::obs::trace::{EventPhase, Tracer};
use saturn::online::{profile_trace, run_trace, run_trace_faults,
                     run_trace_sim};
use saturn::perf::PerfModel;
use saturn::saturn::solver::SolverMode;
use saturn::sim::engine::{RungConfig, SimConfig};
use saturn::util::json::Json;
use saturn::util::prop::{forall, IntRange};
use saturn::workload::{generate_trace, TraceConfig};

fn trace_of_seed(seed: u64) -> saturn::workload::Trace {
    generate_trace(&TraceConfig {
        seed,
        multijobs: 3,
        ..Default::default()
    })
}

/// A crash-hazard-only fault layer: no node deaths, so it runs on any
/// fleet and isolates the checkpoint/rollback arithmetic.
fn crash_cfg(seed: u64) -> FaultConfig {
    FaultConfig {
        seed,
        crash_per_hour: 3.0,
        ..FaultConfig::none()
    }
}

// ---------------------------------------------------------------------------
// strict generalization: faults off == the plain path, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn prop_zero_fault_run_is_bit_identical_for_every_system() {
    forall(201, 6, &IntRange(0, 1000), |&seed| {
        let trace = trace_of_seed(seed as u64);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let rungs = RungConfig::halving();
        // a non-default checkpoint interval must be inert without faults
        let cfg = SimConfig {
            faults: FaultConfig::none(),
            checkpoint_interval_s: 123.0,
            ..SimConfig::default()
        };
        for sys in ["online-current-practice", "online-optimus",
                    "online-saturn"] {
            let (a, ma) = run_trace(&trace, Some(&rungs), &profiles,
                                    &cluster, sys, SolverMode::Joint);
            let mut perf = PerfModel::exact(&profiles);
            let (b, mb) = run_trace_sim(&trace, Some(&rungs), &mut perf,
                                        &cluster, sys, SolverMode::Joint,
                                        None, &cfg);
            if a.finish_times != b.finish_times || a.jct_s != b.jct_s {
                return Err(format!("{sys}: schedules diverged"));
            }
            if a.early_stopped != b.early_stopped
                || a.launches != b.launches
            {
                return Err(format!("{sys}: departures diverged"));
            }
            if ma.makespan_s.to_bits() != mb.makespan_s.to_bits() {
                return Err(format!("{sys}: makespan bits diverged"));
            }
            if mb.failures != 0 || mb.fault_preemptions != 0
                || mb.lost_work_gpu_s != 0.0
            {
                return Err(format!("{sys}: phantom fault metrics"));
            }
            if mb.goodput.to_bits() != mb.gpu_utilization.to_bits() {
                return Err(format!(
                    "{sys}: goodput != utilization without faults"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// progress conservation under crash kills
// ---------------------------------------------------------------------------

#[test]
fn prop_continuous_checkpointing_loses_no_work() {
    forall(202, 6, &IntRange(0, 1000), |&seed| {
        let trace = trace_of_seed(7);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let cfg = SimConfig {
            faults: crash_cfg(seed as u64),
            checkpoint_interval_s: 0.0, // continuous: nothing is lost
            ..SimConfig::default()
        };
        let mut perf = PerfModel::exact(&profiles);
        let (r, m) = run_trace_faults(&trace, None, &mut perf, &cluster,
                                      SolverMode::Joint, &cfg, true);
        if r.finish_times.len() != trace.jobs.len() {
            return Err("a crashed job never departed".into());
        }
        if m.lost_work_gpu_s != 0.0 {
            return Err(format!(
                "continuous checkpointing lost {} GPU-s",
                m.lost_work_gpu_s));
        }
        if m.goodput.to_bits() != m.gpu_utilization.to_bits() {
            return Err("zero lost work but goodput != utilization".into());
        }
        Ok(())
    });
}

#[test]
fn prop_every_job_departs_under_crash_hazards() {
    forall(203, 6, &IntRange(0, 1000), |&seed| {
        let trace = trace_of_seed(seed as u64);
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&trace, &cluster);
        let cfg = SimConfig {
            faults: crash_cfg(seed as u64 + 1),
            checkpoint_interval_s: 600.0,
            ..SimConfig::default()
        };
        let mut perf = PerfModel::exact(&profiles);
        let (r, m) = run_trace_faults(&trace, None, &mut perf, &cluster,
                                      SolverMode::Joint, &cfg, true);
        if r.finish_times.len() != trace.jobs.len() {
            return Err("a crashed job never departed".into());
        }
        if m.completed + m.early_stopped != trace.jobs.len() {
            return Err("departure accounting split a job".into());
        }
        if m.lost_work_gpu_s < 0.0 {
            return Err("negative lost work".into());
        }
        if m.goodput > m.gpu_utilization + 1e-12 {
            return Err(format!("goodput {} above utilization {}",
                               m.goodput, m.gpu_utilization));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// capacity: sane outage windows, never over-granting a degraded fleet
// ---------------------------------------------------------------------------

#[test]
fn prop_outage_windows_are_ascending_disjoint_and_finite() {
    let cluster = ClusterSpec::p4d(2);
    forall(204, 20, &IntRange(0, 10_000), |&seed| {
        let fm = FaultModel::new(FaultConfig::uniform(seed as u64, 2.0),
                                 &cluster);
        for ci in 0..cluster.n_classes() {
            for ni in 0..cluster.class(ci).nodes as usize {
                let mut prev_end = f64::NEG_INFINITY;
                for &(a, b) in fm.outages(ci, ni) {
                    if !(a.is_finite() && b.is_finite()) {
                        return Err("non-finite outage window".into());
                    }
                    if b <= a {
                        return Err(format!("empty window ({a}, {b})"));
                    }
                    if a < prev_end {
                        return Err("overlapping outage windows".into());
                    }
                    // node_down must agree with the window itself
                    if !fm.node_down(ci, ni, (a + b) / 2.0)
                        || fm.node_down(ci, ni, a - 1.0)
                    {
                        return Err("node_down disagrees with \
                                    windows".into());
                    }
                    prev_end = b;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_faulted_runs_never_exceed_fleet_capacity() {
    let cluster = ClusterSpec::p4d(2);
    forall(205, 4, &IntRange(0, 1000), |&seed| {
        let trace = trace_of_seed(seed as u64);
        let profiles = profile_trace(&trace, &cluster);
        let cfg = SimConfig {
            faults: FaultConfig::uniform(seed as u64, 1.0),
            checkpoint_interval_s: 900.0,
            ..SimConfig::default()
        };
        let mut perf = PerfModel::exact(&profiles);
        let (r, _) = run_trace_faults(&trace, None, &mut perf, &cluster,
                                      SolverMode::Joint, &cfg, true);
        if r.peak_gpus > cluster.total_gpus() {
            return Err(format!("granted {} GPUs on a {}-GPU fleet",
                               r.peak_gpus, cluster.total_gpus()));
        }
        if r.finish_times.len() != trace.jobs.len() {
            return Err("a job never departed across fail/repair".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// determinism: faulted replays are bit-identical, traced or untraced
// ---------------------------------------------------------------------------

#[test]
fn prop_faulted_replays_are_bit_identical_traced_or_not() {
    let cluster = ClusterSpec::p4d(2);
    forall(206, 4, &IntRange(0, 1000), |&seed| {
        let trace = trace_of_seed(seed as u64);
        let profiles = profile_trace(&trace, &cluster);
        let run = |tracer: Tracer| {
            let cfg = SimConfig {
                faults: FaultConfig::uniform(seed as u64, 2.0),
                checkpoint_interval_s: 900.0,
                trace: tracer,
                ..SimConfig::default()
            };
            let mut perf = PerfModel::exact(&profiles);
            run_trace_faults(&trace, None, &mut perf, &cluster,
                             SolverMode::Joint, &cfg, true)
                .0
        };
        let a = run(Tracer::off());
        let b = run(Tracer::off());
        let c = run(Tracer::deterministic());
        for (other, label) in [(&b, "replay"), (&c, "traced")] {
            if a.finish_times != other.finish_times
                || a.jct_s != other.jct_s
                || a.launches != other.launches
                || a.fault_preemptions != other.fault_preemptions
                || a.makespan_s.to_bits() != other.makespan_s.to_bits()
            {
                return Err(format!("{label} run diverged"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// attribution: node deaths pair with failure-cause replans in the trace
// ---------------------------------------------------------------------------

#[test]
fn fault_trace_pairs_node_deaths_with_failure_replans() {
    let trace = trace_of_seed(42);
    let cluster = ClusterSpec::p4d(2);
    let profiles = profile_trace(&trace, &cluster);
    let tracer = Tracer::deterministic();
    let cfg = SimConfig {
        faults: FaultConfig::uniform(7, 1.0),
        checkpoint_interval_s: 900.0,
        trace: tracer.clone(),
        ..SimConfig::default()
    };
    let mut perf = PerfModel::exact(&profiles);
    let (r, _) = run_trace_faults(&trace, None, &mut perf, &cluster,
                                  SolverMode::Joint, &cfg, true);
    assert!(r.failures > 0, "no node ever died at MTBF 1 h");
    let events = tracer.events();
    let cause_of = |args: &Json| {
        args.get("cause")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string()
    };
    let deaths: Vec<f64> = events
        .iter()
        .filter(|e| e.cat == "fault" && e.name == "node_down")
        .map(|e| e.t_s)
        .collect();
    assert!(!deaths.is_empty(), "failures counted but never journaled");
    // every node death replans at the same instant, attributed to the
    // failure cause (failure outranks every other cause at a tie)
    for t in &deaths {
        let paired = events.iter().any(|e| {
            e.cat == "sched"
                && e.name == "plan"
                && e.phase == EventPhase::Begin
                && (e.t_s - t).abs() < 1e-9
                && cause_of(&e.args) == "failure"
        });
        assert!(paired, "node death at t={t} has no failure-cause plan");
    }
    // the policy's re-solve spans carry the cause too
    assert!(events.iter().any(|e| {
        e.cat == "solver"
            && e.name == "resolve"
            && e.phase == EventPhase::Begin
            && cause_of(&e.args) == "failure"
    }), "no solver resolve span attributed to a failure");
}
