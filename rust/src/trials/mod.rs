//! The Trial Runner (paper §2): profiles every (job, technique, GPU count,
//! GPU class) combination and materializes the estimates the Solver
//! consumes.
//!
//! Two modes:
//!  * **Analytic** — `Parallelism::search` cost models against each GPU
//!    class's view of the cluster spec (the Table 2 simulation path; GPUs
//!    don't exist on this testbed). On heterogeneous fleets every class is
//!    profiled separately, because memory feasibility and step times are
//!    hardware-dependent (Hydra's lesson: plan choice follows the GPU).
//!  * **Empirical** — measured PJRT-CPU step times of the AOT GPT-mini
//!    artifacts, scaled by the cost models' parallel efficiency. Used by
//!    `examples/e2e_train.rs` so the full profile->solve->train loop runs
//!    against real compiled executables, exactly like the paper's
//!    "one or two mini-batches" probe runs.

use std::collections::HashMap;

use crate::cluster::ClusterSpec;
use crate::parallelism::{Library, StepEstimate};
use crate::workload::Job;

/// Profiling results for a multi-job:
/// `(job, tech, gpus, class) -> StepEstimate`.
#[derive(Debug, Clone, Default)]
pub struct ProfileTable {
    /// Keyed by (job_id, tech_idx, gpus, class_idx).
    entries: HashMap<(usize, usize, u32, usize), StepEstimate>,
    /// Allocation options per GPU class (class index -> sorted GPU counts).
    pub class_gpu_options: Vec<Vec<u32>>,
    pub n_techniques: usize,
    /// Seconds of (simulated) profiling work performed — the paper claims
    /// this is negligible; bench E7 checks that claim.
    pub profiling_cost_s: f64,
}

impl ProfileTable {
    pub fn new(class_gpu_options: Vec<Vec<u32>>, n_techniques: usize) -> Self {
        ProfileTable { class_gpu_options, n_techniques, ..Default::default() }
    }

    pub fn n_classes(&self) -> usize {
        self.class_gpu_options.len()
    }

    pub fn get(&self, job: usize, tech: usize, gpus: u32, class: usize)
        -> Option<&StepEstimate> {
        self.entries.get(&(job, tech, gpus, class))
    }

    pub fn step_time(&self, job: usize, tech: usize, gpus: u32, class: usize)
        -> Option<f64> {
        self.get(job, tech, gpus, class).map(|e| e.step_time_s)
    }

    /// Fastest feasible (tech, step_time) at a given GPU count on a class.
    pub fn best_at(&self, job: usize, gpus: u32, class: usize)
        -> Option<(usize, f64)> {
        (0..self.n_techniques)
            .filter_map(|t| self.step_time(job, t, gpus, class).map(|s| (t, s)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// All feasible plans for a job ON ONE CLASS as (tech, gpus,
    /// step_time), pruned to the per-GPU-count winner and to strictly
    /// improving runtimes (the per-class Pareto set).
    pub fn pareto_plans(&self, job: usize, class: usize)
        -> Vec<(usize, u32, f64)> {
        let Some(options) = self.class_gpu_options.get(class) else {
            return Vec::new();
        };
        let mut plans = Vec::new();
        for &g in options {
            if let Some((tech, t)) = self.best_at(job, g, class) {
                plans.push((tech, g, t));
            }
        }
        // drop dominated entries (more GPUs but not faster)
        let mut pruned: Vec<(usize, u32, f64)> = Vec::new();
        for p in plans {
            if pruned.iter().all(|q| p.2 < q.2) {
                pruned.push(p);
            }
        }
        pruned
    }

    /// The solver's search space: the union of every class's Pareto set,
    /// tagged with the class index, as (tech, gpus, class, step_time)
    /// sorted by step time descending (slowest/cheapest first — the ladder
    /// the greedy allocator climbs). On a single-class fleet this is
    /// exactly the homogeneous Pareto set with class 0.
    pub fn candidate_plans(&self, job: usize) -> Vec<(usize, u32, usize, f64)> {
        let mut all: Vec<(usize, u32, usize, f64)> = Vec::new();
        for ci in 0..self.n_classes() {
            for (tech, g, t) in self.pareto_plans(job, ci) {
                all.push((tech, g, ci, t));
            }
        }
        all.sort_by(|a, b| {
            b.3.partial_cmp(&a.3)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.2.cmp(&b.2))
                .then(a.1.cmp(&b.1))
        });
        all
    }

    /// Whether the job has at least one feasible plan on ANY class.
    pub fn feasible_anywhere(&self, job: usize) -> bool {
        (0..self.n_classes()).any(|ci| !self.pareto_plans(job, ci).is_empty())
    }

    pub fn insert(&mut self, job: usize, tech: usize, gpus: u32, class: usize,
                  e: StepEstimate) {
        self.entries.insert((job, tech, gpus, class), e);
    }

    /// Iterate every profiled cell as `(&(job, tech, gpus, class),
    /// &StepEstimate)` (arbitrary order; the perf layer's hooks).
    pub fn cells(
        &self,
    ) -> impl Iterator<Item = (&(usize, usize, u32, usize), &StepEstimate)>
           + '_ {
        self.entries.iter()
    }

    /// Clone the table with every cell's step time transformed by
    /// `f(job, tech, gpus, class, step_time)` — how the estimate layer
    /// materializes correction factors and the truth model freezes a
    /// drifted snapshot. Memory/MFU diagnostics are left untouched.
    pub fn with_scaled_step_times<F>(&self, mut f: F) -> ProfileTable
    where
        F: FnMut(usize, usize, u32, usize, f64) -> f64,
    {
        let mut t = self.clone();
        for (k, e) in t.entries.iter_mut() {
            e.step_time_s = f(k.0, k.1, k.2, k.3, e.step_time_s);
        }
        t
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Number of mini-batches timed per probe (paper: "one or two").
pub const PROBE_STEPS: f64 = 2.0;

/// Profile a multi-job analytically against the cost models, one GPU class
/// at a time (each class's single-class view carries its own GpuSpec and
/// bandwidths into the cost models).
pub fn profile_analytic(jobs: &[Job], library: &Library,
                        cluster: &ClusterSpec) -> ProfileTable {
    let class_gpu_options: Vec<Vec<u32>> = (0..cluster.n_classes())
        .map(|ci| cluster.class_allocation_options(ci))
        .collect();
    let mut table = ProfileTable {
        class_gpu_options,
        n_techniques: library.len(),
        ..Default::default()
    };
    for ci in 0..cluster.n_classes() {
        let view = cluster.class_view(ci);
        let options = table.class_gpu_options[ci].clone();
        for job in jobs {
            for (ti, tech) in library.iter() {
                for &g in &options {
                    if let Some(est) =
                        tech.search(&job.model, &view, g, job.batch)
                    {
                        // the real system would time PROBE_STEPS mini-batches
                        table.profiling_cost_s += PROBE_STEPS * est.step_time_s;
                        table.insert(job.id, ti, g, ci, est);
                    }
                }
            }
        }
    }
    table
}

/// Empirical profiling: caller supplies measured base step times (seconds
/// at 1 "GPU" lane) per job — e.g. from `runtime::Trainer::time_step` — and
/// the cost models supply the parallel-efficiency scaling.
pub fn profile_empirical(jobs: &[Job], library: &Library,
                         cluster: &ClusterSpec,
                         measured_1gpu: &HashMap<usize, f64>) -> ProfileTable {
    let mut table = profile_analytic(jobs, library, cluster);
    for job in jobs {
        let Some(&measured) = measured_1gpu.get(&job.id) else { continue };
        // Rescale every feasible estimate so that the technique-agnostic
        // compute core matches the measurement while preserving each
        // technique's relative efficiency profile.
        let base = table
            .best_at(job.id, 1, 0)
            .map(|(_, t)| t)
            .unwrap_or(measured);
        let scale = measured / base.max(1e-12);
        for ci in 0..table.n_classes() {
            for ti in 0..table.n_techniques {
                for &g in &table.class_gpu_options[ci].clone() {
                    if let Some(e) = table.entries.get_mut(&(job.id, ti, g, ci))
                    {
                        e.step_time_s *= scale;
                    }
                }
            }
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallelism::default_library;
    use crate::workload::wikitext_workload;

    fn setup() -> (Vec<Job>, Library, ClusterSpec) {
        (wikitext_workload(), default_library(), ClusterSpec::p4d(1))
    }

    #[test]
    fn profiles_cover_feasible_grid() {
        let (jobs, lib, cluster) = setup();
        let t = profile_analytic(&jobs, &lib, &cluster);
        assert!(!t.is_empty());
        assert_eq!(t.n_classes(), 1);
        // every job must have at least one feasible plan (offload backstop)
        for j in &jobs {
            assert!(!t.pareto_plans(j.id, 0).is_empty(),
                    "job {} has no plan", j.name);
            assert!(t.feasible_anywhere(j.id));
        }
    }

    #[test]
    fn gptj_cannot_use_ddp() {
        let (jobs, lib, cluster) = setup();
        let t = profile_analytic(&jobs, &lib, &cluster);
        let gptj = jobs.iter().find(|j| j.model.name == "GPT-J").unwrap();
        let (ddp_idx, _) = lib.by_name("ddp").unwrap();
        for &g in &t.class_gpu_options[0] {
            assert!(t.step_time(gptj.id, ddp_idx, g, 0).is_none());
        }
    }

    #[test]
    fn pareto_plans_strictly_improve() {
        let (jobs, lib, cluster) = setup();
        let t = profile_analytic(&jobs, &lib, &cluster);
        for j in &jobs {
            let plans = t.pareto_plans(j.id, 0);
            for w in plans.windows(2) {
                assert!(w[1].1 > w[0].1, "gpus increase");
                assert!(w[1].2 < w[0].2, "runtime decreases");
            }
        }
    }

    #[test]
    fn profiling_cost_accumulates() {
        let (jobs, lib, cluster) = setup();
        let t = profile_analytic(&jobs, &lib, &cluster);
        assert!(t.profiling_cost_s > 0.0);
    }

    #[test]
    fn hetero_fleet_profiles_every_class() {
        let (jobs, lib, _) = setup();
        let cluster = ClusterSpec::hetero(1, 1);
        let t = profile_analytic(&jobs, &lib, &cluster);
        assert_eq!(t.n_classes(), 2);
        for j in &jobs {
            // the H100 class (bigger memory) admits at least as many
            // Pareto points as the A100 class admits
            let a = t.pareto_plans(j.id, 0);
            let h = t.pareto_plans(j.id, 1);
            assert!(!h.is_empty(), "job {} has no H100 plan", j.name);
            // candidates carry both classes, sorted by runtime descending
            let cands = t.candidate_plans(j.id);
            assert_eq!(cands.len(), a.len() + h.len());
            for w in cands.windows(2) {
                assert!(w[1].3 <= w[0].3 + 1e-12, "ladder not sorted");
            }
            assert!(cands.iter().any(|c| c.2 == 1));
        }
    }

    #[test]
    fn h100_step_times_beat_a100_at_same_point() {
        let (jobs, lib, _) = setup();
        let cluster = ClusterSpec::hetero(1, 1);
        let t = profile_analytic(&jobs, &lib, &cluster);
        let mut compared = 0;
        for j in &jobs {
            for ti in 0..t.n_techniques {
                for &g in &t.class_gpu_options[0] {
                    if let (Some(a), Some(h)) =
                        (t.step_time(j.id, ti, g, 0), t.step_time(j.id, ti, g, 1))
                    {
                        assert!(h < a,
                                "H100 {h} !< A100 {a} (job {} tech {ti} g{g})",
                                j.name);
                        compared += 1;
                    }
                }
            }
        }
        assert!(compared > 0, "no overlapping feasible points");
    }

    #[test]
    fn empirical_rescaling_applies() {
        let (jobs, lib, cluster) = setup();
        let mut measured = HashMap::new();
        measured.insert(0usize, 123.0);
        let base = profile_analytic(&jobs, &lib, &cluster);
        let emp = profile_empirical(&jobs, &lib, &cluster, &measured);
        let (t0, _) = base.best_at(0, 1, 0).unwrap();
        let before = base.step_time(0, t0, 1, 0).unwrap();
        let after = emp.step_time(0, t0, 1, 0).unwrap();
        assert!((after - 123.0).abs() < 1e-6, "{after} vs 123");
        assert!((before - 123.0).abs() > 1.0, "{before} was already 123?");
        // untouched job unchanged
        assert_eq!(base.step_time(1, t0, 1, 0), emp.step_time(1, t0, 1, 0));
    }
}
