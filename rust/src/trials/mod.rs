//! The Trial Runner (paper §2): profiles every (job, technique, GPU count)
//! combination and materializes the estimates the Solver consumes.
//!
//! Two modes:
//!  * **Analytic** — `Parallelism::search` cost models against the cluster
//!    spec (the Table 2 simulation path; GPUs don't exist on this testbed).
//!  * **Empirical** — measured PJRT-CPU step times of the AOT GPT-mini
//!    artifacts, scaled by the cost models' parallel efficiency. Used by
//!    `examples/e2e_train.rs` so the full profile->solve->train loop runs
//!    against real compiled executables, exactly like the paper's
//!    "one or two mini-batches" probe runs.

use std::collections::HashMap;

use crate::cluster::ClusterSpec;
use crate::parallelism::{Library, StepEstimate};
use crate::workload::Job;

/// Profiling results for a multi-job: `(job, tech, gpus) -> StepEstimate`.
#[derive(Debug, Clone, Default)]
pub struct ProfileTable {
    /// Keyed by (job_id, tech_idx, gpus).
    entries: HashMap<(usize, usize, u32), StepEstimate>,
    pub gpu_options: Vec<u32>,
    pub n_techniques: usize,
    /// Seconds of (simulated) profiling work performed — the paper claims
    /// this is negligible; bench E7 checks that claim.
    pub profiling_cost_s: f64,
}

impl ProfileTable {
    pub fn new(gpu_options: Vec<u32>, n_techniques: usize) -> Self {
        ProfileTable { gpu_options, n_techniques, ..Default::default() }
    }

    pub fn get(&self, job: usize, tech: usize, gpus: u32) -> Option<&StepEstimate> {
        self.entries.get(&(job, tech, gpus))
    }

    pub fn step_time(&self, job: usize, tech: usize, gpus: u32) -> Option<f64> {
        self.get(job, tech, gpus).map(|e| e.step_time_s)
    }

    /// Fastest feasible (tech, step_time) at a given GPU count.
    pub fn best_at(&self, job: usize, gpus: u32) -> Option<(usize, f64)> {
        (0..self.n_techniques)
            .filter_map(|t| self.step_time(job, t, gpus).map(|s| (t, s)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// All feasible plans for a job as (tech, gpus, step_time), pruned to
    /// the per-GPU-count winner (the Pareto set the solver searches).
    pub fn pareto_plans(&self, job: usize) -> Vec<(usize, u32, f64)> {
        let mut plans = Vec::new();
        for &g in &self.gpu_options {
            if let Some((tech, t)) = self.best_at(job, g) {
                plans.push((tech, g, t));
            }
        }
        // drop dominated entries (more GPUs but not faster)
        let mut pruned: Vec<(usize, u32, f64)> = Vec::new();
        for p in plans {
            if pruned.iter().all(|q| p.2 < q.2) {
                pruned.push(p);
            }
        }
        pruned
    }

    pub fn insert(&mut self, job: usize, tech: usize, gpus: u32, e: StepEstimate) {
        self.entries.insert((job, tech, gpus), e);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Number of mini-batches timed per probe (paper: "one or two").
pub const PROBE_STEPS: f64 = 2.0;

/// Profile a multi-job analytically against the cost models.
pub fn profile_analytic(jobs: &[Job], library: &Library,
                        cluster: &ClusterSpec) -> ProfileTable {
    let mut table = ProfileTable {
        gpu_options: cluster.allocation_options(),
        n_techniques: library.len(),
        ..Default::default()
    };
    for job in jobs {
        for (ti, tech) in library.iter() {
            for &g in &table.gpu_options.clone() {
                if let Some(est) = tech.search(&job.model, cluster, g, job.batch) {
                    // the real system would time PROBE_STEPS mini-batches
                    table.profiling_cost_s += PROBE_STEPS * est.step_time_s;
                    table.insert(job.id, ti, g, est);
                }
            }
        }
    }
    table
}

/// Empirical profiling: caller supplies measured base step times (seconds
/// at 1 "GPU" lane) per job — e.g. from `runtime::Trainer::time_step` — and
/// the cost models supply the parallel-efficiency scaling.
pub fn profile_empirical(jobs: &[Job], library: &Library,
                         cluster: &ClusterSpec,
                         measured_1gpu: &HashMap<usize, f64>) -> ProfileTable {
    let mut table = profile_analytic(jobs, library, cluster);
    for job in jobs {
        let Some(&measured) = measured_1gpu.get(&job.id) else { continue };
        // Rescale every feasible estimate so that the technique-agnostic
        // compute core matches the measurement while preserving each
        // technique's relative efficiency profile.
        let base = table
            .best_at(job.id, 1)
            .map(|(_, t)| t)
            .unwrap_or(measured);
        let scale = measured / base.max(1e-12);
        for ti in 0..table.n_techniques {
            for &g in &table.gpu_options.clone() {
                if let Some(e) = table.entries.get_mut(&(job.id, ti, g)) {
                    e.step_time_s *= scale;
                }
            }
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallelism::default_library;
    use crate::workload::wikitext_workload;

    fn setup() -> (Vec<Job>, Library, ClusterSpec) {
        (wikitext_workload(), default_library(), ClusterSpec::p4d(1))
    }

    #[test]
    fn profiles_cover_feasible_grid() {
        let (jobs, lib, cluster) = setup();
        let t = profile_analytic(&jobs, &lib, &cluster);
        assert!(!t.is_empty());
        // every job must have at least one feasible plan (offload backstop)
        for j in &jobs {
            assert!(!t.pareto_plans(j.id).is_empty(), "job {} has no plan", j.name);
        }
    }

    #[test]
    fn gptj_cannot_use_ddp() {
        let (jobs, lib, cluster) = setup();
        let t = profile_analytic(&jobs, &lib, &cluster);
        let gptj = jobs.iter().find(|j| j.model.name == "GPT-J").unwrap();
        let (ddp_idx, _) = lib.by_name("ddp").unwrap();
        for &g in &t.gpu_options {
            assert!(t.step_time(gptj.id, ddp_idx, g).is_none());
        }
    }

    #[test]
    fn pareto_plans_strictly_improve() {
        let (jobs, lib, cluster) = setup();
        let t = profile_analytic(&jobs, &lib, &cluster);
        for j in &jobs {
            let plans = t.pareto_plans(j.id);
            for w in plans.windows(2) {
                assert!(w[1].1 > w[0].1, "gpus increase");
                assert!(w[1].2 < w[0].2, "runtime decreases");
            }
        }
    }

    #[test]
    fn profiling_cost_accumulates() {
        let (jobs, lib, cluster) = setup();
        let t = profile_analytic(&jobs, &lib, &cluster);
        assert!(t.profiling_cost_s > 0.0);
    }

    #[test]
    fn empirical_rescaling_applies() {
        let (jobs, lib, cluster) = setup();
        let mut measured = HashMap::new();
        measured.insert(0usize, 123.0);
        let base = profile_analytic(&jobs, &lib, &cluster);
        let emp = profile_empirical(&jobs, &lib, &cluster, &measured);
        let (t0, _) = base.best_at(0, 1).unwrap();
        let before = base.step_time(0, t0, 1).unwrap();
        let after = emp.step_time(0, t0, 1).unwrap();
        assert!((after - 123.0).abs() < 1e-6, "{after} vs 123");
        assert!((before - 123.0).abs() > 1.0, "{before} was already 123?");
        // untouched job unchanged
        assert_eq!(base.step_time(1, t0, 1), emp.step_time(1, t0, 1));
    }
}
