//! Plan data model: the Solver's output consumed by the execution engine.

/// Chosen execution plan for one job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobPlan {
    pub job_id: usize,
    pub tech: usize,
    pub gpus: u32,
    /// GPU class (index into `ClusterSpec::classes`) the job must be
    /// placed on — plans never span classes.
    pub class: usize,
    /// Estimated remaining runtime under this plan (seconds).
    pub runtime_s: f64,
}

/// The Solver's answer for a whole multi-job.
#[derive(Debug, Clone)]
pub struct SaturnPlan {
    /// One plan per unfinished job.
    pub choices: Vec<JobPlan>,
    /// Launch priority (list-scheduling order; earlier = higher priority).
    pub order: Vec<usize>,
    /// Makespan lower bound from the MILP relaxation (diagnostics).
    pub lower_bound_s: f64,
    /// Predicted makespan of the list schedule.
    pub predicted_makespan_s: f64,
}

impl SaturnPlan {
    pub fn plan_for(&self, job_id: usize) -> Option<&JobPlan> {
        self.choices.iter().find(|p| p.job_id == job_id)
    }

    /// Total GPU-seconds of work the plan schedules (area).
    pub fn area(&self) -> f64 {
        self.choices
            .iter()
            .map(|p| p.gpus as f64 * p.runtime_s)
            .sum()
    }

    /// GPU-seconds scheduled on one GPU class (the per-class capacity rows
    /// of the MILP bound `area_in_class(k) <= G_k * M`).
    pub fn area_in_class(&self, class: usize) -> f64 {
        self.choices
            .iter()
            .filter(|p| p.class == class)
            .map(|p| p.gpus as f64 * p.runtime_s)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> SaturnPlan {
        SaturnPlan {
            choices: vec![
                JobPlan { job_id: 0, tech: 1, gpus: 4, class: 0,
                          runtime_s: 100.0 },
                JobPlan { job_id: 2, tech: 0, gpus: 2, class: 1,
                          runtime_s: 50.0 },
            ],
            order: vec![0, 2],
            lower_bound_s: 90.0,
            predicted_makespan_s: 110.0,
        }
    }

    #[test]
    fn lookup_and_area() {
        let p = plan();
        assert_eq!(p.plan_for(2).unwrap().gpus, 2);
        assert!(p.plan_for(1).is_none());
        assert!((p.area() - 500.0).abs() < 1e-12);
        assert!((p.area_in_class(0) - 400.0).abs() < 1e-12);
        assert!((p.area_in_class(1) - 100.0).abs() < 1e-12);
    }
}
