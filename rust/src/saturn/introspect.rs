//! SaturnPolicy: the Solver wired into the execution engine, with the
//! paper's introspection mechanism (re-solve on a fixed interval; the
//! engine checkpoints and re-launches jobs whose allocation changed,
//! adapted from Gandiva/AntMan).

use std::time::Instant;

use crate::objective::JobTerms;
use crate::saturn::plan::{JobPlan, SaturnPlan};
use crate::saturn::solver::{solve_joint_live, SolverMode, SolverStats};
use crate::sim::engine::{Launch, PlanContext, Policy, ReplanCause};
use crate::util::json::Json;

/// Realize launches from a cached plan: pending jobs only, first-fit with
/// backfill against a scratch copy of the free state.
///
/// Ordering is objective-aware (`PlanContext::objective`): under
/// makespan the historical order applies — longest-remaining first,
/// with `by_priority` (the online scheduler) putting tenant priority
/// ahead of runtime — while `tardiness` launches weighted-least-slack
/// first (overdue jobs ahead of everything, WSPT among themselves; see
/// `Objective::urgency_key`) and `wjct` launches by weight-per-second
/// (weighted-shortest-processing-time), both falling back to the
/// historical order on ties.
pub(crate) fn launch_from_plan(plan: &SaturnPlan, ctx: &PlanContext,
                               by_priority: bool) -> Vec<Launch> {
    let mut ordered: Vec<&JobPlan> = plan
        .choices
        .iter()
        .filter(|jp| {
            ctx.jobs
                .get(jp.job_id)
                .map(|s| s.is_pending())
                .unwrap_or(false)
        })
        .collect();
    let historical = |a: &JobPlan, b: &JobPlan| {
        let runtime = b.runtime_s.partial_cmp(&a.runtime_s).unwrap();
        if by_priority {
            let pa = ctx.jobs[a.job_id].priority;
            let pb = ctx.jobs[b.job_id].priority;
            pb.partial_cmp(&pa).unwrap().then(runtime)
        } else {
            runtime
        }
    };
    let urgency = |jp: &JobPlan| {
        let s = &ctx.jobs[jp.job_id];
        ctx.objective.urgency_key(s.priority, jp.runtime_s, s.arrival_s,
                                  s.deadline_s, ctx.now)
    };
    ordered.sort_by(|a, b| match (urgency(a), urgency(b)) {
        (Some(ka), Some(kb)) => ka
            .partial_cmp(&kb)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| historical(a, b)),
        _ => historical(a, b),
    });
    let mut free = ctx.free.clone();
    let mut launches = Vec::new();
    for jp in ordered {
        if free.place(jp.class, jp.gpus).is_some() {
            launches.push(Launch {
                job_id: jp.job_id,
                tech: jp.tech,
                gpus: jp.gpus,
                class: jp.class,
            });
        }
    }
    launches
}

/// Per-job [`JobTerms`] for the objective-aware solver, read off the
/// live simulation state at `ctx.now` (deadlines become due-in-seconds
/// relative to the solve instant). Shared by both Saturn policies.
pub(crate) fn objective_terms(ctx: &PlanContext,
                              remaining: &[(usize, u64)]) -> Vec<JobTerms> {
    remaining
        .iter()
        .filter_map(|&(id, _)| {
            ctx.jobs.get(id).map(|s| JobTerms {
                job_id: id,
                weight: s.priority,
                due_in_s: s.deadline_s.map(|d| s.arrival_s + d - ctx.now),
            })
        })
        .collect()
}

/// Per-class LIVE GPU capacities for the solver's area rows while the
/// fleet is degraded (nodes down), `None` while every node is in
/// service — the healthy-fleet path hands the solver its static
/// capacities and stays bit-identical to the fault-free build. Shared
/// by both Saturn policies.
pub(crate) fn degraded_capacities(ctx: &PlanContext) -> Option<Vec<f64>> {
    let degraded = (0..ctx.free.n_classes()).any(|ci| {
        ctx.free.live_capacity(ci) != ctx.free.class_capacity(ci)
    });
    degraded.then(|| {
        (0..ctx.free.n_classes())
            .map(|ci| ctx.free.live_capacity(ci) as f64)
            .collect()
    })
}

pub struct SaturnPolicy {
    mode: SolverMode,
    /// `None` disables introspection (ablation arm of bench E8).
    pub introspect_every_s: Option<f64>,
    /// Migration hysteresis: a running job is re-allocated only when the
    /// fresh plan improves its remaining runtime by this fraction —
    /// otherwise checkpoint/restart churn eats the gains.
    pub migration_threshold: f64,
    /// Introspection lookahead kappa passed to the solver (>= 1; see
    /// `solve_joint_with`). 1.0 = static plans (default; best on the
    /// Table 2 workloads — larger values under-allocate, bench E8).
    pub lookahead: f64,
    /// Drift-triggered re-solve: when the estimate layer has NEW
    /// observations since the last solve and reports a worst
    /// observed/estimated mismatch beyond this |ln ratio|, re-solve even
    /// though the cached plan still covers every pending job. `None`
    /// disables the trigger. Zero drift never reaches any threshold, so
    /// pre-drift runs are unchanged.
    pub drift_threshold: Option<f64>,
    /// Re-solves fired by the drift trigger alone (not by coverage gaps
    /// or the fixed introspection interval).
    pub drift_resolves: usize,
    /// Failure-aware mode (default): a `ReplanCause::Failure` event
    /// bypasses the plan cache and the re-solve reads the fleet's
    /// DEGRADED per-class capacities. `false` is the failure-blind
    /// ablation arm (`bench_faults`): stale caches, static capacities.
    pub failure_aware: bool,
    last_obs_seen: usize,
    cached: Option<SaturnPlan>,
    last_solve_t: f64,
    decision_s: f64,
    pub last_stats: SolverStats,
    solves: usize,
    /// Accumulated (lp_capped, limit_reached) across every solve.
    pressure: (usize, usize),
}

/// Default |ln(observed/estimated)| beyond which Saturn policies re-plan
/// without waiting for the introspection interval (~10% step-time drift).
pub const DEFAULT_DRIFT_THRESHOLD: f64 = 0.10;

impl SaturnPolicy {
    pub fn new(mode: SolverMode, introspect_every_s: Option<f64>) -> Self {
        SaturnPolicy {
            mode,
            introspect_every_s,
            migration_threshold: 0.15,
            lookahead: 1.0,
            drift_threshold: Some(DEFAULT_DRIFT_THRESHOLD),
            drift_resolves: 0,
            failure_aware: true,
            last_obs_seen: 0,
            cached: None,
            last_solve_t: f64::NEG_INFINITY,
            decision_s: 0.0,
            last_stats: SolverStats::default(),
            solves: 0,
            pressure: (0, 0),
        }
    }

    /// Paper configuration: joint MILP + introspection.
    pub fn paper_default() -> Self {
        // hourly introspection, the granularity Gandiva-style systems use
        Self::new(SolverMode::Joint, Some(3600.0))
    }

    pub fn solves(&self) -> usize {
        self.solves
    }

    /// Launch pending jobs from the cached plan: longest-remaining first,
    /// first-fit with backfill (the list-scheduling realization).
    fn launch_from_cache(&self, ctx: &PlanContext) -> Vec<Launch> {
        let Some(plan) = &self.cached else { return Vec::new() };
        launch_from_plan(plan, ctx, false)
    }
}

/// The drift trigger shared by the batch and online Saturn policies:
/// re-solve when there is NEW evidence since the last solve AND the
/// estimate layer's worst observed/estimated mismatch crossed the
/// threshold. Both conditions matter: without fresh observations the
/// estimate (and thus the plan) cannot have changed, and below the
/// threshold a re-solve only churns checkpoints.
pub fn drift_resolve_due(threshold: Option<f64>, last_obs_seen: usize,
                         obs_seen: usize, drift_alarm: f64) -> bool {
    match threshold {
        Some(th) => obs_seen > last_obs_seen && drift_alarm > th,
        None => false,
    }
}

/// Migration hysteresis shared by the batch and online Saturn policies:
/// keep a previously-running job on its old (tech, gpus, class) unless
/// the fresh plan improves its remaining runtime by more than `threshold`
/// — checkpoint/restart penalties otherwise erode the re-solve gains
/// (Gandiva's lesson). A class move counts as a migration like any other
/// reshape.
pub(crate) fn apply_migration_hysteresis(
    plan: &mut SaturnPlan,
    ctx: &PlanContext,
    remaining: &[(usize, u64)],
    threshold: f64,
) {
    let steps_of = |job_id: usize| {
        remaining.iter().find(|(id, _)| *id == job_id).map(|&(_, s)| s)
    };
    for jp in plan.choices.iter_mut() {
        let Some(s) = ctx.jobs.get(jp.job_id) else { continue };
        let Some(prev) = s.last_alloc else { continue };
        if prev == (jp.tech, jp.gpus, jp.class) {
            continue;
        }
        let Some(steps) = steps_of(jp.job_id) else { continue };
        let Some(prev_step) =
            ctx.profiles.step_time(jp.job_id, prev.0, prev.1, prev.2)
        else {
            continue;
        };
        let prev_runtime = prev_step * steps as f64;
        if jp.runtime_s > prev_runtime * (1.0 - threshold) {
            jp.tech = prev.0;
            jp.gpus = prev.1;
            jp.class = prev.2;
            jp.runtime_s = prev_runtime;
        }
    }
}

impl Policy for SaturnPolicy {
    fn name(&self) -> &'static str {
        "saturn"
    }

    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
        let t0 = Instant::now();
        // Re-solve over unfinished ARRIVED jobs with their *remaining*
        // steps — this is what makes introspection adapt as the workload
        // shifts (in batch mode every job has arrived at t=0).
        let remaining: Vec<(usize, u64)> = ctx
            .jobs
            .iter()
            .filter(|s| s.is_pending())
            .map(|s| (s.job.id, s.remaining_steps()))
            .collect();
        if remaining.is_empty() {
            return Vec::new();
        }

        // Perf: plan reuse (EXPERIMENTS.md §Perf L3 iteration 1). A full
        // MILP solve at every completion event dominated simulation cost;
        // the cached plan already IS the list schedule, so completions
        // just launch the next cached choices. Re-solve only when a
        // pending job is missing from the cache (fresh policy) or the
        // introspection interval elapsed (preempt-and-replan semantics).
        let introspect_due = self
            .introspect_every_s
            .map(|i| ctx.now - self.last_solve_t >= i - 1e-9)
            .unwrap_or(false);
        let drift_due = drift_resolve_due(self.drift_threshold,
                                          self.last_obs_seen, ctx.obs_seen,
                                          ctx.drift_alarm);
        // failure-aware: a fault event invalidates the cached plan (the
        // fleet it was solved against no longer exists)
        let fault_due =
            self.failure_aware && ctx.cause == ReplanCause::Failure;
        // jobs the fleet cannot host at all count as covered: they were
        // shed by the solve and must not force a re-solve at every event
        let cache_covers = self
            .cached
            .as_ref()
            .map(|p| {
                remaining.iter().all(|&(id, _)| {
                    p.plan_for(id).is_some()
                        || !ctx.profiles.feasible_anywhere(id)
                })
            })
            .unwrap_or(false);
        if cache_covers && !introspect_due && !drift_due && !fault_due {
            let launches = self.launch_from_cache(ctx);
            self.decision_s += t0.elapsed().as_secs_f64();
            return launches;
        }
        if drift_due && cache_covers && !introspect_due {
            self.drift_resolves += 1;
        }

        let terms = objective_terms(ctx, &remaining);
        if ctx.trace.is_enabled() {
            // drift-alarm re-solves are the ones the coverage/interval
            // triggers would NOT have fired on their own
            let cause = if drift_due && cache_covers && !introspect_due {
                "drift-alarm"
            } else {
                ctx.cause.name()
            };
            ctx.trace.begin(
                "solver",
                "resolve",
                Json::obj(vec![
                    ("policy", Json::str("saturn")),
                    ("cause", Json::str(cause)),
                    ("jobs", Json::num(remaining.len() as f64)),
                    ("warm", Json::Bool(false)),
                ]),
            );
        }
        let live = if self.failure_aware {
            degraded_capacities(ctx)
        } else {
            None
        };
        let (mut plan, stats) =
            solve_joint_live(&remaining, ctx.profiles, ctx.cluster,
                             self.mode, self.lookahead, None,
                             ctx.objective, &terms, ctx.trace,
                             live.as_deref());
        if ctx.trace.is_enabled() {
            ctx.trace.end(
                "solver",
                "resolve",
                Json::obj(vec![
                    ("nodes", Json::num(stats.milp_nodes as f64)),
                    ("wall_s", Json::num(stats.wall_s)),
                ]),
            );
        }
        self.pressure.0 += stats.lp_capped;
        self.pressure.1 += stats.limit_reached;
        self.last_stats = stats;
        self.solves += 1;
        self.last_solve_t = ctx.now;
        self.last_obs_seen = ctx.obs_seen;

        apply_migration_hysteresis(&mut plan, ctx, &remaining,
                                   self.migration_threshold);

        self.cached = Some(plan);
        let launches = self.launch_from_cache(ctx);
        self.decision_s += t0.elapsed().as_secs_f64();
        launches
    }

    fn introspection_interval(&self) -> Option<f64> {
        self.introspect_every_s
    }

    fn decision_time_s(&self) -> f64 {
        self.decision_s
    }

    fn solver_pressure(&self) -> (usize, usize) {
        self.pressure
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::sim::engine::{simulate, SimConfig};
    use crate::trials::profile_analytic;
    use crate::workload::wikitext_workload;

    #[test]
    fn saturn_completes_table1_workload() {
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let mut policy = SaturnPolicy::paper_default();
        let r = simulate(&jobs, &profiles, &cluster, &mut policy,
                         &SimConfig::default());
        assert_eq!(r.finish_times.len(), 12);
        assert!(policy.solves() >= 1);
        assert!(r.gpu_utilization > 0.3, "util {}", r.gpu_utilization);
    }

    #[test]
    fn introspection_off_means_no_preemptions() {
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let mut policy = SaturnPolicy::new(SolverMode::Joint, None);
        let r = simulate(&jobs, &profiles, &cluster, &mut policy,
                         &SimConfig::default());
        assert_eq!(r.preemptions, 0);
    }

    #[test]
    fn decision_time_is_negligible_fraction() {
        // paper claim: solver+profiling negligible vs training time
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let mut policy = SaturnPolicy::paper_default();
        let r = simulate(&jobs, &profiles, &cluster, &mut policy,
                         &SimConfig::default());
        assert!(r.policy_decision_s < 0.01 * r.makespan_s,
                "solver {}s vs makespan {}s", r.policy_decision_s, r.makespan_s);
    }
}
