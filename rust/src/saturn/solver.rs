//! The joint MILP: parallelism selection x GPU allocation x GPU class x
//! scheduling.
//!
//! The workshop paper states the joint problem is cast as an MILP and
//! solved with Gurobi, without printing the formulation. We implement the
//! standard two-level decomposition for malleable-task makespan problems
//! (documented in DESIGN.md §4), extended with the GPU class as a plan
//! dimension for heterogeneous fleets (DESIGN.md §Fleets):
//!
//!  1. **Plan-selection MILP** (exact, via `solver::milp`): binary
//!     x_{j,c} over each job's candidate plans c = (technique, gpus,
//!     class) — the union of every class's Pareto set — with
//!
//!     ```text
//!     min  M
//!     s.t. sum_c x_{jc} = 1                               (each job planned)
//!          sum_c t_{jc} x_{jc} <= M                       (critical path)
//!          sum_{j,c in k} g_{jc} t_{jc} x_{jc} <= G_k * M (area, class k)
//!     ```
//!
//!     One capacity row per GPU class k (G_k = GPUs in class k) replaces
//!     the homogeneous fleet-wide area row; on a single-class fleet the
//!     formulation degenerates to the original one exactly (the
//!     `bench_hetero` probe holds this to 1e-6). Rows stay cheap because
//!     the revised simplex carries binaries as variable BOUNDS, so the
//!     row count is 2*jobs + n_classes.
//!
//!  2. **List scheduling** (LPT first-fit on the chosen plans, per-class
//!     placement) to realize an order, followed by a local-search repair
//!     that re-plans the makespan-defining job if a different (tech,
//!     gpus, class) shortens the schedule.
//!
//! An exact time-indexed formulation (`SolverMode::ExactSlots`) is kept
//! for small instances to validate the decomposition in tests.
//!
//! The objective is pluggable (DESIGN.md §4.5): [`solve_joint_obj`]
//! threads an [`Objective`] through every level. `WeightedTardiness`
//! adds one epigraph variable + one linearized tardiness row per
//! DEADLINED job (`T_j >= C_j - due_j`, `C_j` proxied by the chosen
//! runtime plus the rolling-horizon completion offset), and
//! `WeightedJct` blends priority-weighted completion coefficients onto
//! the plan binaries — both keep the matrix sparse enough that the
//! PR 2 bounded-variable simplex stays sub-second at 256 jobs under
//! `SolverMode::RollingHorizon`. `Objective::Makespan` (and terms that
//! degenerate to it) build the HISTORICAL formulation bit for bit.

use std::time::Instant;

use crate::cluster::ClusterSpec;
use crate::objective::{JobTerms, Objective};
use crate::obs::trace::Tracer;
use crate::saturn::plan::{JobPlan, SaturnPlan};
use crate::sim::placement::FreeState;
use crate::solver::lp::{Basis, Cmp, Lp, Simplex};
use crate::solver::milp::{solve as milp_solve, solve_with_stats,
                          MilpEngine, MilpOptions, MilpResult};
use crate::trials::ProfileTable;
use crate::util::json::Json;
use crate::util::threadpool::scope_map;

/// Above this many jobs the coordinate-descent schedule repair is skipped:
/// each sweep re-simulates O(jobs x alternatives) list schedules, which
/// dwarfs the MILP itself at rolling-horizon scale.
const LOCAL_SEARCH_MAX_JOBS: usize = 48;

/// One candidate plan: (technique, gpus, class, total runtime seconds).
type Cand = (usize, u32, usize, f64);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverMode {
    /// Plan-selection MILP + list scheduling (default; scales to dozens of
    /// jobs).
    Joint,
    /// Greedy fallback (no MILP) — used for very large instances and as an
    /// ablation arm in bench E9.
    Heuristic,
    /// Time-indexed exact MILP; exponential, tests/small instances only.
    ExactSlots { slots: usize },
    /// Rolling-horizon decomposition for 100+ concurrent jobs: order jobs
    /// by dominance (longest min-GPU runtime first), solve the
    /// plan-selection MILP over a `window`-job slice, commit everything
    /// except the trailing `overlap` jobs, slide, repeat. Committed
    /// windows feed the next solve as a makespan floor plus per-class
    /// GPU-area offsets, so the coupling the windows share is preserved.
    RollingHorizon { window: usize, overlap: usize },
    /// Hierarchical cell sharding for thousands of concurrent jobs: a
    /// cheap top-level assigner balances jobs across cells of at most
    /// `cell_size` by dominant-resource pressure, every cell solves its
    /// own column-generation master against a proportional slice of the
    /// fleet concurrently ([`crate::util::threadpool::scope_map`]), and
    /// the per-cell picks merge back in job order — deterministic for
    /// any worker count. `SolverStats::{cells, shard_gap}` report the
    /// partition width and a bound-relative optimality gap.
    Sharded { cell_size: usize },
}

impl SolverMode {
    /// The rolling default used when callers only know "lots of jobs".
    pub fn rolling_default() -> SolverMode {
        SolverMode::RollingHorizon { window: 32, overlap: 8 }
    }

    /// The sharded default used when callers only know "thousands of
    /// jobs".
    pub fn sharded_default() -> SolverMode {
        SolverMode::Sharded { cell_size: 64 }
    }
}

#[derive(Debug, Clone, Default)]
pub struct SolverStats {
    pub milp_nodes: usize,
    pub wall_s: f64,
    pub proved_optimal: bool,
    /// An incumbent seeded from a previous plan was handed to the MILP
    /// (online incremental re-solves; see `solve_joint_warm`).
    pub warm_used: bool,
    /// Simplex pivots across every branch-and-bound node LP.
    pub lp_pivots: usize,
    /// Node LPs re-solved from the parent basis via dual simplex.
    pub warm_hits: usize,
    /// Node LPs that fell back to a cold two-phase solve.
    pub warm_misses: usize,
    /// Rolling-horizon windows solved (0 = single-shot formulation).
    pub windows: usize,
    /// Node LPs that hit the simplex iteration cap (`LpInfo::capped`):
    /// their objectives are not trusted as bounds, so a growing count
    /// means the search is degrading quietly.
    pub lp_capped: usize,
    /// MILP solves stopped by a node/time limit — `LimitReached` or an
    /// unproved incumbent. Under event-rate re-solving this is the
    /// "solver can no longer keep up" signal the online metrics surface.
    pub limit_reached: usize,
    /// Jobs dropped from a solve because they fit no GPU class of the
    /// fleet ([`check_fleet_feasibility`]): the solver plans the rest
    /// instead of aborting, and the shed jobs stay queued.
    pub shed_jobs: usize,
    /// Plan selections that fell back to the greedy heuristic because
    /// the chosen level returned no plan (MILP infeasible after a fleet
    /// shrink, `LimitReached` with no incumbent, a failed rolling
    /// window) — the degradation ladder's middle rung, counted so it is
    /// never silent. Explicit `SolverMode::Heuristic` solves are not
    /// fallbacks and are not counted.
    pub greedy_fallbacks: usize,
    /// Candidate columns priced into a column-generation restricted
    /// master by reduced cost (seed columns are not counted).
    pub columns_priced: usize,
    /// Product-form eta updates recorded by node LPs in place of dense
    /// basis refactorizations (see `solver/lp.rs`).
    pub eta_updates: usize,
    /// From-scratch basis factorizations across node LPs: one per warm
    /// entry plus every spike-count / drift-triggered eta-file collapse.
    pub refactorizations: usize,
    /// Cells the last sharded solve partitioned the queue into
    /// (0 = unsharded).
    pub cells: usize,
    /// Bound-relative optimality gap of the last sharded solve:
    /// `(sharded objective - monolithic lower bound) / bound`, where the
    /// bound is the classic max(longest fastest-plan runtime, total
    /// min-area / fleet GPUs). An upper bound on the true gap vs the
    /// monolithic solve; 0.0 when unsharded.
    pub shard_gap: f64,
    /// MILP solves truncated by an EXPLICIT anytime budget
    /// ([`SolveBudget`] routed into `MilpOptions::{deadline_ms,
    /// node_budget}`) — distinct from `limit_reached`, which also counts
    /// the default node/time safety limits.
    pub budget_exhausted: usize,
}

impl SolverStats {
    /// Fraction of node LPs served from a parent basis (dual-simplex
    /// warm starts inside branch-and-bound).
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_hits + self.warm_misses;
        if total == 0 {
            0.0
        } else {
            self.warm_hits as f64 / total as f64
        }
    }

    fn absorb(&mut self, st: &crate::solver::milp::MilpStats) {
        self.milp_nodes += st.nodes;
        self.lp_pivots += st.lp_pivots;
        self.warm_hits += st.warm_hits;
        self.warm_misses += st.warm_misses;
        self.lp_capped += st.capped_lps;
        self.eta_updates += st.eta_updates;
        self.refactorizations += st.refactorizations;
        self.budget_exhausted += st.budget_hit as usize;
    }

    /// Fold a per-cell solve's counters into the merged sharded stats.
    fn merge_cell(&mut self, st: &SolverStats) {
        self.milp_nodes += st.milp_nodes;
        self.lp_pivots += st.lp_pivots;
        self.warm_hits += st.warm_hits;
        self.warm_misses += st.warm_misses;
        self.lp_capped += st.lp_capped;
        self.limit_reached += st.limit_reached;
        self.columns_priced += st.columns_priced;
        self.eta_updates += st.eta_updates;
        self.refactorizations += st.refactorizations;
        self.greedy_fallbacks += st.greedy_fallbacks;
        self.budget_exhausted += st.budget_exhausted;
        self.proved_optimal &= st.proved_optimal;
    }
}

/// Anytime re-solve budget for the online hot path (DESIGN.md §4.9):
/// every MILP a budgeted solve dispatches is handed the REMAINING
/// wall-clock/node allowance (`MilpOptions::{deadline_ms, node_budget}`),
/// so one slow window cannot starve the event loop — the search stops at
/// the budget and returns the best incumbent with its bound. The default
/// (both `None`) is no budget: [`solve_joint_live`] and everything above
/// it stay bit-identical. `node_budget` is deterministic; `deadline_ms`
/// depends on the host clock and is for production latency floors, not
/// replays.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SolveBudget {
    /// Wall-clock allowance for the WHOLE solve, milliseconds.
    pub deadline_ms: Option<f64>,
    /// Branch-and-bound node allowance for the whole solve.
    pub node_budget: Option<usize>,
}

impl SolveBudget {
    pub fn is_set(&self) -> bool {
        self.deadline_ms.is_some() || self.node_budget.is_some()
    }
}

/// Verify every job fits somewhere in the fleet. `Err` carries a
/// human-readable description naming the jobs whose memory footprint fits
/// no GPU class — the CLI bails with it up front; the solver logs it,
/// sheds the offending jobs (`SolverStats::shed_jobs`), and plans the
/// rest, so a fleet that degrades mid-run never aborts the process.
pub fn check_fleet_feasibility(jobs: &[(usize, u64)],
                               profiles: &ProfileTable,
                               cluster: &ClusterSpec) -> Result<(), String> {
    let bad: Vec<usize> = jobs
        .iter()
        .map(|&(id, _)| id)
        .filter(|&id| !profiles.feasible_anywhere(id))
        .collect();
    if bad.is_empty() {
        Ok(())
    } else {
        Err(format!(
            "job(s) {bad:?} fit no GPU class in the fleet [{}]: every \
             profiled (technique, gpus, class) combination is infeasible \
             (typically the memory footprint exceeds each class's usable \
             HBM). Add a roomier GPU class to the fleet or register a more \
             memory-frugal parallelism (e.g. offload).",
            cluster.fleet_desc()))
    }
}

/// Inputs per unfinished job: (job_id, remaining_steps).
pub fn solve_joint(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
) -> (SaturnPlan, SolverStats) {
    solve_joint_with(jobs, profiles, cluster, mode, 1.0)
}

/// `lookahead` (kappa >= 1) encodes introspection-awareness: a job's
/// critical-path contribution is divided by kappa because a re-solve can
/// upsize it later. kappa = 1 -> static plans (no introspection). With
/// kappa > 1 the solver prefers max-efficiency (min-area) allocations up
/// front and naturally upgrades the stragglers at the tail — the classic
/// water-filling optimum for malleable jobs under preemption.
pub fn solve_joint_with(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
    lookahead: f64,
) -> (SaturnPlan, SolverStats) {
    solve_joint_warm(jobs, profiles, cluster, mode, lookahead, None)
}

/// Incremental re-solve for the online scheduler: `warm` (the plan from
/// the previous event) seeds the branch-and-bound incumbent, so the MILP
/// prunes against a known-good schedule from node one. Jobs absent from
/// `warm` (fresh arrivals) default to their min-GPU candidate in the
/// seeded incumbent; departed jobs are simply dropped. This is what makes
/// event-rate re-solving affordable (bench_online measures warm vs cold).
///
/// Jobs that fit no GPU class of the fleet are shed (logged with the
/// [`check_fleet_feasibility`] message, counted in
/// [`SolverStats::shed_jobs`], absent from the returned plan) and the
/// rest are planned — callers surface shed jobs as queued work rather
/// than aborting.
pub fn solve_joint_warm(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
    lookahead: f64,
    warm: Option<&SaturnPlan>,
) -> (SaturnPlan, SolverStats) {
    solve_joint_obj(jobs, profiles, cluster, mode, lookahead, warm,
                    Objective::Makespan, &[])
}

/// [`solve_joint_warm`] generalized over the scheduling [`Objective`]
/// axis. `terms` carries per-job weights and deadlines (relative to
/// the solve instant); entries are matched by job id and missing
/// entries are neutral. With `Objective::Makespan` — or
/// terms under which the richer objectives degenerate to it — the
/// solve IS the historical path, bit for bit (the makespan arm of
/// `bench_objective` holds this against BENCH_online at 1e-6).
///
/// For genuinely non-makespan objectives the makespan-targeted
/// coordinate-descent repair is skipped: it would trade the objective
/// the MILP just optimized for packing-only gains.
#[allow(clippy::too_many_arguments)]
pub fn solve_joint_obj(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
    lookahead: f64,
    warm: Option<&SaturnPlan>,
    objective: Objective,
    terms: &[JobTerms],
) -> (SaturnPlan, SolverStats) {
    solve_joint_traced(jobs, profiles, cluster, mode, lookahead, warm,
                       objective, terms, &Tracer::off())
}

/// [`solve_joint_obj`] with a flight-recorder sink: per-phase spans
/// (candidate generation, plan selection — with LP-root/branch-and-bound
/// sub-spans from the MILP engine and per-window spans under rolling
/// horizon — list scheduling, local search) land on `trace`. With the
/// tracer off this IS `solve_joint_obj`: every emission is one branch.
#[allow(clippy::too_many_arguments)]
pub fn solve_joint_traced(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
    lookahead: f64,
    warm: Option<&SaturnPlan>,
    objective: Objective,
    terms: &[JobTerms],
    trace: &Tracer,
) -> (SaturnPlan, SolverStats) {
    solve_joint_live(jobs, profiles, cluster, mode, lookahead, warm,
                     objective, terms, trace, None)
}

/// [`solve_joint_traced`] over a DEGRADED fleet: `live_gpus` (per-class
/// GPU counts from [`crate::sim::placement::FreeState::live_capacity`])
/// replaces the static per-class capacities in the plan-selection area
/// rows, so failure-aware policies solve against what the fleet can
/// actually serve while nodes are down. `None` — or a length mismatch —
/// means the static capacities, making this entry bit-identical to
/// [`solve_joint_traced`] on a healthy fleet. List scheduling and the
/// exact-slot oracle keep the full cluster (the realized launches are
/// still gated by the engine's real `FreeState`, so a too-optimistic
/// schedule only queues; it never over-places).
#[allow(clippy::too_many_arguments)]
pub fn solve_joint_live(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
    lookahead: f64,
    warm: Option<&SaturnPlan>,
    objective: Objective,
    terms: &[JobTerms],
    trace: &Tracer,
    live_gpus: Option<&[f64]>,
) -> (SaturnPlan, SolverStats) {
    solve_joint_budgeted(jobs, profiles, cluster, mode, lookahead, warm,
                         objective, terms, trace, live_gpus,
                         SolveBudget::default())
}

/// [`solve_joint_live`] under an anytime [`SolveBudget`]: the remaining
/// allowance is recomputed before every MILP dispatch, a truncated
/// search returns its best incumbent (counted in
/// [`SolverStats::budget_exhausted`]), and the final plan is FLOORED at
/// the greedy ladder's — a budgeted solve never returns a worse plan
/// than [`SolverMode::Heuristic`] would have. With the default budget
/// this IS `solve_joint_live`, bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn solve_joint_budgeted(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
    lookahead: f64,
    warm: Option<&SaturnPlan>,
    objective: Objective,
    terms: &[JobTerms],
    trace: &Tracer,
    live_gpus: Option<&[f64]>,
    budget: SolveBudget,
) -> (SaturnPlan, SolverStats) {
    let start = Instant::now();
    let traced = trace.is_enabled();
    if traced {
        let mode_name = match mode {
            SolverMode::Joint => "joint",
            SolverMode::Heuristic => "heuristic",
            SolverMode::ExactSlots { .. } => "exact",
            SolverMode::RollingHorizon { .. } => "rolling",
            SolverMode::Sharded { .. } => "sharded",
        };
        trace.begin(
            "solver",
            "solve",
            Json::obj(vec![
                ("jobs", Json::num(jobs.len() as f64)),
                ("mode", Json::str(mode_name)),
            ]),
        );
        trace.begin("solver", "candidates", Json::obj(Vec::new()));
    }
    let kappa = lookahead.max(1.0);
    let mut stats = SolverStats::default();
    // graceful degradation rung 1: jobs that fit nowhere are shed (they
    // stay queued at the caller), never a process abort
    let feasible_jobs: Vec<(usize, u64)>;
    let jobs = match check_fleet_feasibility(jobs, profiles, cluster) {
        Ok(()) => jobs,
        Err(e) => {
            feasible_jobs = jobs
                .iter()
                .copied()
                .filter(|&(id, _)| profiles.feasible_anywhere(id))
                .collect();
            stats.shed_jobs = jobs.len() - feasible_jobs.len();
            log::warn!(
                "{e}; shedding {} job(s) and planning the rest",
                stats.shed_jobs);
            &feasible_jobs
        }
    };
    let plans = expand_plans(jobs, profiles);
    let g_class = match live_gpus {
        Some(live) if live.len() == cluster.n_classes() => live.to_vec(),
        _ => class_capacities(cluster),
    };
    let obj = ObjSpec::new(objective, terms).with_budget(budget, start);
    if traced {
        let cands: usize = plans.iter().map(|(_, ps)| ps.len()).sum();
        trace.end(
            "solver",
            "candidates",
            Json::obj(vec![("plans", Json::num(cands as f64))]),
        );
        trace.begin("solver", "plan_selection", Json::obj(Vec::new()));
    }
    // the greedy heuristic optimizes makespan only — never silently:
    // a user who asked for tardiness/wjct and lands here (explicitly
    // via --mode greedy, or through an MILP fallback) is told that
    // plan selection dropped their objective (launch ordering still
    // honors it downstream)
    let greedy = || {
        if !obj.makespan_like() {
            log::warn!(
                "greedy plan selection ignores the '{}' objective \
                 (it optimizes makespan; launch ordering still honors \
                 the objective)",
                objective.name());
        }
        greedy_choice(&plans, &g_class, kappa)
    };

    let choices = match mode {
        SolverMode::Heuristic => greedy(),
        SolverMode::Joint => {
            match milp_choice(&plans, &g_class, kappa, warm, &obj,
                              trace, &mut stats) {
                Some(c) => c,
                None => {
                    // degradation rung 2: infeasible-after-shrink or a
                    // limit with no incumbent — greedy incumbent plan
                    stats.greedy_fallbacks += 1;
                    greedy()
                }
            }
        }
        SolverMode::ExactSlots { slots } => {
            // the exact time-indexed oracle stays makespan-only (small
            // validation instances; the objective axis is exercised
            // through the decomposition)
            match exact_slot_choice(&plans, cluster, slots, trace,
                                    &mut stats) {
                Some(c) => c,
                None => {
                    stats.greedy_fallbacks += 1;
                    greedy()
                }
            }
        }
        SolverMode::RollingHorizon { window, overlap } => {
            match rolling_choice(&plans, &g_class, kappa, warm, window,
                                 overlap, &obj, trace, &mut stats) {
                Some(c) => c,
                None => {
                    stats.greedy_fallbacks += 1;
                    greedy()
                }
            }
        }
        SolverMode::Sharded { cell_size } => {
            match sharded_choice(&plans, &g_class, kappa, warm, cell_size,
                                 SHARD_THREADS, &obj, trace, &mut stats) {
                Some(c) => c,
                None => {
                    stats.greedy_fallbacks += 1;
                    greedy()
                }
            }
        }
    };
    if traced {
        trace.end(
            "solver",
            "plan_selection",
            Json::obj(vec![(
                "chosen",
                Json::num(choices.len() as f64),
            )]),
        );
        trace.begin("solver", "schedule", Json::obj(Vec::new()));
    }

    let mut plan = build_schedule(choices, cluster);
    if traced {
        trace.end(
            "solver",
            "schedule",
            Json::obj(vec![(
                "makespan_s",
                Json::num(plan.predicted_makespan_s),
            )]),
        );
    }
    if kappa <= 1.0 + 1e-9
        && plan.choices.len() <= LOCAL_SEARCH_MAX_JOBS
        && obj.makespan_like()
    {
        // static plans: repair against the realized list schedule (a
        // makespan-currency sweep, so only on makespan-like solves)
        if traced {
            trace.begin("solver", "local_search", Json::obj(Vec::new()));
        }
        local_search(&mut plan, &plans, cluster);
        if traced {
            trace.end(
                "solver",
                "local_search",
                Json::obj(vec![(
                    "makespan_s",
                    Json::num(plan.predicted_makespan_s),
                )]),
            );
        }
    }
    apply_greedy_floor(&mut plan, &plans, &g_class, kappa, &obj, cluster,
                       &mut stats);
    stats.wall_s = start.elapsed().as_secs_f64();
    if traced {
        trace.end(
            "solver",
            "solve",
            Json::obj(vec![("wall_s", Json::num(stats.wall_s))]),
        );
    }
    (plan, stats)
}

/// Above this many jobs the delta path solves seeded CELLS (the sharded
/// partition) instead of one seeded master — the same crossover at
/// which the online scheduler leaves single-shot Joint solves.
pub(crate) const DELTA_UNSHARDED_MAX: usize = 64;

/// Event-delta joint solve over RETAINED column-generation state — the
/// online incremental hot path (DESIGN.md §4.9). Seeds every restricted
/// master from `state` (pools, duals, remapped basis), updates `state`
/// in place on success, and returns `None` whenever any level fails so
/// the caller ([`crate::saturn::incremental::IncrementalSolver`]) can
/// fall back to the full solve. Makespan-like objectives only: the
/// colgen masters price the makespan formulation.
#[allow(clippy::too_many_arguments)]
pub(crate) fn solve_joint_delta(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    lookahead: f64,
    warm: Option<&SaturnPlan>,
    objective: Objective,
    terms: &[JobTerms],
    trace: &Tracer,
    live_gpus: Option<&[f64]>,
    budget: SolveBudget,
    threads: usize,
    state: &mut ColgenState,
) -> Option<(SaturnPlan, SolverStats)> {
    let start = Instant::now();
    let kappa = lookahead.max(1.0);
    let mut stats = SolverStats::default();
    let feasible_jobs: Vec<(usize, u64)>;
    let jobs = match check_fleet_feasibility(jobs, profiles, cluster) {
        Ok(()) => jobs,
        Err(e) => {
            feasible_jobs = jobs
                .iter()
                .copied()
                .filter(|&(id, _)| profiles.feasible_anywhere(id))
                .collect();
            stats.shed_jobs = jobs.len() - feasible_jobs.len();
            log::warn!(
                "{e}; shedding {} job(s) and planning the rest",
                stats.shed_jobs);
            &feasible_jobs
        }
    };
    let obj = ObjSpec::new(objective, terms).with_budget(budget, start);
    if !obj.makespan_like() {
        return None;
    }
    // departures: drop the departed jobs' retained artifacts up front
    // (the basis layout tolerates missing jobs through the remap)
    let roster: std::collections::HashSet<usize> =
        jobs.iter().map(|&(id, _)| id).collect();
    state.pools.retain(|id, _| roster.contains(id));
    state.job_duals.retain(|id, _| roster.contains(id));
    let plans = expand_plans(jobs, profiles);
    let g_class = match live_gpus {
        Some(live) if live.len() == cluster.n_classes() => live.to_vec(),
        _ => class_capacities(cluster),
    };
    let zeros = vec![0.0; g_class.len()];
    let seed = state.clone();
    let traced = trace.is_enabled();
    if traced {
        trace.begin(
            "solver",
            "solve",
            Json::obj(vec![
                ("jobs", Json::num(plans.len() as f64)),
                ("mode", Json::str("delta")),
            ]),
        );
    }
    let choices = if plans.len() <= DELTA_UNSHARDED_MAX {
        colgen_choice_seeded(&plans, &g_class, kappa, 0.0, &zeros, warm,
                             20_000, 10.0, 0.01, &obj, trace, &mut stats,
                             Some(&seed), Some(state))
    } else {
        sharded_choice_seeded(&plans, &g_class, kappa, warm,
                              DELTA_UNSHARDED_MAX, threads, &obj, trace,
                              &mut stats, Some(&seed), Some(state))
    };
    let Some(choices) = choices else {
        if traced {
            trace.end(
                "solver",
                "solve",
                Json::obj(vec![("failed", Json::Bool(true))]),
            );
        }
        return None;
    };
    let mut plan = build_schedule(choices, cluster);
    if kappa <= 1.0 + 1e-9
        && plan.choices.len() <= LOCAL_SEARCH_MAX_JOBS
        && obj.makespan_like()
    {
        local_search(&mut plan, &plans, cluster);
    }
    apply_greedy_floor(&mut plan, &plans, &g_class, kappa, &obj, cluster,
                       &mut stats);
    stats.wall_s = start.elapsed().as_secs_f64();
    if traced {
        trace.end(
            "solver",
            "solve",
            Json::obj(vec![("wall_s", Json::num(stats.wall_s))]),
        );
    }
    Some((plan, stats))
}

/// Objective payload threaded through the plan-selection levels.
struct ObjSpec<'a> {
    objective: Objective,
    /// Matched by job id (slices/windows of `plans` look terms up);
    /// empty = neutral terms for every job.
    terms: &'a [JobTerms],
    /// job id -> index into `terms`: rolling windows and the LP builder
    /// look terms up per (job, row), so lookups must not scan the slice.
    by_id: std::collections::HashMap<usize, usize>,
    /// Anytime budget shared by EVERY MILP this solve dispatches; the
    /// default (unset) keeps the historical limits bit for bit.
    budget: SolveBudget,
    /// Instant the budget's deadline is measured from (solve entry).
    t0: Instant,
}

impl ObjSpec<'_> {
    fn new(objective: Objective, terms: &[JobTerms]) -> ObjSpec<'_> {
        let by_id = terms
            .iter()
            .enumerate()
            .map(|(i, t)| (t.job_id, i))
            .collect();
        ObjSpec { objective, terms, by_id, budget: SolveBudget::default(),
                  t0: Instant::now() }
    }

    fn with_budget(mut self, budget: SolveBudget, t0: Instant) -> Self {
        self.budget = budget;
        self.t0 = t0;
        self
    }

    /// The budget allowance still unspent at this dispatch: wall clock
    /// measured from the solve's entry, nodes from the running total in
    /// `stats`. Clamped at zero so an overrun dispatch still returns
    /// its warm incumbent immediately instead of underflowing.
    fn remaining_budget(&self, stats: &SolverStats)
        -> (Option<f64>, Option<usize>) {
        let deadline_ms = self.budget.deadline_ms.map(|d| {
            (d - self.t0.elapsed().as_secs_f64() * 1e3).max(0.0)
        });
        let node_budget = self
            .budget
            .node_budget
            .map(|b| b.saturating_sub(stats.milp_nodes));
        (deadline_ms, node_budget)
    }

    /// The historical objective: pure makespan, neutral terms.
    fn makespan() -> ObjSpec<'static> {
        ObjSpec::new(Objective::Makespan, &[])
    }

    fn term(&self, job_id: usize) -> JobTerms {
        self.by_id
            .get(&job_id)
            .map(|&i| self.terms[i])
            .unwrap_or_else(|| JobTerms::neutral(job_id))
    }

    /// Whether the formulation degenerates to pure makespan (the
    /// historical — bit-identical — LP is built in that case).
    fn makespan_like(&self) -> bool {
        self.objective.degenerates_to_makespan(self.terms)
    }
}

/// GPUs per class, in class order.
fn class_capacities(cluster: &ClusterSpec) -> Vec<f64> {
    (0..cluster.n_classes())
        .map(|ci| cluster.class_gpus(ci) as f64)
        .collect()
}

/// Anytime floor for budgeted solves: whatever the (possibly truncated)
/// MILP produced, the returned plan may never be worse than the greedy
/// ladder pushed through the SAME schedule/repair pipeline — this makes
/// "budget-on never loses to the greedy fallback" a structural property
/// of [`solve_joint_budgeted`], not a tendency. No-op without a budget
/// and on non-makespan objectives (greedy optimizes makespan only).
fn apply_greedy_floor(
    plan: &mut SaturnPlan,
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
    obj: &ObjSpec,
    cluster: &ClusterSpec,
    stats: &mut SolverStats,
) {
    if !obj.budget.is_set() || !obj.makespan_like() {
        return;
    }
    let mut g =
        build_schedule(greedy_choice(plans, g_class, kappa), cluster);
    if kappa <= 1.0 + 1e-9 && g.choices.len() <= LOCAL_SEARCH_MAX_JOBS {
        local_search(&mut g, plans, cluster);
    }
    if g.predicted_makespan_s + 1e-9 < plan.predicted_makespan_s {
        stats.greedy_fallbacks += 1;
        *plan = g;
    }
}

/// Per-job candidate plans (tech, gpus, class, total runtime) over the
/// remaining steps — the search space every solver level shares.
fn expand_plans(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
) -> Vec<(usize, Vec<Cand>)> {
    jobs.iter()
        .map(|&(id, steps)| {
            let ps = profiles
                .candidate_plans(id)
                .into_iter()
                .map(|(tech, g, class, step)| {
                    (tech, g, class, step * steps as f64)
                })
                .collect::<Vec<_>>();
            (id, ps)
        })
        .collect()
}

/// The SEED solver path, preserved verbatim for benchmarking: the dense
/// tableau MILP (`MilpEngine::DenseReference` — bounds as rows, every
/// node cold-solved from scratch) followed by the same list scheduling
/// and local search. `bench_solver_scale` measures the revised path's
/// speedup against this at matched plan quality; it is not meant for
/// production use.
pub fn solve_joint_reference(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
) -> (SaturnPlan, SolverStats) {
    let start = Instant::now();
    let mut stats = SolverStats::default();
    let plans = expand_plans(jobs, profiles);
    let g_class = class_capacities(cluster);
    let zeros = vec![0.0; g_class.len()];
    let choices = match plan_selection_with_engine(
        &plans, &g_class, 1.0, 0.0, &zeros, None, 20_000, 10.0, 0.01,
        MilpEngine::DenseReference, &ObjSpec::makespan(), 0.0,
        &Tracer::off(), &mut stats)
    {
        Some(c) => c,
        None => {
            stats.greedy_fallbacks += 1;
            greedy_choice(&plans, &g_class, 1.0)
        }
    };
    let mut plan = build_schedule(choices, cluster);
    if plan.choices.len() <= LOCAL_SEARCH_MAX_JOBS {
        local_search(&mut plan, &plans, cluster);
    }
    stats.wall_s = start.elapsed().as_secs_f64();
    (plan, stats)
}

/// Solve ONLY the level-1 plan-selection MILP (no list scheduling, no
/// local search) with the chosen engine at a TIGHT 1e-6 gap, returning
/// the proved objective `M`. Because both engines prove optimality, this
/// is the apples-to-apples probe `bench_solver_scale` uses to show the
/// revised engine's speedup at objective-identical results.
pub fn plan_selection_probe(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    engine: MilpEngine,
) -> Option<(f64, SolverStats)> {
    let start = Instant::now();
    let mut stats = SolverStats::default();
    let plans = expand_plans(jobs, profiles);
    let g_class = class_capacities(cluster);
    let zeros = vec![0.0; g_class.len()];
    let choices = plan_selection_with_engine(
        &plans, &g_class, 1.0, 0.0, &zeros, None, 200_000, 120.0, 1e-6,
        engine, &ObjSpec::makespan(), 0.0, &Tracer::off(), &mut stats)?;
    stats.wall_s = start.elapsed().as_secs_f64();
    Some((probe_objective(&choices, &g_class), stats))
}

/// The PRE-heterogeneity formulation, kept as the degenerate-fleet
/// equivalence oracle: the fleet is one interchangeable pool (a single
/// area row over `total_gpus`) and the candidate set is class 0's Pareto
/// set. On a single-class fleet this IS the original solver bit for bit;
/// `bench_hetero` and `tests/prop_hetero.rs` hold the per-class path to
/// it within 1e-6. Meaningless on a mixed fleet — callers assert
/// single-class.
pub fn plan_selection_probe_pooled(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    engine: MilpEngine,
) -> Option<(f64, SolverStats)> {
    assert!(cluster.is_single_class(),
            "pooled probe only defined on single-class fleets");
    let start = Instant::now();
    let mut stats = SolverStats::default();
    let plans: Vec<(usize, Vec<Cand>)> = jobs
        .iter()
        .map(|&(id, steps)| {
            let ps = profiles
                .pareto_plans(id, 0)
                .into_iter()
                .map(|(tech, g, step)| (tech, g, 0usize, step * steps as f64))
                .collect::<Vec<_>>();
            (id, ps)
        })
        .collect();
    let g_class = vec![cluster.total_gpus() as f64];
    let zeros = vec![0.0];
    let choices = plan_selection_with_engine(
        &plans, &g_class, 1.0, 0.0, &zeros, None, 200_000, 120.0, 1e-6,
        engine, &ObjSpec::makespan(), 0.0, &Tracer::off(), &mut stats)?;
    stats.wall_s = start.elapsed().as_secs_f64();
    Some((probe_objective(&choices, &g_class), stats))
}

/// The proved objective of a plan-selection solution:
/// max(longest runtime, max_k area_k / G_k).
fn probe_objective(choices: &[JobPlan], g_class: &[f64]) -> f64 {
    let longest = choices.iter().map(|p| p.runtime_s).fold(0.0, f64::max);
    let mut areas = vec![0.0f64; g_class.len()];
    for p in choices {
        areas[p.class] += p.gpus as f64 * p.runtime_s;
    }
    areas
        .iter()
        .zip(g_class)
        .map(|(a, g)| a / g.max(1e-9))
        .fold(longest, f64::max)
}

// ---------------------------------------------------------------------------
// Column generation (pricing over the candidate ladders)
// ---------------------------------------------------------------------------

/// A candidate column must undercut the master's duals by this much to
/// be priced in; at convergence every out-of-set column's reduced cost
/// sits above `-COLGEN_RC_TOL`, i.e. the restricted LP bound equals the
/// full grid's.
const COLGEN_RC_TOL: f64 = 1e-9;

/// Column-generation analogue of [`plan_selection_probe`]: same tight
/// 1e-6 budgets, but the master starts from one seed column per job and
/// prices the rest of the ladders in by reduced cost. The bench and
/// `tests/prop_solver.rs` hold its objective to the full-grid probe
/// within 1e-6 — the reduced-cost widening pass below makes that an
/// identity, not a heuristic.
pub fn plan_selection_colgen(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
) -> Option<(f64, SolverStats)> {
    let start = Instant::now();
    let mut stats = SolverStats::default();
    let plans = expand_plans(jobs, profiles);
    let g_class = class_capacities(cluster);
    let zeros = vec![0.0; g_class.len()];
    let choices = colgen_choice(
        &plans, &g_class, 1.0, 0.0, &zeros, None, 200_000, 120.0, 1e-6,
        &ObjSpec::makespan(), &Tracer::off(), &mut stats)?;
    stats.wall_s = start.elapsed().as_secs_f64();
    Some((probe_objective(&choices, &g_class), stats))
}

/// Sharded plan selection with an explicit worker count, for the
/// determinism props: the cell merge is input-ordered (`scope_map`
/// preserves item order), so the returned objective is identical for
/// any `threads` — workers only change wall time.
pub fn sharded_probe(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    cell_size: usize,
    threads: usize,
) -> Option<(f64, SolverStats)> {
    let start = Instant::now();
    let mut stats = SolverStats::default();
    let plans = expand_plans(jobs, profiles);
    let g_class = class_capacities(cluster);
    let choices = sharded_choice(
        &plans, &g_class, 1.0, None, cell_size, threads,
        &ObjSpec::makespan(), &Tracer::off(), &mut stats)?;
    stats.wall_s = start.elapsed().as_secs_f64();
    Some((probe_objective(&choices, &g_class), stats))
}

/// Column-generation artifacts RETAINED across online events — what the
/// incremental re-solve path (`saturn::incremental`, DESIGN.md §4.9)
/// persists instead of rebuilding the master from scratch. Everything
/// here is a warm-start hint, never a correctness input: pools re-admit
/// previously-priced columns, duals drive a pricing pre-pass, and the
/// basis re-enters the first master via [`Basis::remap`] + dual-simplex
/// repair — a stale or singular artifact only costs pivots.
#[derive(Debug, Clone, Default)]
pub(crate) struct ColgenState {
    /// job id -> admitted candidate KEYS (tech, gpus, class). Runtimes
    /// are re-derived from the live ladders every event, so a key whose
    /// remaining-steps runtime changed stays valid.
    pub pools: std::collections::HashMap<usize, Vec<(usize, u32, usize)>>,
    /// job id -> (assignment dual, critical-path dual) from the last
    /// converged master that priced the job.
    pub job_duals: std::collections::HashMap<usize, (f64, f64)>,
    /// Per-class area duals from the last converged master.
    pub area_duals: Vec<f64>,
    /// Master simplex basis from the last UNSHARDED converged pricing
    /// loop, with the layout it refers to: rows 2*ji / 2*ji+1 per job in
    /// `job_order` then one area row per class; structural columns in
    /// `col_keys` order with the makespan variable M last.
    pub basis: Option<Basis>,
    pub job_order: Vec<usize>,
    pub col_keys: Vec<(usize, (usize, u32, usize))>,
}

/// Carry a retained master basis onto THIS event's restricted master:
/// arrivals become brand-new rows (slack-basic, dual-feasible),
/// departures delete their rows/columns, and surviving rows keep their
/// basic columns translated through the key maps ([`Basis::remap`]).
/// `None` when the retained layout is unusable — the caller cold-solves.
fn remap_master_basis(
    state: &ColgenState,
    plans: &[(usize, Vec<Cand>)],
    sel: &[Vec<usize>],
    n_classes: usize,
) -> Option<Basis> {
    let basis = state.basis.as_ref()?;
    let old_nj = state.job_order.len();
    let old_n = state.col_keys.len() + 1; // structural columns + M
    let old_m = 2 * old_nj + n_classes;
    if basis.basic.len() != old_m || basis.at_upper.len() != old_n + old_m
    {
        return None;
    }
    let old_ji: std::collections::HashMap<usize, usize> = state
        .job_order
        .iter()
        .enumerate()
        .map(|(i, &id)| (id, i))
        .collect();
    // new structural index per (job, key), in master column order
    let mut new_col: std::collections::HashMap<
        (usize, (usize, u32, usize)),
        usize,
    > = std::collections::HashMap::new();
    let mut var = 0usize;
    for (ji, s) in sel.iter().enumerate() {
        let (id, ps) = &plans[ji];
        for &c in s {
            new_col.insert((*id, (ps[c].0, ps[c].1, ps[c].2)), var);
            var += 1;
        }
    }
    let m_var = var;
    let col_to: Vec<Option<usize>> = state
        .col_keys
        .iter()
        .map(|&(id, key)| new_col.get(&(id, key)).copied())
        .chain(std::iter::once(Some(m_var)))
        .collect();
    let mut row_from: Vec<Option<usize>> =
        Vec::with_capacity(2 * plans.len() + n_classes);
    for (id, _) in plans {
        match old_ji.get(id) {
            Some(&o) => {
                row_from.push(Some(2 * o));
                row_from.push(Some(2 * o + 1));
            }
            None => {
                row_from.push(None);
                row_from.push(None);
            }
        }
    }
    for ci in 0..n_classes {
        row_from.push(Some(2 * old_nj + ci));
    }
    Some(basis.remap(&row_from, &col_to, old_n, m_var + 1))
}

/// Tight-gap seeded column-generation probe: the parity oracle for the
/// incremental path. Starting the pricing loop from `state`'s pools,
/// duals, and basis must land on the SAME objective as the full-grid
/// probe — the reduced-cost widening pass makes colgen exact from ANY
/// starting pool, so `tests/prop_incremental.rs` holds this to 1e-6.
/// Read-only on `state`.
pub(crate) fn plan_selection_colgen_from(
    state: &ColgenState,
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
) -> Option<(f64, SolverStats)> {
    let start = Instant::now();
    let mut stats = SolverStats::default();
    let plans = expand_plans(jobs, profiles);
    let g_class = class_capacities(cluster);
    let zeros = vec![0.0; g_class.len()];
    let choices = colgen_choice_seeded(
        &plans, &g_class, 1.0, 0.0, &zeros, None, 200_000, 120.0, 1e-6,
        &ObjSpec::makespan(), &Tracer::off(), &mut stats, Some(state),
        None)?;
    stats.wall_s = start.elapsed().as_secs_f64();
    Some((probe_objective(&choices, &g_class), stats))
}

/// The makespan restricted master over `sel`ected candidate subsets
/// (`sel[ji]` indexes into `plans[ji].1`). Row layout is what the
/// pricing step scores against: per job `ji` an assignment row `2*ji`
/// and a critical-path row `2*ji + 1`, then one area row per class at
/// `2*jobs + class`.
fn build_restricted_master(
    plans: &[(usize, Vec<Cand>)],
    sel: &[Vec<usize>],
    g_class: &[f64],
    kappa: f64,
    m_floor: f64,
    fixed_area: &[f64],
) -> Lp {
    let mut var = 0usize;
    let mut index: Vec<Vec<usize>> = Vec::new();
    for s in sel {
        index.push((var..var + s.len()).collect());
        var += s.len();
    }
    let m_var = var;
    let mut lp = Lp::new(var + 1);
    lp.set_obj(m_var, 1.0);
    lp.bound_ge(m_var, m_floor);
    for (ji, s) in sel.iter().enumerate() {
        let ps = &plans[ji].1;
        lp.add(index[ji].iter().map(|&v| (v, 1.0)).collect(), Cmp::Eq, 1.0);
        let mut cp: Vec<(usize, f64)> = s
            .iter()
            .enumerate()
            .map(|(k, &c)| (index[ji][k], ps[c].3 / kappa))
            .collect();
        cp.push((m_var, -1.0));
        lp.add(cp, Cmp::Le, 0.0);
    }
    for (ci, (&g_k, &fixed_k)) in g_class.iter().zip(fixed_area).enumerate()
    {
        let mut area: Vec<(usize, f64)> = Vec::new();
        for (ji, s) in sel.iter().enumerate() {
            let ps = &plans[ji].1;
            for (k, &c) in s.iter().enumerate() {
                if ps[c].2 == ci {
                    area.push((index[ji][k], ps[c].1 as f64 * ps[c].3));
                }
            }
        }
        area.push((m_var, -g_k));
        lp.add(area, Cmp::Le, -fixed_k);
    }
    for vs in &index {
        for &v in vs {
            lp.bound_le(v, 1.0);
        }
    }
    lp
}

/// Reduced cost of ladder candidate `p` for job `ji` against master
/// duals `y` (objective coefficient 0 under makespan): the column hits
/// the job's assignment row with 1, its critical-path row with `t/kappa`
/// and its class's area row with `g*t`.
fn reduced_cost(y: &[f64], nj: usize, ji: usize, p: &Cand, kappa: f64)
    -> f64 {
    -(y[2 * ji]
        + y[2 * ji + 1] * (p.3 / kappa)
        + y[2 * nj + p.2] * (p.1 as f64 * p.3))
}

/// Column-generation plan selection (DESIGN.md §4.8). The restricted
/// master starts from one seed column per job (the warm plan's choice
/// where available, else the min-GPU candidate — ladder index 0), prices
/// candidates in by reduced cost until none is negative, then solves the
/// restricted MILP. A final reduced-cost widening pass re-admits every
/// column within the integrality gap `Z_R - Z_LP` of the converged
/// duals — classic reduced-cost fixing says no other column can appear
/// in an integer solution better than the restricted incumbent, so the
/// re-solve's optimum IS the full-grid optimum (at the same MILP gap).
/// Non-makespan objectives price a different master than they optimize,
/// so they fall through to the full grid untouched.
#[allow(clippy::too_many_arguments)]
fn colgen_choice(
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
    m_floor: f64,
    fixed_area: &[f64],
    warm: Option<&SaturnPlan>,
    max_nodes: usize,
    time_limit_s: f64,
    gap: f64,
    obj: &ObjSpec,
    trace: &Tracer,
    stats: &mut SolverStats,
) -> Option<Vec<JobPlan>> {
    colgen_choice_seeded(plans, g_class, kappa, m_floor, fixed_area, warm,
                         max_nodes, time_limit_s, gap, obj, trace, stats,
                         None, None)
}

/// [`colgen_choice`] with RETAINED state on both ends (the incremental
/// hot path): `seed` re-admits the previous event's column pool, runs a
/// pricing pre-pass against the retained duals, and warm-starts the
/// first master from the remapped basis; `out_state` receives the
/// converged pool/duals/basis for the next event. Both `None` IS the
/// unseeded solve. Seeding only changes which columns the restricted
/// masters start from — never the pricing rule or the widening pass —
/// so the tight-gap objective is unchanged from any seed.
#[allow(clippy::too_many_arguments)]
fn colgen_choice_seeded(
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
    m_floor: f64,
    fixed_area: &[f64],
    warm: Option<&SaturnPlan>,
    max_nodes: usize,
    time_limit_s: f64,
    gap: f64,
    obj: &ObjSpec,
    trace: &Tracer,
    stats: &mut SolverStats,
    seed: Option<&ColgenState>,
    out_state: Option<&mut ColgenState>,
) -> Option<Vec<JobPlan>> {
    if !obj.makespan_like() {
        return plan_selection_with_engine(
            plans, g_class, kappa, m_floor, fixed_area, warm, max_nodes,
            time_limit_s, gap, MilpEngine::Revised, obj, 0.0, trace,
            stats);
    }
    if plans.iter().any(|(_, ps)| ps.is_empty()) {
        return None;
    }
    let nj = plans.len();
    let mut sel: Vec<Vec<usize>> = plans
        .iter()
        .map(|(id, ps)| {
            let c = warm
                .and_then(|prev| prev.plan_for(*id))
                .and_then(|jp| {
                    ps.iter().position(|&(t, g, cl, _)| {
                        (t, g, cl) == (jp.tech, jp.gpus, jp.class)
                    })
                })
                .unwrap_or(0);
            vec![c]
        })
        .collect();
    let mut in_sel: Vec<Vec<bool>> = plans
        .iter()
        .map(|(_, ps)| vec![false; ps.len()])
        .collect();
    for (ji, s) in sel.iter().enumerate() {
        in_sel[ji][s[0]] = true;
    }
    if let Some(state) = seed {
        // re-admit the retained pool (seed columns, not "priced")
        for (ji, (id, ps)) in plans.iter().enumerate() {
            if let Some(keys) = state.pools.get(id) {
                for (c, p) in ps.iter().enumerate() {
                    if !in_sel[ji][c] && keys.contains(&(p.0, p.1, p.2)) {
                        sel[ji].push(c);
                        in_sel[ji][c] = true;
                    }
                }
            }
        }
        // pricing pre-pass from the RETAINED duals: one event later they
        // are stale, but still a strong predictor — negative-rc columns
        // enter before the first master ever solves
        if state.area_duals.len() == g_class.len() {
            for (ji, (id, ps)) in plans.iter().enumerate() {
                let Some(&(ya, yc)) = state.job_duals.get(id) else {
                    continue;
                };
                for (c, p) in ps.iter().enumerate() {
                    if in_sel[ji][c] {
                        continue;
                    }
                    let rc = -(ya
                        + yc * (p.3 / kappa)
                        + state.area_duals[p.2] * (p.1 as f64 * p.3));
                    if rc < -COLGEN_RC_TOL {
                        sel[ji].push(c);
                        in_sel[ji][c] = true;
                        stats.columns_priced += 1;
                    }
                }
            }
        }
    }
    let want_state = out_state.is_some();
    // basis layout snapshot of the master column order (job by job, sel
    // order) — what ColgenState::col_keys must mirror
    let snapshot = |sel: &[Vec<usize>]| {
        plans
            .iter()
            .zip(sel)
            .flat_map(|((id, ps), s)| {
                s.iter().map(move |&c| (*id, (ps[c].0, ps[c].1, ps[c].2)))
            })
            .collect::<Vec<_>>()
    };
    // each round adds at most one column per job, so the longest ladder
    // bounds the rounds to converge (then every column is in)
    let max_rounds =
        plans.iter().map(|(_, ps)| ps.len()).max().unwrap_or(1) + 1;
    let mut z_lp = f64::NAN;
    let mut duals: Option<Vec<f64>> = None;
    let mut converged = false;
    let mut entry_basis: Option<Basis> =
        seed.and_then(|s| remap_master_basis(s, plans, &sel,
                                             g_class.len()));
    let mut last_round: Option<(Basis, Vec<(usize, (usize, u32, usize))>)> =
        None;
    for _ in 0..max_rounds {
        let lp = build_restricted_master(plans, &sel, g_class, kappa,
                                         m_floor, fixed_area);
        let sx = Simplex::new(&lp);
        let solved = match entry_basis.take() {
            // arrival/departure repair: the retained basis re-enters via
            // the dual simplex; a singular remap falls back to cold
            Some(b) => sx
                .solve_warm(&lp.lower, &lp.upper, &b)
                .unwrap_or_else(|| sx.solve_cold(&lp.lower, &lp.upper)),
            None => sx.solve_cold(&lp.lower, &lp.upper),
        };
        stats.lp_pivots += solved.info.pivots;
        stats.eta_updates += solved.info.eta_updates;
        stats.refactorizations += solved.info.refactorizations;
        if solved.info.capped {
            stats.lp_capped += 1;
        }
        let Some((_, objective)) = solved.result.optimal() else {
            return None; // master is structurally feasible; bail upward
        };
        let Some(basis) = solved.basis else { break };
        let Some(y) = sx.duals_for(&basis) else { break };
        if want_state {
            last_round = Some((basis, snapshot(&sel)));
        }
        z_lp = objective;
        let mut added = false;
        for (ji, (_, ps)) in plans.iter().enumerate() {
            let mut best: Option<(f64, usize)> = None;
            for (c, p) in ps.iter().enumerate() {
                if in_sel[ji][c] {
                    continue;
                }
                let rc = reduced_cost(&y, nj, ji, p, kappa);
                if rc < -COLGEN_RC_TOL
                    && best.is_none_or(|(b, _)| rc < b)
                {
                    best = Some((rc, c));
                }
            }
            if let Some((_, c)) = best {
                sel[ji].push(c);
                in_sel[ji][c] = true;
                stats.columns_priced += 1;
                added = true;
            }
        }
        duals = Some(y);
        if !added {
            converged = true;
            break;
        }
    }
    let restrict = |sel: &[Vec<usize>]| -> Vec<(usize, Vec<Cand>)> {
        plans
            .iter()
            .zip(sel)
            .map(|((id, ps), s)| (*id, s.iter().map(|&c| ps[c]).collect()))
            .collect()
    };
    let choices = 'solve: {
        let Some(choices) = plan_selection_with_engine(
            &restrict(&sel), g_class, kappa, m_floor, fixed_area, warm,
            max_nodes, time_limit_s, gap, MilpEngine::Revised, obj, 0.0,
            trace, stats)
        else {
            break 'solve None;
        };
        let y = match (&duals, converged && z_lp.is_finite()) {
            (Some(y), true) => y,
            _ => break 'solve Some(choices),
        };
        // integer objective of the incumbent in this formulation's
        // currency
        let z_r = {
            let longest = choices
                .iter()
                .map(|p| p.runtime_s / kappa)
                .fold(m_floor, f64::max);
            let mut areas = fixed_area.to_vec();
            for p in &choices {
                areas[p.class] += p.gpus as f64 * p.runtime_s;
            }
            areas
                .iter()
                .zip(g_class)
                .map(|(a, g)| a / g.max(1e-9))
                .fold(longest, f64::max)
        };
        let slack = (z_r - z_lp).max(0.0) + COLGEN_RC_TOL;
        let mut widened = false;
        for (ji, (_, ps)) in plans.iter().enumerate() {
            for (c, p) in ps.iter().enumerate() {
                if !in_sel[ji][c]
                    && reduced_cost(y, nj, ji, p, kappa) <= slack
                {
                    sel[ji].push(c);
                    in_sel[ji][c] = true;
                    stats.columns_priced += 1;
                    widened = true;
                }
            }
        }
        if !widened {
            break 'solve Some(choices);
        }
        plan_selection_with_engine(
            &restrict(&sel), g_class, kappa, m_floor, fixed_area, warm,
            max_nodes, time_limit_s, gap, MilpEngine::Revised, obj, 0.0,
            trace, stats)
    };
    if let Some(state) = out_state {
        if choices.is_some() {
            for ((id, ps), s) in plans.iter().zip(&sel) {
                state.pools.insert(
                    *id,
                    s.iter()
                        .map(|&c| (ps[c].0, ps[c].1, ps[c].2))
                        .collect());
            }
            if let Some(y) = &duals {
                if y.len() == 2 * nj + g_class.len() {
                    for (ji, (id, _)) in plans.iter().enumerate() {
                        state
                            .job_duals
                            .insert(*id, (y[2 * ji], y[2 * ji + 1]));
                    }
                    state.area_duals = y[2 * nj..].to_vec();
                }
            }
            if let Some((b, keys)) = last_round {
                state.basis = Some(b);
                state.col_keys = keys;
                state.job_order =
                    plans.iter().map(|(id, _)| *id).collect();
            }
        }
    }
    choices
}

// ---------------------------------------------------------------------------
// Hierarchical cell sharding
// ---------------------------------------------------------------------------

/// Worker threads the sharded mode fans per-cell solves across. The
/// merge is order-preserving, so the count only changes wall time —
/// `sharded_probe` lets the props pin that down.
pub(crate) const SHARD_THREADS: usize = 4;

/// Per-cell MILP budgets: many small interactive solves, like rolling
/// windows but concurrent (same budgets — a cell is at most twice a
/// default window, and colgen shrinks its variable count well below
/// the window's full grid).
const CELL_MAX_NODES: usize = 4_000;
const CELL_TIME_LIMIT_S: f64 = 2.0;

/// Hierarchical sharding (DESIGN.md §4.8): a cheap top-level assigner
/// balances jobs across `ceil(n / cell_size)` cells by dominant-resource
/// pressure (LPT on each job's cheapest possible GPU-area), every cell
/// runs a column-generation solve against a proportional `1/cells`
/// slice of each class concurrently, and the picks merge back in job
/// order. A cell whose solve fails degrades to greedy on its slice —
/// counted, never silent. `stats.shard_gap` reports the merged
/// objective against the monolithic lower bound.
#[allow(clippy::too_many_arguments)]
fn sharded_choice(
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
    warm: Option<&SaturnPlan>,
    cell_size: usize,
    threads: usize,
    obj: &ObjSpec,
    trace: &Tracer,
    stats: &mut SolverStats,
) -> Option<Vec<JobPlan>> {
    sharded_choice_seeded(plans, g_class, kappa, warm, cell_size, threads,
                          obj, trace, stats, None, None)
}

/// [`sharded_choice`] with retained column-generation state: every cell
/// seeds its colgen from the SHARED `seed` (pools and duals are keyed by
/// job id, so any partition can consume them) and the per-cell converged
/// states merge back into `out_state` in cell order — deterministic for
/// any worker count, exactly like the pick merge.
#[allow(clippy::too_many_arguments)]
fn sharded_choice_seeded(
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
    warm: Option<&SaturnPlan>,
    cell_size: usize,
    threads: usize,
    obj: &ObjSpec,
    trace: &Tracer,
    stats: &mut SolverStats,
    seed: Option<&ColgenState>,
    out_state: Option<&mut ColgenState>,
) -> Option<Vec<JobPlan>> {
    if plans.is_empty() {
        return Some(Vec::new());
    }
    if plans.iter().any(|(_, ps)| ps.is_empty()) {
        return None;
    }
    let cell_size = cell_size.max(2);
    let n_cells = plans.len().div_ceil(cell_size);
    let traced = trace.is_enabled();
    if traced {
        trace.begin(
            "solver",
            "cells",
            Json::obj(vec![
                ("cells", Json::num(n_cells as f64)),
                ("cell_size", Json::num(cell_size as f64)),
            ]),
        );
    }
    // dominant-resource pressure: the cheapest GPU-area a job can run
    // at — what it must take from SOME class no matter which plan wins
    let pressure: Vec<f64> = plans
        .iter()
        .map(|(_, ps)| {
            ps.iter()
                .map(|p| p.1 as f64 * p.3)
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    // LPT balance: heaviest job onto the lightest cell with room; ties
    // break to the lowest index on both sides, so the partition is a
    // pure function of the input order
    let mut order: Vec<usize> = (0..plans.len()).collect();
    order.sort_by(|&a, &b| {
        pressure[b]
            .partial_cmp(&pressure[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut cells: Vec<Vec<usize>> = vec![Vec::new(); n_cells];
    let mut load = vec![0.0f64; n_cells];
    for &ji in &order {
        let ci = (0..n_cells)
            .filter(|&ci| cells[ci].len() < cell_size)
            .min_by(|&a, &b| {
                load[a]
                    .partial_cmp(&load[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("n_cells * cell_size >= jobs");
        cells[ci].push(ji);
        load[ci] += pressure[ji];
    }
    for members in &mut cells {
        members.sort_unstable(); // job order within the cell
    }
    // every cell plans against a proportional slice of each class; list
    // scheduling downstream still places on the full fleet, so a plan
    // larger than its slice only inflates the cell's M, never misplaces
    let share: Vec<f64> =
        g_class.iter().map(|g| g / n_cells as f64).collect();
    let zeros = vec![0.0; g_class.len()];
    let want_state = out_state.is_some();
    let solved: Vec<
        Option<(Vec<JobPlan>, SolverStats, Option<ColgenState>)>,
    > = scope_map(
        threads,
        (0..n_cells).collect(),
        |ci: usize| {
            let sub: Vec<(usize, Vec<Cand>)> = cells[ci]
                .iter()
                .map(|&ji| plans[ji].clone())
                .collect();
            let mut cstats = SolverStats::default();
            let mut cell_state = want_state.then(ColgenState::default);
            colgen_choice_seeded(&sub, &share, kappa, 0.0, &zeros, warm,
                                 CELL_MAX_NODES, CELL_TIME_LIMIT_S, 0.01,
                                 obj, &Tracer::off(), &mut cstats, seed,
                                 cell_state.as_mut())
                .map(|c| (c, cstats, cell_state))
        },
    );
    let mut all_proved = true;
    let mut merged: Vec<Option<JobPlan>> = vec![None; plans.len()];
    let mut cell_states: Vec<ColgenState> = Vec::new();
    for (ci, res) in solved.into_iter().enumerate() {
        let picks = match res {
            Some((picks, cstats, cstate)) => {
                all_proved &= cstats.proved_optimal;
                stats.merge_cell(&cstats);
                cell_states.extend(cstate);
                picks
            }
            None => {
                stats.greedy_fallbacks += 1;
                all_proved = false;
                let sub: Vec<(usize, Vec<Cand>)> = cells[ci]
                    .iter()
                    .map(|&ji| plans[ji].clone())
                    .collect();
                greedy_choice(&sub, &share, kappa)
            }
        };
        for (k, &ji) in cells[ci].iter().enumerate() {
            merged[ji] = Some(picks[k]);
        }
    }
    if let Some(state) = out_state {
        // cell-order merge: pools/duals are job-keyed (disjoint across
        // cells); the basis snapshot keeps the LAST cell's — any cell's
        // basis is only a warm-start hint for the next event
        for cs in cell_states {
            state.pools.extend(cs.pools);
            state.job_duals.extend(cs.job_duals);
            if !cs.area_duals.is_empty() {
                state.area_duals = cs.area_duals;
            }
            if cs.basis.is_some() {
                state.basis = cs.basis;
                state.job_order = cs.job_order;
                state.col_keys = cs.col_keys;
            }
        }
    }
    let choices: Vec<JobPlan> = merged
        .into_iter()
        .map(|o| o.expect("every job lands in exactly one cell"))
        .collect();
    stats.proved_optimal = all_proved;
    stats.cells = n_cells;
    stats.shard_gap = shard_gap(&choices, plans, g_class);
    if traced {
        trace.end(
            "solver",
            "cells",
            Json::obj(vec![
                ("columns_priced",
                 Json::num(stats.columns_priced as f64)),
                ("shard_gap", Json::num(stats.shard_gap)),
            ]),
        );
    }
    Some(choices)
}

/// Bound-relative gap of a sharded solution: the monolithic problem can
/// never beat max(longest fastest-candidate runtime, total minimum
/// GPU-area / total fleet GPUs), so the merged objective's distance to
/// that bound UPPER BOUNDS the loss vs the monolithic solve.
fn shard_gap(choices: &[JobPlan], plans: &[(usize, Vec<Cand>)],
             g_class: &[f64]) -> f64 {
    let obj = probe_objective(choices, g_class);
    let mut lb = 0.0f64;
    let mut min_area = 0.0f64;
    for (_, ps) in plans {
        let fastest =
            ps.iter().map(|p| p.3).fold(f64::INFINITY, f64::min);
        lb = lb.max(fastest);
        min_area += ps
            .iter()
            .map(|p| p.1 as f64 * p.3)
            .fold(f64::INFINITY, f64::min);
    }
    let total: f64 = g_class.iter().sum();
    lb = lb.max(min_area / total.max(1e-9));
    if lb <= 0.0 {
        return 0.0;
    }
    ((obj - lb) / lb).max(0.0)
}

// ---------------------------------------------------------------------------
// Level 1: plan selection
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn milp_choice(
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
    warm: Option<&SaturnPlan>,
    obj: &ObjSpec,
    trace: &Tracer,
    stats: &mut SolverStats,
) -> Option<Vec<JobPlan>> {
    let zeros = vec![0.0; g_class.len()];
    plan_selection_milp(plans, g_class, kappa, 0.0, &zeros, warm,
                        20_000, 10.0, obj, 0.0, trace, stats)
}

/// The plan-selection MILP over one slice of jobs. `m_floor` and
/// `fixed_area` (one entry per GPU class) carry the coupling from
/// already-committed rolling-horizon windows: M may not undercut a
/// committed job's runtime, and each class's GPU-area budget `G_k * M` is
/// charged for committed work on that class. `completion_offset` is the
/// committed congestion ahead of this window (seconds) — it shifts the
/// tardiness rows' completion proxy so later windows see their jobs as
/// later. Single-shot solves pass zeros. Returns one [`JobPlan`] per
/// input job, in input order.
#[allow(clippy::too_many_arguments)]
fn plan_selection_milp(
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
    m_floor: f64,
    fixed_area: &[f64],
    warm: Option<&SaturnPlan>,
    max_nodes: usize,
    time_limit_s: f64,
    obj: &ObjSpec,
    completion_offset: f64,
    trace: &Tracer,
    stats: &mut SolverStats,
) -> Option<Vec<JobPlan>> {
    plan_selection_with_engine(plans, g_class, kappa, m_floor, fixed_area,
                               warm, max_nodes, time_limit_s, 0.01,
                               MilpEngine::Revised, obj, completion_offset,
                               trace, stats)
}

#[allow(clippy::too_many_arguments)]
fn plan_selection_with_engine(
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
    m_floor: f64,
    fixed_area: &[f64],
    warm: Option<&SaturnPlan>,
    max_nodes: usize,
    time_limit_s: f64,
    gap: f64,
    engine: MilpEngine,
    obj: &ObjSpec,
    completion_offset: f64,
    trace: &Tracer,
    stats: &mut SolverStats,
) -> Option<Vec<JobPlan>> {
    debug_assert_eq!(g_class.len(), fixed_area.len());
    // variable layout: x_{j,c} ... , M, then one tardiness epigraph
    // variable per DEADLINED job under WeightedTardiness (sparse: the
    // makespan/wjct formulations add no variables at all, keeping the
    // historical layout bit for bit)
    let mut var = 0usize;
    let mut index: Vec<Vec<usize>> = Vec::new();
    for (_, ps) in plans {
        index.push((var..var + ps.len()).collect());
        var += ps.len();
    }
    let m_var = var;
    let makespan_like = obj.makespan_like();
    let use_tardiness = !makespan_like
        && matches!(obj.objective, Objective::WeightedTardiness { .. });
    let mut tard_var: Vec<Option<usize>> = vec![None; plans.len()];
    let mut n = var + 1;
    if use_tardiness {
        for (ji, (id, _)) in plans.iter().enumerate() {
            if obj.term(*id).due_in_s.is_some() {
                tard_var[ji] = Some(n);
                n += 1;
            }
        }
    }

    let mut lp = Lp::new(n);
    // objective coefficients (DESIGN.md §4.5): weights are normalized
    // by their sum so the tardiness/completion terms stay in the same
    // seconds scale as M no matter how many jobs the slice holds
    match obj.objective {
        _ if makespan_like => lp.set_obj(m_var, 1.0),
        Objective::Makespan => lp.set_obj(m_var, 1.0),
        Objective::WeightedTardiness { deadline_weight } => {
            lp.set_obj(m_var, 1.0);
            let w_sum: f64 = plans
                .iter()
                .map(|(id, _)| obj.term(*id).weight.max(0.0))
                .sum::<f64>()
                .max(1e-9);
            for (ji, (id, _)) in plans.iter().enumerate() {
                if let Some(tv) = tard_var[ji] {
                    let w = obj.term(*id).weight.max(0.0) / w_sum;
                    lp.set_obj(tv, deadline_weight * w);
                }
            }
        }
        Objective::WeightedJct { alpha } => {
            let alpha = alpha.clamp(0.0, 1.0);
            lp.set_obj(m_var, alpha);
            let w_sum: f64 = plans
                .iter()
                .map(|(id, _)| obj.term(*id).weight.max(0.0))
                .sum::<f64>()
                .max(1e-9);
            // completion proxy: sunk waiting time is a per-job
            // constant and drops out of the argmin, so C_j reduces to
            // the remaining runtime and the blend lands directly on
            // the plan binaries
            for (ji, (id, ps)) in plans.iter().enumerate() {
                let w = obj.term(*id).weight.max(0.0) / w_sum;
                for (c, p) in ps.iter().enumerate() {
                    lp.set_obj(index[ji][c],
                               (1.0 - alpha) * w * (p.3 / kappa));
                }
            }
        }
    }
    lp.bound_ge(m_var, m_floor);
    // assignment + critical path per job
    for (ji, (_, ps)) in plans.iter().enumerate() {
        if ps.is_empty() {
            return None; // job with no feasible plan: give up to greedy
        }
        lp.add(index[ji].iter().map(|&v| (v, 1.0)).collect(), Cmp::Eq, 1.0);
        // critical path, discounted by the introspection lookahead kappa
        let mut cp: Vec<(usize, f64)> = ps
            .iter()
            .enumerate()
            .map(|(c, p)| (index[ji][c], p.3 / kappa))
            .collect();
        cp.push((m_var, -1.0));
        lp.add(cp, Cmp::Le, 0.0);
    }
    // one area bound PER CLASS, charged for work committed on that class
    // by earlier windows:   sum_{c in k} g t x - G_k M <= -fixed_area_k
    for (ci, (&g_k, &fixed_k)) in
        g_class.iter().zip(fixed_area).enumerate()
    {
        let mut area: Vec<(usize, f64)> = Vec::new();
        for (ji, (_, ps)) in plans.iter().enumerate() {
            for (c, p) in ps.iter().enumerate() {
                if p.2 == ci {
                    area.push((index[ji][c], p.1 as f64 * p.3));
                }
            }
        }
        area.push((m_var, -g_k));
        lp.add(area, Cmp::Le, -fixed_k);
    }
    // linearized tardiness rows (WeightedTardiness only): with the
    // completion proxy C_j = offset + runtime_j, the epigraph
    //   T_j >= C_j - due_j,  T_j >= 0 (default bound)
    // becomes  sum_c (t_jc / kappa) x_jc - T_j <= due_j - offset —
    // ONE extra row per deadlined job, so the matrix stays sparse
    if use_tardiness {
        for (ji, (id, ps)) in plans.iter().enumerate() {
            let Some(tv) = tard_var[ji] else { continue };
            let due = obj.term(*id).due_in_s.expect("tard var has due");
            let mut row: Vec<(usize, f64)> = ps
                .iter()
                .enumerate()
                .map(|(c, p)| (index[ji][c], p.3 / kappa))
                .collect();
            row.push((tv, -1.0));
            lp.add(row, Cmp::Le, due - completion_offset);
        }
    }
    // binaries: first-class variable bounds, NOT rows — with the revised
    // simplex this keeps the row count at 2*jobs + n_classes
    // (+ deadlined jobs under WeightedTardiness)
    for vs in &index {
        for &v in vs {
            lp.bound_le(v, 1.0);
        }
    }

    // Warm start: translate the previous plan into an incumbent vector.
    // Every job needs exactly one plan set; arrivals absent from the old
    // plan (and stale choices pruned off the candidate set) fall back to
    // the min-GPU candidate, which always satisfies the area bounds
    // together with the matching makespan value for M.
    let warm_x = warm.map(|prev| {
        let mut x = vec![0.0; n];
        let mut longest = 0.0f64;
        let mut areas = vec![0.0f64; g_class.len()];
        for (ji, (id, ps)) in plans.iter().enumerate() {
            let c = prev
                .plan_for(*id)
                .and_then(|jp| {
                    ps.iter().position(|&(t, g, cl, _)| {
                        (t, g, cl) == (jp.tech, jp.gpus, jp.class)
                    })
                })
                .unwrap_or(0);
            x[index[ji][c]] = 1.0;
            let (_, g, cl, t) = ps[c];
            longest = longest.max(t / kappa);
            areas[cl] += g as f64 * t;
        }
        let area_m = areas
            .iter()
            .zip(g_class)
            .zip(fixed_area)
            .map(|((a, g), f)| (a + f) / g.max(1e-9))
            .fold(0.0f64, f64::max);
        x[m_var] = longest.max(area_m).max(m_floor);
        if use_tardiness {
            // tardiness epigraph values matching the seeded choices
            for (ji, (id, ps)) in plans.iter().enumerate() {
                let Some(tv) = tard_var[ji] else { continue };
                let due = obj.term(*id).due_in_s.expect("due set");
                let c = (0..ps.len())
                    .find(|&c| x[index[ji][c]] > 0.5)
                    .unwrap_or(0);
                x[tv] = (ps[c].3 / kappa - (due - completion_offset))
                    .max(0.0);
            }
        }
        x
    });
    stats.warm_used = stats.warm_used || warm_x.is_some();

    let ints: Vec<usize> = index.iter().flatten().copied().collect();
    // scope_map spawns scoped threads per node batch, so parallelism only
    // pays once node LPs are ms-scale: big single-shot formulations.
    // Rolling windows (<= ~230 vars, microsecond warm re-solves) would
    // lose more to spawn/join than they gain — keep them serial.
    let threads = if n >= 256 { 4 } else { 1 };
    let (deadline_ms, node_budget) = obj.remaining_budget(stats);
    let opts = MilpOptions {
        gap,
        max_nodes,
        time_limit_s,
        warm_start: warm_x,
        threads,
        engine,
        // anytime budgets: the REMAINING allowance at this dispatch
        // (None without a budget — the historical limits, bit for bit)
        deadline_ms,
        node_budget,
        // root strong branching stays off here: warm-started event-rate
        // re-solves already prune from a seeded incumbent, and k > 0
        // would perturb the bit-exact makespan replays the benches pin
        strong_branch_k: 0,
        trace: trace.clone(),
    };
    let (result, milp_stats) = solve_with_stats(&lp, &ints, &opts);
    stats.absorb(&milp_stats);
    match result {
        MilpResult::Solved { x, proved_optimal, .. } => {
            stats.proved_optimal = proved_optimal;
            if !proved_optimal {
                stats.limit_reached += 1;
            }
            let mut out = Vec::new();
            for (ji, (id, ps)) in plans.iter().enumerate() {
                let c = (0..ps.len())
                    .find(|&c| x[index[ji][c]] > 0.5)
                    .unwrap_or(0);
                let (tech, gpus, class, runtime) = ps[c];
                out.push(JobPlan {
                    job_id: *id,
                    tech,
                    gpus,
                    class,
                    runtime_s: runtime,
                });
            }
            Some(out)
        }
        MilpResult::LimitReached { .. } => {
            stats.limit_reached += 1;
            None
        }
        _ => None,
    }
}

/// Rolling-horizon decomposition: windows of `window` jobs over a
/// dominance ordering (longest min-GPU runtime first), committing all but
/// the trailing `overlap` jobs per solve. Each window re-optimizes the
/// overlap jointly with the next slice, and inherits the committed
/// makespan floor + per-class GPU areas, so window boundaries cannot
/// starve or oversubscribe any class. Per-window MILPs get tight
/// node/time budgets — the point is many small interactive solves, not
/// one big one.
///
/// Objective-aware windows: under `WeightedTardiness` the dominance
/// order becomes least-slack-first (urgent jobs reach an early — thus
/// early-completing — window), under `WeightedJct` it becomes
/// weight-per-second-first, and every window solve receives the
/// committed congestion ahead of it as a completion offset so its
/// tardiness rows see the window's true lateness. Makespan keeps the
/// historical order and ignores the offset — bit for bit.
#[allow(clippy::too_many_arguments)]
fn rolling_choice(
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
    warm: Option<&SaturnPlan>,
    window: usize,
    overlap: usize,
    obj: &ObjSpec,
    trace: &Tracer,
    stats: &mut SolverStats,
) -> Option<Vec<JobPlan>> {
    let window = window.max(2);
    let overlap = overlap.min(window - 1);
    if plans.iter().any(|(_, ps)| ps.is_empty()) {
        return None;
    }
    // dominance order: longest min-GPU runtime first (ties: job order, so
    // replays are deterministic — sort_by is stable); non-makespan
    // objectives rank by urgency instead (see above)
    let mut order: Vec<usize> = (0..plans.len()).collect();
    let makespan_like = obj.makespan_like();
    order.sort_by(|&a, &b| {
        let ta = plans[a].1.first().map(|p| p.3).unwrap_or(0.0);
        let tb = plans[b].1.first().map(|p| p.3).unwrap_or(0.0);
        let longest =
            tb.partial_cmp(&ta).unwrap_or(std::cmp::Ordering::Equal);
        if makespan_like {
            return longest;
        }
        let key = |ji: usize, t: f64| {
            let term = obj.term(plans[ji].0);
            // due_in_s is already relative to the solve instant, so
            // arrival = now = 0 makes the key the plain slack
            // `due - runtime` (or -w/runtime under the JCT blend)
            obj.objective
                .urgency_key(term.weight, t, 0.0, term.due_in_s, 0.0)
                .unwrap_or(0.0)
        };
        key(a, ta)
            .partial_cmp(&key(b, tb))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(longest)
    });

    let mut chosen: Vec<Option<JobPlan>> = vec![None; plans.len()];
    let mut fixed_area = vec![0.0f64; g_class.len()];
    let mut m_floor = 0.0f64;
    let mut k = 0usize;
    while k < order.len() {
        let hi = (k + window).min(order.len());
        let slice: Vec<(usize, Vec<Cand>)> = order[k..hi]
            .iter()
            .map(|&ji| plans[ji].clone())
            .collect();
        // per-window completion offset: committed work ahead of this
        // window delays its jobs by at least the worst per-class
        // congestion (unused by makespan-like windows)
        let completion_offset = fixed_area
            .iter()
            .zip(g_class)
            .map(|(a, g)| a / g.max(1e-9))
            .fold(0.0f64, f64::max);
        if trace.is_enabled() {
            trace.begin(
                "solver",
                "window",
                Json::obj(vec![
                    ("index", Json::num(stats.windows as f64)),
                    ("jobs", Json::num(slice.len() as f64)),
                ]),
            );
        }
        let picks = match plan_selection_milp(
            &slice, g_class, kappa, m_floor, &fixed_area, warm, 4_000,
            2.0, obj, completion_offset, trace, stats)
        {
            Some(p) => p,
            None => {
                // keep the span balanced before bubbling the failure
                // up to the greedy fallback
                if trace.is_enabled() {
                    trace.end(
                        "solver",
                        "window",
                        Json::obj(vec![("failed", Json::Bool(true))]),
                    );
                }
                return None;
            }
        };
        stats.windows += 1;
        // commit everything except the overlap tail (the final window
        // commits everything)
        let commit = if hi == order.len() {
            hi - k
        } else {
            (hi - k).saturating_sub(overlap).max(1)
        };
        for (offset, jp) in picks.into_iter().enumerate().take(commit) {
            let ji = order[k + offset];
            fixed_area[jp.class] += jp.gpus as f64 * jp.runtime_s;
            m_floor = m_floor.max(jp.runtime_s / kappa);
            chosen[ji] = Some(jp);
        }
        k += commit;
        if trace.is_enabled() {
            trace.end(
                "solver",
                "window",
                Json::obj(vec![("committed", Json::num(commit as f64))]),
            );
        }
    }
    chosen.into_iter().collect()
}

/// Greedy: start every job at its slowest/cheapest candidate, then spend
/// the remaining per-class "area budget" on the job that currently bounds
/// the makespan.
fn greedy_choice(
    plans: &[(usize, Vec<Cand>)],
    g_class: &[f64],
    kappa: f64,
) -> Vec<JobPlan> {
    let mut pick: Vec<usize> = plans.iter().map(|_| 0).collect();
    for _ in 0..64 {
        // current makespan bound = max(longest job, max_k area_k/G_k)
        let Some(longest_ji) = (0..plans.len()).max_by(|&a, &b| {
            let ta = plans[a].1.get(pick[a]).map(|p| p.3).unwrap_or(0.0);
            let tb = plans[b].1.get(pick[b]).map(|p| p.3).unwrap_or(0.0);
            ta.partial_cmp(&tb).unwrap()
        }) else {
            break; // no jobs: nothing to upgrade
        };
        let mut areas = vec![0.0f64; g_class.len()];
        for ji in 0..plans.len() {
            if let Some(p) = plans[ji].1.get(pick[ji]) {
                areas[p.2] += p.1 as f64 * p.3;
            }
        }
        let area_bound = areas
            .iter()
            .zip(g_class)
            .map(|(a, g)| a / g.max(1e-9))
            .fold(0.0f64, f64::max);
        let longest = plans[longest_ji].1.get(pick[longest_ji])
            .map(|p| p.3).unwrap_or(0.0);
        if area_bound >= longest / kappa {
            break; // area-bound: more GPUs per job only adds area
        }
        // upgrade the critical job if a bigger plan exists
        if pick[longest_ji] + 1 < plans[longest_ji].1.len() {
            pick[longest_ji] += 1;
        } else {
            break;
        }
    }
    plans
        .iter()
        .zip(&pick)
        .filter(|((_, ps), _)| !ps.is_empty())
        .map(|((id, ps), &c)| {
            let (tech, gpus, class, runtime) = ps[c];
            JobPlan { job_id: *id, tech, gpus, class, runtime_s: runtime }
        })
        .collect()
}

/// Exact time-indexed MILP (x_{j,c,s}); small instances only.
fn exact_slot_choice(
    plans: &[(usize, Vec<Cand>)],
    cluster: &ClusterSpec,
    slots: usize,
    trace: &Tracer,
    stats: &mut SolverStats,
) -> Option<Vec<JobPlan>> {
    let g_class = class_capacities(cluster);
    // horizon: makespan of the greedy schedule
    let greedy =
        build_schedule(greedy_choice(plans, &g_class, 1.0), cluster);
    let horizon = greedy.predicted_makespan_s * 1.25 + 1.0;
    let dt = horizon / slots as f64;

    // variables: x_{j,c,s} + M
    let mut var = 0usize;
    let mut idx: Vec<Vec<Vec<usize>>> = Vec::new(); // [j][c][s]
    for (_, ps) in plans {
        let mut per_c = Vec::new();
        for _ in ps {
            per_c.push((0..slots).map(|s| var + s).collect());
            var += slots;
        }
        idx.push(per_c);
    }
    let m_var = var;
    let n = var + 1;
    let mut lp = Lp::new(n);
    lp.set_obj(m_var, 1.0);

    for (ji, (_, ps)) in plans.iter().enumerate() {
        if ps.is_empty() {
            return None;
        }
        // one (plan, start)
        let all: Vec<(usize, f64)> = idx[ji]
            .iter()
            .flatten()
            .map(|&v| (v, 1.0))
            .collect();
        lp.add(all, Cmp::Eq, 1.0);
        // makespan: start*dt + t <= M  (big-M linearization)
        let big = horizon * 2.0;
        for (c, p) in ps.iter().enumerate() {
            for s in 0..slots {
                lp.add(
                    vec![(idx[ji][c][s], s as f64 * dt + p.3 + big),
                         (m_var, -1.0)],
                    Cmp::Le,
                    big,
                );
            }
        }
    }
    // capacity per (slot, class)
    for slot in 0..slots {
        for (ci, &g_k) in g_class.iter().enumerate() {
            let mut cap: Vec<(usize, f64)> = Vec::new();
            for (ji, (_, ps)) in plans.iter().enumerate() {
                for (c, p) in ps.iter().enumerate() {
                    if p.2 != ci {
                        continue;
                    }
                    let dur_slots = (p.3 / dt).ceil() as usize;
                    // job occupies `slot` if it started in (slot-dur, slot]
                    let lo = slot.saturating_sub(dur_slots.saturating_sub(1));
                    for s in lo..=slot {
                        cap.push((idx[ji][c][s], p.1 as f64));
                    }
                }
            }
            if !cap.is_empty() {
                lp.add(cap, Cmp::Le, g_k);
            }
        }
    }
    for vs in idx.iter().flatten().flatten() {
        lp.bound_le(*vs, 1.0);
    }

    let ints: Vec<usize> = idx.iter().flatten().flatten().copied().collect();
    let opts = MilpOptions {
        gap: 1e-3,
        max_nodes: 50_000,
        time_limit_s: 20.0,
        trace: trace.clone(),
        ..Default::default()
    };
    match milp_solve(&lp, &ints, &opts) {
        MilpResult::Solved { x, nodes, proved_optimal, .. } => {
            stats.milp_nodes += nodes;
            stats.proved_optimal = proved_optimal;
            let mut out = Vec::new();
            for (ji, (id, ps)) in plans.iter().enumerate() {
                let mut found = None;
                for (c, p) in ps.iter().enumerate() {
                    for s in 0..slots {
                        if x[idx[ji][c][s]] > 0.5 {
                            found = Some((c, *p));
                        }
                    }
                }
                let (_, (tech, gpus, class, runtime)) = found?;
                out.push(JobPlan {
                    job_id: *id,
                    tech,
                    gpus,
                    class,
                    runtime_s: runtime,
                });
            }
            Some(out)
        }
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Level 2: list scheduling + local search
// ---------------------------------------------------------------------------

/// LPT first-fit simulation of the chosen plans; fills `order` and
/// `predicted_makespan_s`.
pub fn build_schedule(mut choices: Vec<JobPlan>, cluster: &ClusterSpec)
    -> SaturnPlan {
    choices.sort_by(|a, b| b.runtime_s.partial_cmp(&a.runtime_s).unwrap());
    let order: Vec<usize> = choices.iter().map(|p| p.job_id).collect();
    let lower = lower_bound(&choices, cluster);
    let makespan = simulate_list(&choices, cluster);
    SaturnPlan {
        choices,
        order,
        lower_bound_s: lower,
        predicted_makespan_s: makespan,
    }
}

fn lower_bound(choices: &[JobPlan], cluster: &ClusterSpec) -> f64 {
    let longest = choices.iter().map(|p| p.runtime_s).fold(0.0, f64::max);
    (0..cluster.n_classes())
        .map(|ci| {
            let area: f64 = choices
                .iter()
                .filter(|p| p.class == ci)
                .map(|p| p.gpus as f64 * p.runtime_s)
                .sum();
            area / cluster.class_gpus(ci).max(1) as f64
        })
        .fold(longest, f64::max)
}

/// Fast list-schedule makespan (same per-class placement rules as the
/// simulator).
fn simulate_list(choices: &[JobPlan], cluster: &ClusterSpec) -> f64 {
    let mut free = FreeState::new(cluster);
    let mut running: Vec<(f64, Vec<crate::sim::placement::Placement>)> =
        Vec::new(); // (finish, placement)
    let mut pending: Vec<&JobPlan> = choices.iter().collect();
    let mut now = 0.0f64;
    let mut makespan = 0.0f64;
    while !pending.is_empty() || !running.is_empty() {
        // launch whatever fits, in order (backfill allowed)
        pending.retain(|p| {
            if let Some(pl) = free.place(p.class, p.gpus) {
                let fin = now + p.runtime_s;
                makespan = makespan.max(fin);
                running.push((fin, pl));
                false
            } else {
                true
            }
        });
        if running.is_empty() {
            break; // nothing runnable (shouldn't happen with valid plans)
        }
        // advance to next completion
        let (i, _) = running
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .unwrap();
        let (fin, pl) = running.swap_remove(i);
        now = fin;
        free.release(&pl);
    }
    makespan
}

/// Coordinate-descent repair on the REALIZED list schedule: the MILP's
/// area/critical-path relaxation ignores packing losses, so sweep every
/// job's alternatives against the simulated schedule and keep improvements.
/// This is what turns "good on paper" plans into good makespans (and where
/// Saturn's joint view beats per-job greedy allocation). On mixed fleets
/// the alternatives include cross-class moves.
fn local_search(
    plan: &mut SaturnPlan,
    plans: &[(usize, Vec<Cand>)],
    cluster: &ClusterSpec,
) {
    for _sweep in 0..64 {
        let mut improved = false;
        // visit jobs by schedule impact (longest runtime first)
        let mut order: Vec<usize> = (0..plan.choices.len()).collect();
        order.sort_by(|&a, &b| {
            plan.choices[b]
                .runtime_s
                .partial_cmp(&plan.choices[a].runtime_s)
                .unwrap()
        });
        for pos in order {
            let job_id = plan.choices[pos].job_id;
            let Some((_, alts)) = plans.iter().find(|(id, _)| *id == job_id)
            else {
                continue;
            };
            let mut best = plan.predicted_makespan_s;
            let mut best_plan: Option<SaturnPlan> = None;
            for &(tech, gpus, class, runtime) in alts {
                let cur = &plan.choices[pos];
                if (tech, gpus, class) == (cur.tech, cur.gpus, cur.class) {
                    continue;
                }
                let mut cand = plan.choices.clone();
                cand[pos] = JobPlan {
                    job_id,
                    tech,
                    gpus,
                    class,
                    runtime_s: runtime,
                };
                let new_plan = build_schedule(cand, cluster);
                if new_plan.predicted_makespan_s < best - 1e-9 {
                    best = new_plan.predicted_makespan_s;
                    best_plan = Some(new_plan);
                }
            }
            if let Some(p) = best_plan {
                // positions shift after rebuild; restart the sweep ordering
                *plan = p;
                improved = true;
                break;
            }
        }
        if !improved {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallelism::default_library;
    use crate::trials::profile_analytic;
    use crate::workload::{toy_workload, wikitext_workload};

    fn setup(nodes: u32)
        -> (Vec<crate::workload::Job>, ProfileTable, ClusterSpec) {
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::p4d(nodes);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        (jobs, profiles, cluster)
    }

    fn remaining(jobs: &[crate::workload::Job]) -> Vec<(usize, u64)> {
        jobs.iter().map(|j| (j.id, j.total_steps())).collect()
    }

    #[test]
    fn joint_plans_every_job() {
        let (jobs, profiles, cluster) = setup(1);
        let (plan, stats) = solve_joint(&remaining(&jobs), &profiles,
                                        &cluster, SolverMode::Joint);
        assert_eq!(plan.choices.len(), 12);
        assert_eq!(plan.order.len(), 12);
        assert!(plan.predicted_makespan_s >= plan.lower_bound_s * 0.999);
        assert!(stats.wall_s < 10.0);
    }

    #[test]
    fn joint_beats_or_matches_greedy() {
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (joint, _) = solve_joint(&rem, &profiles, &cluster, SolverMode::Joint);
        let (greedy, _) =
            solve_joint(&rem, &profiles, &cluster, SolverMode::Heuristic);
        assert!(joint.predicted_makespan_s
                <= greedy.predicted_makespan_s * 1.001,
                "joint {} greedy {}", joint.predicted_makespan_s,
                greedy.predicted_makespan_s);
    }

    #[test]
    fn two_nodes_shorter_than_one() {
        let (jobs, p1, c1) = setup(1);
        let (_, p2, c2) = {
            let cluster = ClusterSpec::p4d(2);
            let lib = default_library();
            let p = profile_analytic(&jobs, &lib, &cluster);
            (jobs.clone(), p, cluster)
        };
        let rem = remaining(&jobs);
        let (m1, _) = solve_joint(&rem, &p1, &c1, SolverMode::Joint);
        let (m2, _) = solve_joint(&rem, &p2, &c2, SolverMode::Joint);
        assert!(m2.predicted_makespan_s < m1.predicted_makespan_s);
    }

    #[test]
    fn mixed_allocations_appear() {
        // the paper's "unintuitive" plans: not everything gets 8 GPUs
        let (jobs, profiles, cluster) = setup(1);
        let (plan, _) = solve_joint(&remaining(&jobs), &profiles, &cluster,
                                    SolverMode::Joint);
        let gpus: std::collections::BTreeSet<u32> =
            plan.choices.iter().map(|p| p.gpus).collect();
        assert!(gpus.len() > 1, "all jobs got identical allocations: {gpus:?}");
    }

    #[test]
    fn exact_slots_close_to_joint_on_small_instance() {
        let jobs = toy_workload(4);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let (joint, _) = solve_joint(&rem, &profiles, &cluster, SolverMode::Joint);
        let (exact, _) = solve_joint(&rem, &profiles, &cluster,
                                     SolverMode::ExactSlots { slots: 6 });
        // exact formulation should not be dramatically worse than the
        // decomposition (coarse slots cost some rounding)
        assert!(exact.predicted_makespan_s
                <= joint.predicted_makespan_s * 1.6 + 1.0,
                "exact {} joint {}", exact.predicted_makespan_s,
                joint.predicted_makespan_s);
    }

    #[test]
    fn warm_start_matches_cold_quality() {
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (cold, _) = solve_joint(&rem, &profiles, &cluster, SolverMode::Joint);
        let (warm, warm_stats) = solve_joint_warm(&rem, &profiles, &cluster,
                                                  SolverMode::Joint, 1.0,
                                                  Some(&cold));
        assert!(warm_stats.warm_used);
        assert!(warm.predicted_makespan_s
                <= cold.predicted_makespan_s * 1.001,
                "warm {} vs cold {}", warm.predicted_makespan_s,
                cold.predicted_makespan_s);
    }

    #[test]
    fn warm_start_tolerates_arrivals_and_departures() {
        // warm plan covers a different job set: overlaps warm-start, new
        // arrivals fall back to min-GPU plans, departures are dropped
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (prev, _) = solve_joint(&rem[..6], &profiles, &cluster,
                                    SolverMode::Joint);
        let (plan, stats) = solve_joint_warm(&rem[3..], &profiles, &cluster,
                                             SolverMode::Joint, 1.0,
                                             Some(&prev));
        assert!(stats.warm_used);
        assert_eq!(plan.choices.len(), rem.len() - 3);
        assert!(plan.predicted_makespan_s >= plan.lower_bound_s * 0.999);
    }

    #[test]
    fn probe_engines_prove_identical_objectives() {
        // the rebuilt solver must return objective-identical results to
        // the seed dense path at a tight gap (tolerance 1e-6)
        let jobs = toy_workload(8);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let (a, _) = plan_selection_probe(&rem, &profiles, &cluster,
                                          MilpEngine::Revised)
            .expect("revised probe");
        let (b, _) = plan_selection_probe(&rem, &profiles, &cluster,
                                          MilpEngine::DenseReference)
            .expect("reference probe");
        assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0),
                "revised {a} vs seed {b}");
    }

    #[test]
    fn degenerate_single_class_matches_pooled_formulation() {
        // acceptance bar: an all-A100 fleet routed through the per-class
        // machinery yields the pre-change (pooled) solver's objective
        // within 1e-6
        for nodes in [1u32, 2] {
            let jobs = toy_workload(8);
            let cluster = ClusterSpec::p4d(nodes);
            let lib = default_library();
            let profiles = profile_analytic(&jobs, &lib, &cluster);
            let rem: Vec<(usize, u64)> =
                jobs.iter().map(|j| (j.id, j.total_steps())).collect();
            let (per_class, _) = plan_selection_probe(
                &rem, &profiles, &cluster, MilpEngine::Revised)
                .expect("per-class probe");
            let (pooled, _) = plan_selection_probe_pooled(
                &rem, &profiles, &cluster, MilpEngine::Revised)
                .expect("pooled probe");
            assert!((per_class - pooled).abs()
                        <= 1e-6 * pooled.abs().max(1.0),
                    "{nodes} node(s): per-class {per_class} vs pooled {pooled}");
        }
    }

    #[test]
    fn hetero_fleet_plans_use_both_classes() {
        // with 12 jobs and two one-node classes, the joint solver should
        // spread work across classes (leaving the H100 idle forfeits 3x
        // the FLOPs of the A100 node)
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::hetero(1, 1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let (plan, _) = solve_joint(&remaining(&jobs), &profiles, &cluster,
                                    SolverMode::Joint);
        assert_eq!(plan.choices.len(), 12);
        let classes: std::collections::BTreeSet<usize> =
            plan.choices.iter().map(|p| p.class).collect();
        assert_eq!(classes.len(), 2,
                   "solver left a whole class idle: {classes:?}");
        // per-class area never exceeds what the class can host by M
        for ci in 0..cluster.n_classes() {
            assert!(plan.area_in_class(ci)
                        <= cluster.class_gpus(ci) as f64
                            * plan.predicted_makespan_s + 1e-6);
        }
    }

    #[test]
    fn hetero_fleet_beats_its_a100_half() {
        let jobs = wikitext_workload();
        let lib = default_library();
        let rem = remaining(&jobs);
        let small = ClusterSpec::p4d(1);
        let p_small = profile_analytic(&jobs, &lib, &small);
        let (m_small, _) = solve_joint(&rem, &p_small, &small,
                                       SolverMode::Joint);
        let mixed = ClusterSpec::hetero(1, 1);
        let p_mixed = profile_analytic(&jobs, &lib, &mixed);
        let (m_mixed, _) = solve_joint(&rem, &p_mixed, &mixed,
                                       SolverMode::Joint);
        assert!(m_mixed.predicted_makespan_s < m_small.predicted_makespan_s,
                "adding an H100 node did not help: {} vs {}",
                m_mixed.predicted_makespan_s, m_small.predicted_makespan_s);
    }

    #[test]
    fn job_fitting_no_class_is_shed_not_a_panic() {
        use crate::models::{DatasetSpec, ModelSpec};
        use crate::workload::Job;
        // a pathological model whose activation checkpoints alone overflow
        // every class: even offload at full fleet width is infeasible.
        // The solver must shed it and keep planning the feasible jobs —
        // a fleet that degrades mid-run never aborts the process.
        let mut model = ModelSpec::gpt2_xl();
        model.hidden = 1_000_000;
        model.act_bytes_per_sample = 1e15;
        let monster = Job {
            id: 0,
            name: "monster".into(),
            model,
            dataset: DatasetSpec { name: "toy".into(), samples: 64 },
            lr: 1e-4,
            batch: 16,
            epochs: 1,
        };
        let mut jobs = vec![monster];
        for (i, mut j) in wikitext_workload().into_iter().take(3).enumerate()
        {
            j.id = i + 1;
            jobs.push(j);
        }
        let cluster = ClusterSpec::hetero(1, 1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let (plan, stats) =
            solve_joint(&rem, &profiles, &cluster, SolverMode::Joint);
        assert_eq!(stats.shed_jobs, 1, "the monster job was not shed");
        assert!(plan.plan_for(0).is_none(),
                "an infeasible job appeared in the plan");
        assert_eq!(plan.choices.len(), 3,
                   "feasible jobs were not planned after the shed");
        // check_fleet_feasibility still reports it for the CLI's bail
        assert!(check_fleet_feasibility(&rem, &profiles, &cluster)
                    .unwrap_err()
                    .contains("fit no GPU class"));
    }

    #[test]
    fn degraded_live_capacity_changes_the_plan_not_the_process() {
        // halve class 0's live capacity: the solve must stay panic-free
        // and the area packed into class 0 must respect the degraded
        // budget; a zeroed class simply pushes work to the other one
        let jobs = wikitext_workload();
        let lib = default_library();
        let cluster = ClusterSpec::hetero(1, 1);
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem = remaining(&jobs);
        let full = class_capacities(&cluster);
        let degraded = vec![0.0, full[1]];
        let (plan, _) = solve_joint_live(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::Makespan, &[], &Tracer::off(), Some(&degraded));
        assert_eq!(plan.choices.len(), jobs.len());
        // with class 0 dead, the MILP packs everything into class 1
        // (jobs feasible only on class 0 would be the fallback's
        // problem; this workload fits both)
        let in_dead: f64 = plan.area_in_class(0);
        let (plan_full, _) = solve_joint(&rem, &profiles, &cluster,
                                         SolverMode::Joint);
        assert!(in_dead <= plan_full.area_in_class(0),
                "degraded capacity did not discourage the dead class");
        // mismatched live slice falls back to static capacities
        let (plan_bad, _) = solve_joint_live(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::Makespan, &[], &Tracer::off(), Some(&[1.0]));
        assert_eq!(plan_bad.predicted_makespan_s.to_bits(),
                   plan_full.predicted_makespan_s.to_bits());
    }

    #[test]
    fn seed_reference_path_still_plans_every_job() {
        let (jobs, profiles, cluster) = setup(1);
        let (plan, stats) =
            solve_joint_reference(&remaining(&jobs), &profiles, &cluster);
        assert_eq!(plan.choices.len(), 12);
        assert!(plan.predicted_makespan_s >= plan.lower_bound_s * 0.999);
        assert!(stats.warm_hits == 0,
                "the seed path must not warm-start node LPs");
    }

    #[test]
    fn solver_stats_report_warm_basis_reuse() {
        // the branch-and-bound must re-solve child nodes from parent
        // bases: a non-zero warm-start hit rate plus pivot accounting
        let (jobs, profiles, cluster) = setup(1);
        let (_, stats) = solve_joint(&remaining(&jobs), &profiles, &cluster,
                                     SolverMode::Joint);
        assert!(stats.warm_hits > 0, "no warm-basis node solves");
        assert!(stats.warm_hit_rate() > 0.0);
        assert!(stats.lp_pivots > 0);
        assert_eq!(stats.windows, 0, "single-shot solve has no windows");
    }

    #[test]
    fn rolling_horizon_plans_every_job_and_respects_bounds() {
        let jobs = toy_workload(40);
        let cluster = ClusterSpec::p4d(2);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let (plan, stats) = solve_joint(
            &rem, &profiles, &cluster,
            SolverMode::RollingHorizon { window: 16, overlap: 4 });
        assert_eq!(plan.choices.len(), 40);
        assert!(stats.windows >= 2, "expected several windows, got {}",
                stats.windows);
        assert!(plan.predicted_makespan_s >= plan.lower_bound_s - 1e-6);
        assert!(plan.predicted_makespan_s
                >= plan.area() / cluster.total_gpus() as f64 - 1e-6);
    }

    #[test]
    fn rolling_horizon_on_mixed_fleet_tracks_class_budgets() {
        let jobs = toy_workload(40);
        let cluster = ClusterSpec::hetero(1, 1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let (plan, stats) = solve_joint(
            &rem, &profiles, &cluster,
            SolverMode::RollingHorizon { window: 16, overlap: 4 });
        assert_eq!(plan.choices.len(), 40);
        assert!(stats.windows >= 2);
        // the committed-area coupling is per class: neither class's area
        // may exceed its own G_k * M
        for ci in 0..cluster.n_classes() {
            assert!(plan.area_in_class(ci)
                        <= cluster.class_gpus(ci) as f64
                            * plan.predicted_makespan_s + 1e-6,
                    "class {ci} oversubscribed");
        }
    }

    #[test]
    fn rolling_horizon_quality_tracks_joint_on_medium_instances() {
        let jobs = toy_workload(24);
        let cluster = ClusterSpec::p4d(2);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let (joint, _) = solve_joint(&rem, &profiles, &cluster,
                                     SolverMode::Joint);
        let (rolling, _) = solve_joint(
            &rem, &profiles, &cluster,
            SolverMode::RollingHorizon { window: 8, overlap: 2 });
        // windows lose some cross-window packing, but the committed-area
        // coupling keeps them in the same regime
        assert!(rolling.predicted_makespan_s
                <= joint.predicted_makespan_s * 1.35 + 1.0,
                "rolling {} vs joint {}", rolling.predicted_makespan_s,
                joint.predicted_makespan_s);
    }

    #[test]
    fn rolling_horizon_replays_deterministically() {
        let jobs = toy_workload(30);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let run = || solve_joint(&rem, &profiles, &cluster,
                                 SolverMode::rolling_default()).0;
        let (a, b) = (run(), run());
        assert_eq!(a.choices.len(), b.choices.len());
        for (pa, pb) in a.choices.iter().zip(&b.choices) {
            assert_eq!((pa.job_id, pa.tech, pa.gpus, pa.class),
                       (pb.job_id, pb.tech, pb.gpus, pb.class));
        }
        assert_eq!(a.predicted_makespan_s, b.predicted_makespan_s);
    }

    #[test]
    fn tardiness_without_deadlines_is_bit_identical_to_makespan() {
        // satellite acceptance: WeightedTardiness degenerates to pure
        // makespan when no job carries a deadline — same LP, same plan
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (mk, _) = solve_joint(&rem, &profiles, &cluster,
                                  SolverMode::Joint);
        let terms: Vec<JobTerms> = rem
            .iter()
            .map(|&(id, _)| JobTerms {
                weight: 1.0 + (id % 3) as f64,
                ..JobTerms::neutral(id)
            })
            .collect();
        let (td, _) = solve_joint_obj(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::WeightedTardiness { deadline_weight: 5.0 }, &terms);
        assert_eq!(mk.choices, td.choices);
        assert_eq!(mk.predicted_makespan_s.to_bits(),
                   td.predicted_makespan_s.to_bits());
    }

    #[test]
    fn wjct_alpha_one_is_bit_identical_to_makespan() {
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (mk, _) = solve_joint(&rem, &profiles, &cluster,
                                  SolverMode::Joint);
        let terms: Vec<JobTerms> =
            rem.iter().map(|&(id, _)| JobTerms::neutral(id)).collect();
        let (wj, _) = solve_joint_obj(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::WeightedJct { alpha: 1.0 }, &terms);
        assert_eq!(mk.choices, wj.choices);
        assert_eq!(mk.predicted_makespan_s.to_bits(),
                   wj.predicted_makespan_s.to_bits());
    }

    /// The tardiness currency of a plan under given terms:
    /// sum_j (w_j / W) * max(0, runtime_j - due_j).
    fn weighted_tardiness_proxy(plan: &SaturnPlan, terms: &[JobTerms])
        -> f64 {
        let w_sum: f64 = terms.iter().map(|t| t.weight).sum();
        plan.choices
            .iter()
            .map(|p| {
                let t = terms
                    .iter()
                    .find(|t| t.job_id == p.job_id)
                    .expect("term");
                match t.due_in_s {
                    Some(due) => {
                        t.weight / w_sum * (p.runtime_s - due).max(0.0)
                    }
                    None => 0.0,
                }
            })
            .sum()
    }

    #[test]
    fn tardiness_objective_improves_its_own_currency() {
        // under tight deadlines, the makespan plan is FEASIBLE for the
        // tardiness formulation, so the tardiness solve must score no
        // worse on M + dw * weighted tardiness (up to the MILP gap) —
        // and in practice strictly better on the tardiness term
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (mk, _) = solve_joint(&rem, &profiles, &cluster,
                                  SolverMode::Joint);
        // deadlines at half of each job's makespan-plan runtime: tight
        // enough that tardiness rows all activate
        let terms: Vec<JobTerms> = rem
            .iter()
            .map(|&(id, _)| JobTerms {
                weight: 1.0 + (id % 2) as f64,
                due_in_s: mk.plan_for(id).map(|p| p.runtime_s * 0.5),
                ..JobTerms::neutral(id)
            })
            .collect();
        let dw = 10.0;
        let (td, stats) = solve_joint_obj(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::WeightedTardiness { deadline_weight: dw }, &terms);
        assert_eq!(td.choices.len(), rem.len());
        assert!(stats.wall_s < 10.0);
        let score = |p: &SaturnPlan| {
            let longest =
                p.choices.iter().map(|c| c.runtime_s).fold(0.0, f64::max);
            let m = (0..cluster.n_classes())
                .map(|ci| {
                    p.area_in_class(ci) / cluster.class_gpus(ci) as f64
                })
                .fold(longest, f64::max);
            m + dw * weighted_tardiness_proxy(p, &terms)
        };
        assert!(score(&td) <= score(&mk) * 1.02 + 1.0,
                "tardiness solve scored worse on its own objective: \
                 {} vs makespan plan {}", score(&td), score(&mk));
    }

    #[test]
    fn wjct_alpha_zero_tracks_the_weighted_jct_lower_bound() {
        // alpha = 0 is pure priority-weighted JCT: the chosen runtimes'
        // weighted sum must sit within the MILP gap of the per-job
        // fastest-plan lower bound (area pressure no longer restrains
        // the solve — M has zero cost)
        let jobs = toy_workload(8);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let terms: Vec<JobTerms> = rem
            .iter()
            .map(|&(id, _)| JobTerms {
                weight: 1.0 + (id % 3) as f64,
                ..JobTerms::neutral(id)
            })
            .collect();
        let (wj, _) = solve_joint_obj(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::WeightedJct { alpha: 0.0 }, &terms);
        let w_sum: f64 = terms.iter().map(|t| t.weight).sum();
        let chosen: f64 = wj
            .choices
            .iter()
            .map(|p| {
                let w = terms
                    .iter()
                    .find(|t| t.job_id == p.job_id)
                    .unwrap()
                    .weight;
                w / w_sum * p.runtime_s
            })
            .sum();
        let bound: f64 = rem
            .iter()
            .map(|&(id, steps)| {
                let w = terms
                    .iter()
                    .find(|t| t.job_id == id)
                    .unwrap()
                    .weight;
                let fastest = profiles
                    .candidate_plans(id)
                    .into_iter()
                    .map(|(_, _, _, s)| s * steps as f64)
                    .fold(f64::INFINITY, f64::min);
                w / w_sum * fastest
            })
            .sum();
        assert!(chosen <= bound * 1.02 + 1.0,
                "alpha=0 strayed from the weighted-JCT bound: \
                 {chosen} vs {bound}");
    }

    #[test]
    fn rolling_tardiness_plans_every_job_with_offsets() {
        // the objective-aware rolling path: least-slack window order +
        // per-window completion offsets still plan the full set and
        // respect the per-class budgets
        let jobs = toy_workload(40);
        let cluster = ClusterSpec::p4d(2);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let terms: Vec<JobTerms> = rem
            .iter()
            .map(|&(id, _)| JobTerms {
                weight: 1.0 + (id % 3) as f64,
                due_in_s: Some(600.0 * (1 + id % 7) as f64),
                ..JobTerms::neutral(id)
            })
            .collect();
        let (plan, stats) = solve_joint_obj(
            &rem, &profiles, &cluster,
            SolverMode::RollingHorizon { window: 16, overlap: 4 }, 1.0,
            None, Objective::WeightedTardiness { deadline_weight: 1.0 },
            &terms);
        assert_eq!(plan.choices.len(), 40);
        assert!(stats.windows >= 2, "windows {}", stats.windows);
        for ci in 0..cluster.n_classes() {
            assert!(plan.area_in_class(ci)
                        <= cluster.class_gpus(ci) as f64
                            * plan.predicted_makespan_s + 1e-6);
        }
    }

    #[test]
    fn budgeted_solve_with_no_budget_is_bit_identical() {
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (a, _) = solve_joint(&rem, &profiles, &cluster,
                                 SolverMode::Joint);
        let (b, sb) = solve_joint_budgeted(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::Makespan, &[], &Tracer::off(), None,
            SolveBudget::default());
        assert_eq!(a.choices, b.choices);
        assert_eq!(a.predicted_makespan_s.to_bits(),
                   b.predicted_makespan_s.to_bits());
        assert_eq!(sb.budget_exhausted, 0);
    }

    #[test]
    fn exhausted_node_budget_still_beats_or_matches_greedy() {
        // node_budget 0: every MILP returns its seed incumbent at once,
        // and the greedy floor guarantees the plan never loses to the
        // Heuristic mode on the same inputs
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let budget = SolveBudget { deadline_ms: None,
                                   node_budget: Some(0) };
        let (plan, stats) = solve_joint_budgeted(
            &rem, &profiles, &cluster, SolverMode::Joint, 1.0, None,
            Objective::Makespan, &[], &Tracer::off(), None, budget);
        assert!(stats.budget_exhausted > 0,
                "a zero node budget never fired");
        let (greedy, _) = solve_joint(&rem, &profiles, &cluster,
                                      SolverMode::Heuristic);
        assert!(plan.predicted_makespan_s
                    <= greedy.predicted_makespan_s + 1e-9,
                "budgeted {} vs greedy {}", plan.predicted_makespan_s,
                greedy.predicted_makespan_s);
        assert_eq!(plan.choices.len(), rem.len());
    }

    #[test]
    fn delta_solve_matches_full_probe_across_events() {
        // arrival -> departure -> arrival event mix: after every event
        // the seeded tight-gap probe must equal the full-grid probe
        // (colgen is exact from ANY pool), and the retained state must
        // track the roster
        let jobs = toy_workload(12);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let mut state = ColgenState::default();
        let events: Vec<Vec<(usize, u64)>> = vec![
            rem[..8].to_vec(),           // initial cohort
            rem[..10].to_vec(),          // arrival of 2
            rem[2..10].to_vec(),         // departure of 2
            rem[2..].to_vec(),           // arrival of 2 more
        ];
        for (ei, ev) in events.iter().enumerate() {
            let got = solve_joint_delta(
                ev, &profiles, &cluster, 1.0, None, Objective::Makespan,
                &[], &Tracer::off(), None, SolveBudget::default(),
                SHARD_THREADS, &mut state);
            let (plan, _) = got.expect("delta solve");
            assert_eq!(plan.choices.len(), ev.len(), "event {ei}");
            let (seeded, _) = plan_selection_colgen_from(
                &state, ev, &profiles, &cluster)
                .expect("seeded probe");
            let (full, _) = plan_selection_probe(
                ev, &profiles, &cluster, MilpEngine::Revised)
                .expect("full probe");
            assert!((seeded - full).abs() <= 1e-6 * full.abs().max(1.0),
                    "event {ei}: seeded {seeded} vs full {full}");
            // retained state covers exactly the live roster
            assert_eq!(state.pools.len(), ev.len());
            assert!(state.basis.is_some(),
                    "event {ei} retained no master basis");
        }
    }

    #[test]
    fn delta_solve_is_thread_count_invariant_when_sharded() {
        // 80 jobs > DELTA_UNSHARDED_MAX forces the seeded-cell path;
        // the merge is order-preserving, so worker count changes wall
        // time only
        let jobs = toy_workload(80);
        let cluster = ClusterSpec::p4d(2);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let run = |threads: usize| {
            let mut state = ColgenState::default();
            // one event to build state, a second to consume it
            solve_joint_delta(&rem[..70], &profiles, &cluster, 1.0, None,
                              Objective::Makespan, &[], &Tracer::off(),
                              None, SolveBudget::default(), threads,
                              &mut state)
                .expect("warmup");
            solve_joint_delta(&rem, &profiles, &cluster, 1.0, None,
                              Objective::Makespan, &[], &Tracer::off(),
                              None, SolveBudget::default(), threads,
                              &mut state)
                .expect("delta")
                .0
        };
        let (a, b) = (run(1), run(4));
        assert_eq!(a.choices, b.choices);
        assert_eq!(a.predicted_makespan_s.to_bits(),
                   b.predicted_makespan_s.to_bits());
    }

    #[test]
    fn schedule_never_oversubscribes() {
        // simulate_list with capacity accounting is exercised via
        // lower-bound sanity: predicted >= area/G and >= longest
        let (jobs, profiles, cluster) = setup(1);
        let (plan, _) = solve_joint(&remaining(&jobs), &profiles, &cluster,
                                    SolverMode::Joint);
        assert!(plan.predicted_makespan_s >= plan.lower_bound_s - 1e-6);
        assert!(plan.predicted_makespan_s
                >= plan.area() / cluster.total_gpus() as f64 - 1e-6);
    }
}
