//! The joint MILP: parallelism selection x GPU allocation x scheduling.
//!
//! The workshop paper states the joint problem is cast as an MILP and
//! solved with Gurobi, without printing the formulation. We implement the
//! standard two-level decomposition for malleable-task makespan problems
//! (documented in DESIGN.md §4):
//!
//!  1. **Plan-selection MILP** (exact, via `solver::milp`): binary
//!     x_{j,c} over each job's Pareto plans c = (technique, gpus) with
//!
//!     ```text
//!     min  M
//!     s.t. sum_c x_{jc} = 1                          (each job planned)
//!          sum_c t_{jc} x_{jc} <= M                  (critical path)
//!          sum_{j,c} g_{jc} t_{jc} x_{jc} <= G * M   (GPU area)
//!     ```
//!
//!     The two lower bounds (longest job, total area / G) are exactly the
//!     classic makespan LP bounds; minimizing M trades per-job speedups
//!     (more GPUs) against cluster-wide packing — the paper's core insight
//!     that allocation, parallelism and schedule must be decided jointly.
//!
//!  2. **List scheduling** (LPT first-fit on the chosen plans) to realize
//!     an order, followed by a local-search repair that re-plans the
//!     makespan-defining job if a different (tech, gpus) shortens the
//!     schedule.
//!
//! An exact time-indexed formulation (`SolverMode::ExactSlots`) is kept
//! for small instances to validate the decomposition in tests.

use std::time::Instant;

use crate::cluster::ClusterSpec;
use crate::saturn::plan::{JobPlan, SaturnPlan};
use crate::sim::placement::FreeState;
use crate::solver::lp::{Cmp, Lp};
use crate::solver::milp::{solve as milp_solve, MilpOptions, MilpResult};
use crate::trials::ProfileTable;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverMode {
    /// Plan-selection MILP + list scheduling (default; scales to dozens of
    /// jobs).
    Joint,
    /// Greedy fallback (no MILP) — used for very large instances and as an
    /// ablation arm in bench E9.
    Heuristic,
    /// Time-indexed exact MILP; exponential, tests/small instances only.
    ExactSlots { slots: usize },
}

#[derive(Debug, Clone, Default)]
pub struct SolverStats {
    pub milp_nodes: usize,
    pub wall_s: f64,
    pub proved_optimal: bool,
    /// An incumbent seeded from a previous plan was handed to the MILP
    /// (online incremental re-solves; see `solve_joint_warm`).
    pub warm_used: bool,
}

/// Inputs per unfinished job: (job_id, remaining_steps).
pub fn solve_joint(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
) -> (SaturnPlan, SolverStats) {
    solve_joint_with(jobs, profiles, cluster, mode, 1.0)
}

/// `lookahead` (kappa >= 1) encodes introspection-awareness: a job's
/// critical-path contribution is divided by kappa because a re-solve can
/// upsize it later. kappa = 1 -> static plans (no introspection). With
/// kappa > 1 the solver prefers max-efficiency (min-area) allocations up
/// front and naturally upgrades the stragglers at the tail — the classic
/// water-filling optimum for malleable jobs under preemption.
pub fn solve_joint_with(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
    lookahead: f64,
) -> (SaturnPlan, SolverStats) {
    solve_joint_warm(jobs, profiles, cluster, mode, lookahead, None)
}

/// Incremental re-solve for the online scheduler: `warm` (the plan from
/// the previous event) seeds the branch-and-bound incumbent, so the MILP
/// prunes against a known-good schedule from node one. Jobs absent from
/// `warm` (fresh arrivals) default to their min-GPU Pareto plan in the
/// seeded incumbent; departed jobs are simply dropped. This is what makes
/// event-rate re-solving affordable (bench_online measures warm vs cold).
pub fn solve_joint_warm(
    jobs: &[(usize, u64)],
    profiles: &ProfileTable,
    cluster: &ClusterSpec,
    mode: SolverMode,
    lookahead: f64,
    warm: Option<&SaturnPlan>,
) -> (SaturnPlan, SolverStats) {
    let start = Instant::now();
    let kappa = lookahead.max(1.0);
    let mut stats = SolverStats::default();

    let plans: Vec<(usize, Vec<(usize, u32, f64)>)> = jobs
        .iter()
        .map(|&(id, steps)| {
            let ps = profiles
                .pareto_plans(id)
                .into_iter()
                .map(|(tech, g, step)| (tech, g, step * steps as f64))
                .collect::<Vec<_>>();
            (id, ps)
        })
        .collect();

    let choices = match mode {
        SolverMode::Heuristic => greedy_choice(&plans, cluster, kappa),
        SolverMode::Joint => {
            match milp_choice(&plans, cluster, kappa, warm, &mut stats) {
                Some(c) => c,
                None => greedy_choice(&plans, cluster, kappa), // fallback
            }
        }
        SolverMode::ExactSlots { slots } => {
            match exact_slot_choice(&plans, cluster, slots, &mut stats) {
                Some(c) => c,
                None => greedy_choice(&plans, cluster, kappa),
            }
        }
    };

    let mut plan = build_schedule(choices, cluster);
    if kappa <= 1.0 + 1e-9 {
        // static plans: repair against the realized list schedule
        local_search(&mut plan, &plans, cluster);
    }
    stats.wall_s = start.elapsed().as_secs_f64();
    (plan, stats)
}

// ---------------------------------------------------------------------------
// Level 1: plan selection
// ---------------------------------------------------------------------------

fn milp_choice(
    plans: &[(usize, Vec<(usize, u32, f64)>)],
    cluster: &ClusterSpec,
    kappa: f64,
    warm: Option<&SaturnPlan>,
    stats: &mut SolverStats,
) -> Option<Vec<JobPlan>> {
    let g_total = cluster.total_gpus() as f64;
    // variable layout: x_{j,c} ... , M (last)
    let mut var = 0usize;
    let mut index: Vec<Vec<usize>> = Vec::new();
    for (_, ps) in plans {
        index.push((0..ps.len()).map(|c| { let v = var + c; v }).collect());
        var += ps.len();
    }
    let m_var = var;
    let n = var + 1;

    let mut lp = Lp::new(n);
    lp.set_obj(m_var, 1.0);
    // assignment + critical path per job
    for (ji, (_, ps)) in plans.iter().enumerate() {
        if ps.is_empty() {
            return None; // job with no feasible plan: give up to greedy
        }
        lp.add(index[ji].iter().map(|&v| (v, 1.0)).collect(), Cmp::Eq, 1.0);
        // critical path, discounted by the introspection lookahead kappa
        let mut cp: Vec<(usize, f64)> = ps
            .iter()
            .enumerate()
            .map(|(c, p)| (index[ji][c], p.2 / kappa))
            .collect();
        cp.push((m_var, -1.0));
        lp.add(cp, Cmp::Le, 0.0);
    }
    // area bound
    let mut area: Vec<(usize, f64)> = Vec::new();
    for (ji, (_, ps)) in plans.iter().enumerate() {
        for (c, p) in ps.iter().enumerate() {
            area.push((index[ji][c], p.1 as f64 * p.2));
        }
    }
    area.push((m_var, -g_total));
    lp.add(area, Cmp::Le, 0.0);
    // binaries bounded by 1
    for vs in &index {
        for &v in vs {
            lp.bound_le(v, 1.0);
        }
    }

    // Warm start: translate the previous plan into an incumbent vector.
    // Every job needs exactly one plan set; arrivals absent from the old
    // plan (and stale choices pruned off the Pareto set) fall back to the
    // min-GPU plan, which always satisfies the area bound together with
    // the matching makespan value for M.
    let warm_x = warm.map(|prev| {
        let mut x = vec![0.0; n];
        let mut longest = 0.0f64;
        let mut area_tot = 0.0f64;
        for (ji, (id, ps)) in plans.iter().enumerate() {
            let c = prev
                .plan_for(*id)
                .and_then(|jp| {
                    ps.iter().position(|&(t, g, _)| (t, g) == (jp.tech, jp.gpus))
                })
                .unwrap_or(0);
            x[index[ji][c]] = 1.0;
            let (_, g, t) = ps[c];
            longest = longest.max(t / kappa);
            area_tot += g as f64 * t;
        }
        x[m_var] = longest.max(area_tot / g_total);
        x
    });
    stats.warm_used = warm_x.is_some();

    let ints: Vec<usize> = index.iter().flatten().copied().collect();
    let opts = MilpOptions {
        gap: 0.01,
        max_nodes: 20_000,
        time_limit_s: 10.0,
        warm_start: warm_x,
    };
    match milp_solve(&lp, &ints, &opts) {
        MilpResult::Solved { x, nodes, proved_optimal, .. } => {
            stats.milp_nodes = nodes;
            stats.proved_optimal = proved_optimal;
            let mut out = Vec::new();
            for (ji, (id, ps)) in plans.iter().enumerate() {
                let c = (0..ps.len())
                    .find(|&c| x[index[ji][c]] > 0.5)
                    .unwrap_or(0);
                let (tech, gpus, runtime) = ps[c];
                out.push(JobPlan { job_id: *id, tech, gpus, runtime_s: runtime });
            }
            Some(out)
        }
        _ => None,
    }
}

/// Greedy: start every job at its smallest feasible plan, then spend the
/// remaining "area budget" on the job that currently bounds the makespan.
fn greedy_choice(
    plans: &[(usize, Vec<(usize, u32, f64)>)],
    cluster: &ClusterSpec,
    kappa: f64,
) -> Vec<JobPlan> {
    let g_total = cluster.total_gpus() as f64;
    let mut pick: Vec<usize> = plans.iter().map(|_| 0).collect();
    for _ in 0..64 {
        // current makespan bound = max(longest job, area/G)
        let longest_ji = (0..plans.len())
            .max_by(|&a, &b| {
                let ta = plans[a].1.get(pick[a]).map(|p| p.2).unwrap_or(0.0);
                let tb = plans[b].1.get(pick[b]).map(|p| p.2).unwrap_or(0.0);
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap();
        let area: f64 = (0..plans.len())
            .map(|ji| plans[ji].1.get(pick[ji])
                .map(|p| p.1 as f64 * p.2).unwrap_or(0.0))
            .sum();
        let longest = plans[longest_ji].1.get(pick[longest_ji])
            .map(|p| p.2).unwrap_or(0.0);
        if area / g_total >= longest / kappa {
            break; // area-bound: more GPUs per job only adds area
        }
        // upgrade the critical job if a bigger plan exists
        if pick[longest_ji] + 1 < plans[longest_ji].1.len() {
            pick[longest_ji] += 1;
        } else {
            break;
        }
    }
    plans
        .iter()
        .zip(&pick)
        .filter(|((_, ps), _)| !ps.is_empty())
        .map(|((id, ps), &c)| {
            let (tech, gpus, runtime) = ps[c];
            JobPlan { job_id: *id, tech, gpus, runtime_s: runtime }
        })
        .collect()
}

/// Exact time-indexed MILP (x_{j,c,s}); small instances only.
fn exact_slot_choice(
    plans: &[(usize, Vec<(usize, u32, f64)>)],
    cluster: &ClusterSpec,
    slots: usize,
    stats: &mut SolverStats,
) -> Option<Vec<JobPlan>> {
    // horizon: makespan of the greedy schedule
    let greedy = build_schedule(greedy_choice(plans, cluster, 1.0), cluster);
    let horizon = greedy.predicted_makespan_s * 1.25 + 1.0;
    let dt = horizon / slots as f64;
    let g_total = cluster.total_gpus() as f64;

    // variables: x_{j,c,s} + M
    let mut var = 0usize;
    let mut idx: Vec<Vec<Vec<usize>>> = Vec::new(); // [j][c][s]
    for (_, ps) in plans {
        let mut per_c = Vec::new();
        for _ in ps {
            per_c.push((0..slots).map(|s| { let v = var + s; v }).collect());
            var += slots;
        }
        idx.push(per_c);
    }
    let m_var = var;
    let n = var + 1;
    let mut lp = Lp::new(n);
    lp.set_obj(m_var, 1.0);

    for (ji, (_, ps)) in plans.iter().enumerate() {
        if ps.is_empty() {
            return None;
        }
        // one (plan, start)
        let all: Vec<(usize, f64)> = idx[ji]
            .iter()
            .flatten()
            .map(|&v| (v, 1.0))
            .collect();
        lp.add(all, Cmp::Eq, 1.0);
        // makespan: start*dt + t <= M  (big-M linearization)
        let big = horizon * 2.0;
        for (c, p) in ps.iter().enumerate() {
            for s in 0..slots {
                lp.add(
                    vec![(idx[ji][c][s], s as f64 * dt + p.2 + big),
                         (m_var, -1.0)],
                    Cmp::Le,
                    big,
                );
            }
        }
    }
    // capacity per slot
    for slot in 0..slots {
        let mut cap: Vec<(usize, f64)> = Vec::new();
        for (ji, (_, ps)) in plans.iter().enumerate() {
            for (c, p) in ps.iter().enumerate() {
                let dur_slots = (p.2 / dt).ceil() as usize;
                // job occupies `slot` if it started in (slot-dur, slot]
                let lo = slot.saturating_sub(dur_slots.saturating_sub(1));
                for s in lo..=slot {
                    cap.push((idx[ji][c][s], p.1 as f64));
                }
            }
        }
        if !cap.is_empty() {
            lp.add(cap, Cmp::Le, g_total);
        }
    }
    for vs in idx.iter().flatten().flatten() {
        lp.bound_le(*vs, 1.0);
    }

    let ints: Vec<usize> = idx.iter().flatten().flatten().copied().collect();
    let opts = MilpOptions {
        gap: 1e-3,
        max_nodes: 50_000,
        time_limit_s: 20.0,
        warm_start: None,
    };
    match milp_solve(&lp, &ints, &opts) {
        MilpResult::Solved { x, nodes, proved_optimal, .. } => {
            stats.milp_nodes += nodes;
            stats.proved_optimal = proved_optimal;
            let mut out = Vec::new();
            for (ji, (id, ps)) in plans.iter().enumerate() {
                let mut found = None;
                for (c, p) in ps.iter().enumerate() {
                    for s in 0..slots {
                        if x[idx[ji][c][s]] > 0.5 {
                            found = Some((c, *p));
                        }
                    }
                }
                let (_, (tech, gpus, runtime)) = found?;
                out.push(JobPlan { job_id: *id, tech, gpus, runtime_s: runtime });
            }
            Some(out)
        }
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Level 2: list scheduling + local search
// ---------------------------------------------------------------------------

/// LPT first-fit simulation of the chosen plans; fills `order` and
/// `predicted_makespan_s`.
pub fn build_schedule(mut choices: Vec<JobPlan>, cluster: &ClusterSpec)
    -> SaturnPlan {
    choices.sort_by(|a, b| b.runtime_s.partial_cmp(&a.runtime_s).unwrap());
    let order: Vec<usize> = choices.iter().map(|p| p.job_id).collect();
    let lower = lower_bound(&choices, cluster);
    let makespan = simulate_list(&choices, cluster);
    SaturnPlan {
        choices,
        order,
        lower_bound_s: lower,
        predicted_makespan_s: makespan,
    }
}

fn lower_bound(choices: &[JobPlan], cluster: &ClusterSpec) -> f64 {
    let longest = choices.iter().map(|p| p.runtime_s).fold(0.0, f64::max);
    let area: f64 = choices.iter().map(|p| p.gpus as f64 * p.runtime_s).sum();
    longest.max(area / cluster.total_gpus() as f64)
}

/// Fast list-schedule makespan (same placement rules as the simulator).
fn simulate_list(choices: &[JobPlan], cluster: &ClusterSpec) -> f64 {
    let mut free = FreeState::new(cluster);
    let mut running: Vec<(f64, Vec<(usize, u32)>)> = Vec::new(); // (finish, placement)
    let mut pending: Vec<&JobPlan> = choices.iter().collect();
    let mut now = 0.0f64;
    let mut makespan = 0.0f64;
    while !pending.is_empty() || !running.is_empty() {
        // launch whatever fits, in order (backfill allowed)
        pending.retain(|p| {
            if let Some(pl) = free.place(p.gpus) {
                let fin = now + p.runtime_s;
                makespan = makespan.max(fin);
                running.push((fin, pl));
                false
            } else {
                true
            }
        });
        if running.is_empty() {
            break; // nothing runnable (shouldn't happen with valid plans)
        }
        // advance to next completion
        let (i, _) = running
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .unwrap();
        let (fin, pl) = running.swap_remove(i);
        now = fin;
        free.release(&pl);
    }
    makespan
}

/// Coordinate-descent repair on the REALIZED list schedule: the MILP's
/// area/critical-path relaxation ignores packing losses, so sweep every
/// job's alternatives against the simulated schedule and keep improvements.
/// This is what turns "good on paper" plans into good makespans (and where
/// Saturn's joint view beats per-job greedy allocation).
fn local_search(
    plan: &mut SaturnPlan,
    plans: &[(usize, Vec<(usize, u32, f64)>)],
    cluster: &ClusterSpec,
) {
    for _sweep in 0..64 {
        let mut improved = false;
        // visit jobs by schedule impact (longest runtime first)
        let mut order: Vec<usize> = (0..plan.choices.len()).collect();
        order.sort_by(|&a, &b| {
            plan.choices[b]
                .runtime_s
                .partial_cmp(&plan.choices[a].runtime_s)
                .unwrap()
        });
        for pos in order {
            let job_id = plan.choices[pos].job_id;
            let Some((_, alts)) = plans.iter().find(|(id, _)| *id == job_id)
            else {
                continue;
            };
            let mut best = plan.predicted_makespan_s;
            let mut best_plan: Option<SaturnPlan> = None;
            for &(tech, gpus, runtime) in alts {
                if (tech, gpus) == (plan.choices[pos].tech, plan.choices[pos].gpus) {
                    continue;
                }
                let mut cand = plan.choices.clone();
                cand[pos] = JobPlan { job_id, tech, gpus, runtime_s: runtime };
                let new_plan = build_schedule(cand, cluster);
                if new_plan.predicted_makespan_s < best - 1e-9 {
                    best = new_plan.predicted_makespan_s;
                    best_plan = Some(new_plan);
                }
            }
            if let Some(p) = best_plan {
                // positions shift after rebuild; restart the sweep ordering
                *plan = p;
                improved = true;
                break;
            }
        }
        if !improved {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallelism::default_library;
    use crate::trials::profile_analytic;
    use crate::workload::{toy_workload, wikitext_workload};

    fn setup(nodes: u32) -> (Vec<crate::workload::Job>, ProfileTable, ClusterSpec) {
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::p4d(nodes);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        (jobs, profiles, cluster)
    }

    fn remaining(jobs: &[crate::workload::Job]) -> Vec<(usize, u64)> {
        jobs.iter().map(|j| (j.id, j.total_steps())).collect()
    }

    #[test]
    fn joint_plans_every_job() {
        let (jobs, profiles, cluster) = setup(1);
        let (plan, stats) = solve_joint(&remaining(&jobs), &profiles,
                                        &cluster, SolverMode::Joint);
        assert_eq!(plan.choices.len(), 12);
        assert_eq!(plan.order.len(), 12);
        assert!(plan.predicted_makespan_s >= plan.lower_bound_s * 0.999);
        assert!(stats.wall_s < 10.0);
    }

    #[test]
    fn joint_beats_or_matches_greedy() {
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (joint, _) = solve_joint(&rem, &profiles, &cluster, SolverMode::Joint);
        let (greedy, _) =
            solve_joint(&rem, &profiles, &cluster, SolverMode::Heuristic);
        assert!(joint.predicted_makespan_s
                <= greedy.predicted_makespan_s * 1.001,
                "joint {} greedy {}", joint.predicted_makespan_s,
                greedy.predicted_makespan_s);
    }

    #[test]
    fn two_nodes_shorter_than_one() {
        let (jobs, p1, c1) = setup(1);
        let (_, p2, c2) = {
            let cluster = ClusterSpec::p4d(2);
            let lib = default_library();
            let p = profile_analytic(&jobs, &lib, &cluster);
            (jobs.clone(), p, cluster)
        };
        let rem = remaining(&jobs);
        let (m1, _) = solve_joint(&rem, &p1, &c1, SolverMode::Joint);
        let (m2, _) = solve_joint(&rem, &p2, &c2, SolverMode::Joint);
        assert!(m2.predicted_makespan_s < m1.predicted_makespan_s);
    }

    #[test]
    fn mixed_allocations_appear() {
        // the paper's "unintuitive" plans: not everything gets 8 GPUs
        let (jobs, profiles, cluster) = setup(1);
        let (plan, _) = solve_joint(&remaining(&jobs), &profiles, &cluster,
                                    SolverMode::Joint);
        let gpus: std::collections::BTreeSet<u32> =
            plan.choices.iter().map(|p| p.gpus).collect();
        assert!(gpus.len() > 1, "all jobs got identical allocations: {gpus:?}");
    }

    #[test]
    fn exact_slots_close_to_joint_on_small_instance() {
        let jobs = toy_workload(4);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let (joint, _) = solve_joint(&rem, &profiles, &cluster, SolverMode::Joint);
        let (exact, _) = solve_joint(&rem, &profiles, &cluster,
                                     SolverMode::ExactSlots { slots: 6 });
        // exact formulation should not be dramatically worse than the
        // decomposition (coarse slots cost some rounding)
        assert!(exact.predicted_makespan_s
                <= joint.predicted_makespan_s * 1.6 + 1.0,
                "exact {} joint {}", exact.predicted_makespan_s,
                joint.predicted_makespan_s);
    }

    #[test]
    fn warm_start_matches_cold_quality() {
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (cold, _) = solve_joint(&rem, &profiles, &cluster, SolverMode::Joint);
        let (warm, warm_stats) = solve_joint_warm(&rem, &profiles, &cluster,
                                                  SolverMode::Joint, 1.0,
                                                  Some(&cold));
        assert!(warm_stats.warm_used);
        assert!(warm.predicted_makespan_s
                <= cold.predicted_makespan_s * 1.001,
                "warm {} vs cold {}", warm.predicted_makespan_s,
                cold.predicted_makespan_s);
    }

    #[test]
    fn warm_start_tolerates_arrivals_and_departures() {
        // warm plan covers a different job set: overlaps warm-start, new
        // arrivals fall back to min-GPU plans, departures are dropped
        let (jobs, profiles, cluster) = setup(1);
        let rem = remaining(&jobs);
        let (prev, _) = solve_joint(&rem[..6], &profiles, &cluster,
                                    SolverMode::Joint);
        let (plan, stats) = solve_joint_warm(&rem[3..], &profiles, &cluster,
                                             SolverMode::Joint, 1.0,
                                             Some(&prev));
        assert!(stats.warm_used);
        assert_eq!(plan.choices.len(), rem.len() - 3);
        assert!(plan.predicted_makespan_s >= plan.lower_bound_s * 0.999);
    }

    #[test]
    fn schedule_never_oversubscribes() {
        // simulate_list with capacity accounting is exercised via
        // lower-bound sanity: predicted >= area/G and >= longest
        let (jobs, profiles, cluster) = setup(1);
        let (plan, _) = solve_joint(&remaining(&jobs), &profiles, &cluster,
                                    SolverMode::Joint);
        assert!(plan.predicted_makespan_s >= plan.lower_bound_s - 1e-6);
        assert!(plan.predicted_makespan_s
                >= plan.area() / cluster.total_gpus() as f64 - 1e-6);
    }
}
