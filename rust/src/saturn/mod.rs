//! Saturn's contribution: the joint (parallelism, allocation, schedule)
//! solver and its introspection loop (paper §2, "Solver").

pub mod incremental;
pub mod introspect;
pub mod plan;
pub mod solver;

pub use incremental::IncrementalSolver;
pub use introspect::SaturnPolicy;
pub use plan::{JobPlan, SaturnPlan};
pub use solver::{solve_joint, solve_joint_obj, SolveBudget, SolverMode,
                 SolverStats};
