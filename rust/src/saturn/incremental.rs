//! [`IncrementalSolver`]: persistent event-delta re-optimization for
//! the online scheduler (DESIGN.md §4.9).
//!
//! `OnlineSaturn` historically re-solved the joint problem from scratch
//! at every arrival/departure/rung-kill, even though consecutive events
//! share almost all of their structure. This module retains the
//! column-generation artifacts of the last re-solve — admitted column
//! pools, converged duals, and the master simplex basis with its row
//! layout ([`ColgenState`]) — and replays the NEXT event as a delta:
//! an arrival appends the new job's seed columns and assign/critical-
//! path rows (entering slack-basic, so the retained basis stays dual
//! feasible), a departure deletes that job's rows and columns and lets
//! the dual simplex repair the basis, and pricing restarts from the
//! retained duals instead of from zero.
//!
//! Correctness never depends on the retained state: the reduced-cost
//! widening pass makes column generation exact from ANY starting pool,
//! and a stale or singular basis only costs pivots (the warm solve
//! falls back to a cold factorization). A **dirty-set heuristic**
//! declines the delta path outright when the event is too big for the
//! state to help — more than 25 % of the roster changed, the live fleet
//! capacities moved, the objective changed or is not pure makespan, a
//! failure fired, or no state exists yet — and the caller runs the
//! existing full solve, which stays bit-identical when the feature is
//! off.

use crate::cluster::ClusterSpec;
use crate::objective::{JobTerms, Objective};
use crate::obs::trace::Tracer;
use crate::saturn::plan::SaturnPlan;
use crate::saturn::solver::{plan_selection_colgen_from, solve_joint_delta,
                            ColgenState, SolveBudget, SolverStats,
                            SHARD_THREADS};
use crate::trials::ProfileTable;

/// Retained re-solve state plus the fingerprints the dirty-set
/// heuristic compares against. Owned by `OnlineSaturn`; one instance
/// lives for the whole streaming run.
#[derive(Debug, Default)]
pub struct IncrementalSolver {
    state: ColgenState,
    /// Roster (job ids) of the last retained solve.
    last_jobs: Vec<usize>,
    /// Live per-class capacities the last solve planned against
    /// (`None` = static fleet).
    last_live: Option<Vec<f64>>,
    last_objective: Option<Objective>,
    /// Re-solves served by the delta path.
    pub delta_resolves: usize,
    /// Re-solves that went through the full pipeline (declined by the
    /// heuristic, or the delta attempt failed and fell back).
    pub full_resolves: usize,
}

impl IncrementalSolver {
    pub fn new() -> Self {
        Self::default()
    }

    /// The dirty-set heuristic: `true` when the retained state is fresh
    /// enough that a delta re-solve should pay off. Conservative by
    /// design — declining only costs a full solve, accepting a hopeless
    /// delta costs a failed attempt AND the full solve.
    pub fn wants_delta(
        &self,
        jobs: &[(usize, u64)],
        objective: Objective,
        failure: bool,
        live_gpus: Option<&[f64]>,
    ) -> bool {
        // no state yet (first solve of the run, or just reset)
        if self.state.pools.is_empty() || self.last_jobs.is_empty() {
            return false;
        }
        // failures invalidate the fleet the state was priced against
        if failure {
            return false;
        }
        // objective changed, or not pure makespan: the delta masters
        // price the makespan formulation only (degenerate makespan-like
        // blends go through the full path rather than guessing terms)
        if !objective.is_makespan()
            || self.last_objective != Some(objective)
        {
            return false;
        }
        // fleet changed: retained duals price against dead capacities
        if self.last_live.as_deref() != live_gpus {
            return false;
        }
        // churn: >25 % of the previous roster touched (arrivals +
        // departures, symmetric difference) → the state is mostly noise
        let cur: std::collections::HashSet<usize> =
            jobs.iter().map(|&(id, _)| id).collect();
        let prev: std::collections::HashSet<usize> =
            self.last_jobs.iter().copied().collect();
        let touched = cur.symmetric_difference(&prev).count();
        touched * 4 <= self.last_jobs.len()
    }

    /// Run the event as a delta over the retained state. `None` means
    /// the delta failed (infeasible master, non-makespan terms) — the
    /// state keeps its pruned-but-valid artifacts and the caller must
    /// run the full solve and [`Self::note_full`]. On success the state
    /// is refreshed in place and the fingerprints advance.
    #[allow(clippy::too_many_arguments)]
    pub fn solve_delta(
        &mut self,
        jobs: &[(usize, u64)],
        profiles: &ProfileTable,
        cluster: &ClusterSpec,
        lookahead: f64,
        warm: Option<&SaturnPlan>,
        objective: Objective,
        terms: &[JobTerms],
        trace: &Tracer,
        live_gpus: Option<&[f64]>,
        budget: SolveBudget,
    ) -> Option<(SaturnPlan, SolverStats)> {
        let out = solve_joint_delta(jobs, profiles, cluster, lookahead,
                                    warm, objective, terms, trace,
                                    live_gpus, budget, SHARD_THREADS,
                                    &mut self.state);
        if out.is_some() {
            self.delta_resolves += 1;
            self.remember(jobs, objective, live_gpus);
        }
        out
    }

    /// Record a FULL re-solve: reseed the pools from the chosen plan
    /// (each job's winning key is the best imaginable seed column for
    /// the next event) and clear duals/basis, which described a master
    /// the full pipeline never built. Advances the fingerprints so the
    /// next event can go delta.
    pub fn note_full(
        &mut self,
        jobs: &[(usize, u64)],
        plan: &SaturnPlan,
        objective: Objective,
        live_gpus: Option<&[f64]>,
    ) {
        self.full_resolves += 1;
        self.state = ColgenState::default();
        for jp in &plan.choices {
            self.state
                .pools
                .insert(jp.job_id, vec![(jp.tech, jp.gpus, jp.class)]);
        }
        self.remember(jobs, objective, live_gpus);
    }

    /// Tight-gap column-generation probe seeded from the retained state
    /// — the 1e-6 parity oracle `tests/prop_incremental.rs` compares
    /// against [`crate::saturn::solver::plan_selection_probe`].
    /// Read-only on the state.
    pub fn parity_probe(
        &self,
        jobs: &[(usize, u64)],
        profiles: &ProfileTable,
        cluster: &ClusterSpec,
    ) -> Option<(f64, SolverStats)> {
        plan_selection_colgen_from(&self.state, jobs, profiles, cluster)
    }

    /// Whether any retained state exists (post-first-solve).
    pub fn has_state(&self) -> bool {
        !self.state.pools.is_empty()
    }

    fn remember(
        &mut self,
        jobs: &[(usize, u64)],
        objective: Objective,
        live_gpus: Option<&[f64]>,
    ) {
        self.last_jobs = jobs.iter().map(|&(id, _)| id).collect();
        self.last_objective = Some(objective);
        self.last_live = live_gpus.map(|l| l.to_vec());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::saturn::solver::{plan_selection_probe, solve_joint_budgeted,
                                SolverMode};
    use crate::solver::milp::MilpEngine;
    use crate::trials::profile_analytic;
    use crate::workload::toy_workload;

    fn setup(n: usize) -> (Vec<(usize, u64)>, ProfileTable, ClusterSpec) {
        let jobs = toy_workload(n);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let rem: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        (rem, profiles, cluster)
    }

    fn full(
        rem: &[(usize, u64)],
        profiles: &ProfileTable,
        cluster: &ClusterSpec,
    ) -> SaturnPlan {
        solve_joint_budgeted(rem, profiles, cluster, SolverMode::Joint,
                             1.0, None, Objective::Makespan, &[],
                             &Tracer::off(), None, SolveBudget::default())
            .0
    }

    #[test]
    fn cold_solver_declines_then_accepts_after_note_full() {
        let (rem, profiles, cluster) = setup(8);
        let mut inc = IncrementalSolver::new();
        assert!(!inc.wants_delta(&rem, Objective::Makespan, false, None),
                "no retained state must decline the delta path");
        let plan = full(&rem, &profiles, &cluster);
        inc.note_full(&rem, &plan, Objective::Makespan, None);
        assert!(inc.has_state());
        assert_eq!(inc.full_resolves, 1);
        assert!(inc.wants_delta(&rem, Objective::Makespan, false, None));
    }

    #[test]
    fn heuristic_declines_failure_objective_fleet_and_churn() {
        let (rem, profiles, cluster) = setup(8);
        let mut inc = IncrementalSolver::new();
        let plan = full(&rem, &profiles, &cluster);
        inc.note_full(&rem, &plan, Objective::Makespan, None);
        // failure cause
        assert!(!inc.wants_delta(&rem, Objective::Makespan, true, None));
        // objective changed / non-makespan
        assert!(!inc.wants_delta(
            &rem, Objective::WeightedJct { alpha: 0.5 }, false, None));
        // fleet changed (static -> degraded live row)
        let live = vec![4.0; cluster.n_classes()];
        assert!(!inc.wants_delta(&rem, Objective::Makespan, false,
                                 Some(&live)));
        // churn: 3 of 8 jobs departed = 37.5 % > 25 %
        assert!(!inc.wants_delta(&rem[..5], Objective::Makespan, false,
                                 None));
        // 2 of 8 = 25 % is still within budget
        assert!(inc.wants_delta(&rem[..6], Objective::Makespan, false,
                                None));
    }

    #[test]
    fn delta_after_departure_matches_full_probe() {
        let (rem, profiles, cluster) = setup(10);
        let mut inc = IncrementalSolver::new();
        let plan = full(&rem, &profiles, &cluster);
        inc.note_full(&rem, &plan, Objective::Makespan, None);
        // two jobs depart (20 % churn) -> delta path accepts
        let after: Vec<_> = rem[..8].to_vec();
        assert!(inc.wants_delta(&after, Objective::Makespan, false, None));
        let got = inc.solve_delta(&after, &profiles, &cluster, 1.0, None,
                                  Objective::Makespan, &[], &Tracer::off(),
                                  None, SolveBudget::default());
        assert!(got.is_some(), "delta re-solve failed on a plain departure");
        assert_eq!(inc.delta_resolves, 1);
        let (probe, _) = inc
            .parity_probe(&after, &profiles, &cluster)
            .expect("seeded parity probe failed");
        let (reference, _) =
            plan_selection_probe(&after, &profiles, &cluster,
                                 MilpEngine::Revised)
                .expect("full-grid probe failed");
        assert!((probe - reference).abs() <= 1e-6 * reference.abs().max(1.0),
                "seeded probe {probe} != full probe {reference}");
    }
}
