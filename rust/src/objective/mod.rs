//! The scheduling-objective axis (DESIGN.md §4.5): what the joint
//! solver — and every policy competing against it — actually optimizes.
//!
//! The seed system hard-coded pure makespan. The online layer, however,
//! carries tenant priorities and deadlines (`workload::arrivals`) that
//! until this refactor only influenced FIFO tie-breaks and post-hoc
//! reporting. [`Objective`] turns the goal into a first-class value
//! threaded through the MILP (epigraph tardiness variables, blended
//! completion-time coefficients), the launch ordering of every policy
//! (earliest-deadline-first / weighted-slack instead of only
//! priority-then-longest), and the metrics (`total_tardiness_s`,
//! `weighted_tardiness_s`).
//!
//! Behavior preservation: [`Objective::Makespan`] — and any terms under
//! which the other objectives degenerate to it (no deadlines, `alpha`
//! = 1) — produces the HISTORICAL formulation and orderings bit for
//! bit; `bench_objective` and `tests/prop_objective.rs` hold this to
//! 1e-6/bit-identity.

/// What the joint solve minimizes (see DESIGN.md §4.5 for the rows each
/// variant adds to the plan-selection MILP).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Objective {
    /// The paper's objective: minimize the makespan `M` alone.
    #[default]
    Makespan,
    /// `min M + deadline_weight * sum_j (w_j / W) T_j` with per-job
    /// epigraph tardiness variables `T_j >= C_j - due_j`, `T_j >= 0`
    /// (only jobs carrying a deadline get one, so the rows stay sparse).
    /// `W = sum_j w_j` keeps the tardiness term in the same seconds
    /// scale as `M` regardless of job count.
    WeightedTardiness { deadline_weight: f64 },
    /// `min alpha * M + (1 - alpha) * sum_j (w_j / W) C_j`: the
    /// makespan / priority-weighted-JCT trade-off knob. The completion
    /// proxy (each job's remaining runtime; sunk waiting time is a
    /// constant) is linear in the plan binaries, so no extra variables
    /// are needed — the blend lands directly on the objective
    /// coefficients. `alpha = 1` IS pure makespan (identical LP);
    /// `alpha = 0` is pure weighted JCT.
    WeightedJct { alpha: f64 },
}

impl Objective {
    /// Stable tag used by the CLI, benches and JSON records.
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Makespan => "makespan",
            Objective::WeightedTardiness { .. } => "tardiness",
            Objective::WeightedJct { .. } => "wjct",
        }
    }

    /// Parse the CLI knob triple `--objective NAME [--alpha A]
    /// [--deadline-weight W]`.
    pub fn parse(name: &str, alpha: f64, deadline_weight: f64)
        -> Result<Objective, String> {
        match name {
            "makespan" => Ok(Objective::Makespan),
            "tardiness" => {
                if deadline_weight <= 0.0 || !deadline_weight.is_finite() {
                    return Err(format!(
                        "--deadline-weight must be positive and finite, \
                         got {deadline_weight}"));
                }
                Ok(Objective::WeightedTardiness { deadline_weight })
            }
            "wjct" => {
                if !(0.0..=1.0).contains(&alpha) {
                    return Err(format!(
                        "--alpha must lie in [0, 1], got {alpha}"));
                }
                Ok(Objective::WeightedJct { alpha })
            }
            other => Err(format!(
                "unknown objective '{other}' (makespan|tardiness|wjct)")),
        }
    }

    pub fn is_makespan(&self) -> bool {
        matches!(self, Objective::Makespan)
    }

    /// True when the formulation collapses to pure makespan for the
    /// given job terms: no deadlines can ever activate a tardiness row,
    /// and the `alpha = 1` endpoint of the JCT blend zeroes every
    /// completion coefficient. Callers use this to stay on the
    /// historical (bit-identical) solve path.
    pub fn degenerates_to_makespan(&self, terms: &[JobTerms]) -> bool {
        match *self {
            Objective::Makespan => true,
            Objective::WeightedTardiness { .. } => {
                terms.iter().all(|t| t.due_in_s.is_none())
            }
            Objective::WeightedJct { alpha } => alpha >= 1.0,
        }
    }

    /// Primary launch-ordering key for a pending job under this
    /// objective — SMALLER launches first — or `None` under makespan,
    /// where callers keep their historical order (longest-first /
    /// priority-then-longest).
    ///
    /// Tardiness uses WEIGHTED slack: jobs still inside their deadline
    /// rank by `slack / w` (earliest-deadline-first generalized by the
    /// remaining work, with heavy tenants pulled forward), already-late
    /// jobs rank ahead of everything by `-w / runtime` — once tardiness
    /// is accruing, minimizing the weighted sum degenerates to
    /// weighted-shortest-processing-time among the overdue. Deadline-
    /// less jobs go last. The JCT blend ranks purely by
    /// weight-per-second of remaining runtime (WSPT).
    pub fn urgency_key(&self, priority: f64, runtime_s: f64, arrival_s: f64,
                       deadline_s: Option<f64>, now: f64) -> Option<f64> {
        match *self {
            Objective::Makespan => None,
            // the alpha = 1 endpoint IS makespan: keep its ordering too
            Objective::WeightedJct { alpha } if alpha >= 1.0 => None,
            Objective::WeightedTardiness { .. } => Some(match deadline_s {
                Some(d) => {
                    let slack = arrival_s + d - now - runtime_s;
                    if slack >= 0.0 {
                        // weighted slack (>= 0: after every overdue job)
                        slack / priority.max(1e-9)
                    } else {
                        // overdue (< 0: ahead of every on-time job),
                        // WSPT-ordered among themselves
                        -(priority.max(1e-9) / runtime_s.max(1e-9))
                    }
                }
                None => f64::INFINITY,
            }),
            Objective::WeightedJct { .. } => {
                Some(-(priority.max(1e-9) / runtime_s.max(1e-9)))
            }
        }
    }
}

/// Per-job objective inputs handed to the solver alongside the
/// `(job_id, remaining_steps)` pairs. Entries are matched by job id;
/// jobs without an entry (and the batch path, which passes an empty
/// slice) get [`JobTerms::neutral`].
///
/// Time already elapsed since arrival is deliberately NOT a term: it
/// is a per-job constant at each solve instant, so it drops out of
/// every argmin the solver evaluates (deadlines already arrive as
/// due-in-seconds relative to the solve instant).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobTerms {
    pub job_id: usize,
    /// Tenant priority weight (>= 1 in traces; 1 = neutral).
    pub weight: f64,
    /// Seconds from "now" until the deadline (negative = already
    /// overdue); `None` = no deadline.
    pub due_in_s: Option<f64>,
}

impl JobTerms {
    /// Neutral terms: weight 1, no deadline.
    pub fn neutral(job_id: usize) -> JobTerms {
        JobTerms { job_id, weight: 1.0, due_in_s: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_cli_triples() {
        assert_eq!(Objective::parse("makespan", 0.5, 1.0).unwrap(),
                   Objective::Makespan);
        assert_eq!(Objective::parse("tardiness", 0.5, 2.0).unwrap(),
                   Objective::WeightedTardiness { deadline_weight: 2.0 });
        assert_eq!(Objective::parse("wjct", 0.25, 1.0).unwrap(),
                   Objective::WeightedJct { alpha: 0.25 });
    }

    #[test]
    fn parse_rejects_bad_knobs() {
        assert!(Objective::parse("latency", 0.5, 1.0).is_err());
        assert!(Objective::parse("wjct", 1.5, 1.0).is_err());
        assert!(Objective::parse("wjct", -0.1, 1.0).is_err());
        assert!(Objective::parse("tardiness", 0.5, 0.0).is_err());
        assert!(Objective::parse("tardiness", 0.5, -1.0).is_err());
    }

    #[test]
    fn names_round_trip() {
        for (name, obj) in [
            ("makespan", Objective::Makespan),
            ("tardiness",
             Objective::WeightedTardiness { deadline_weight: 1.0 }),
            ("wjct", Objective::WeightedJct { alpha: 0.5 }),
        ] {
            assert_eq!(obj.name(), name);
            assert_eq!(Objective::parse(name, 0.5, 1.0).unwrap().name(),
                       name);
        }
    }

    #[test]
    fn degeneracy_covers_the_makespan_equivalent_corners() {
        let no_deadline = [JobTerms::neutral(0), JobTerms::neutral(1)];
        let with_deadline = [JobTerms {
            due_in_s: Some(10.0),
            ..JobTerms::neutral(0)
        }];
        let tard = Objective::WeightedTardiness { deadline_weight: 1.0 };
        assert!(Objective::Makespan.degenerates_to_makespan(&with_deadline));
        assert!(tard.degenerates_to_makespan(&no_deadline));
        assert!(tard.degenerates_to_makespan(&[]));
        assert!(!tard.degenerates_to_makespan(&with_deadline));
        assert!(Objective::WeightedJct { alpha: 1.0 }
            .degenerates_to_makespan(&with_deadline));
        assert!(!Objective::WeightedJct { alpha: 0.5 }
            .degenerates_to_makespan(&[]));
    }

    #[test]
    fn makespan_has_no_urgency_key() {
        assert!(Objective::Makespan
            .urgency_key(2.0, 100.0, 0.0, Some(50.0), 10.0)
            .is_none());
        // the degenerate wjct endpoint keeps the makespan ordering too
        assert!(Objective::WeightedJct { alpha: 1.0 }
            .urgency_key(2.0, 100.0, 0.0, Some(50.0), 10.0)
            .is_none());
    }

    #[test]
    fn tardiness_urgency_is_weighted_least_slack_first() {
        let o = Objective::WeightedTardiness { deadline_weight: 1.0 };
        // tighter slack => smaller key => launches first
        let tight = o.urgency_key(1.0, 3600.0, 0.0, Some(4000.0), 0.0);
        let loose = o.urgency_key(1.0, 600.0, 0.0, Some(4000.0), 0.0);
        let none = o.urgency_key(9.0, 600.0, 0.0, None, 0.0);
        assert!(tight.unwrap() < loose.unwrap());
        assert_eq!(none, Some(f64::INFINITY)); // deadline-less jobs last
        // at equal slack, the heavier tenant launches first
        let heavy = o.urgency_key(4.0, 3600.0, 0.0, Some(4000.0), 0.0);
        assert!(heavy.unwrap() < tight.unwrap());
        // overdue jobs rank ahead of everything with positive slack...
        let late = o.urgency_key(1.0, 600.0, 0.0, Some(100.0), 5000.0);
        assert!(late.unwrap() < tight.unwrap());
        assert!(late.unwrap() < heavy.unwrap());
        // ...and WSPT among themselves: heavy-short overdue jobs first
        let late_heavy_short =
            o.urgency_key(4.0, 300.0, 0.0, Some(100.0), 5000.0);
        assert!(late_heavy_short.unwrap() < late.unwrap());
    }

    #[test]
    fn wjct_urgency_is_weighted_shortest_first() {
        let o = Objective::WeightedJct { alpha: 0.5 };
        let heavy_short = o.urgency_key(4.0, 100.0, 0.0, None, 0.0);
        let light_short = o.urgency_key(1.0, 100.0, 0.0, None, 0.0);
        let heavy_long = o.urgency_key(4.0, 10_000.0, 0.0, None, 0.0);
        assert!(heavy_short.unwrap() < light_short.unwrap());
        assert!(light_short.unwrap() < heavy_long.unwrap());
    }
}
