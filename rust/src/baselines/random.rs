//! "Random" baseline (paper §3): randomize allocations, parallelisms and
//! schedule order. Each job draws a feasible (technique, gpus) pair once;
//! launch order is a random permutation. Seeded for reproducibility.

use crate::sim::engine::{Launch, PlanContext, Policy};
use crate::util::rng::Rng;

pub struct RandomPolicy {
    rng: Rng,
    /// job_id -> (tech, gpus, class); drawn lazily on first plan() call.
    assignment: Vec<Option<(usize, u32, usize)>>,
    order: Vec<usize>,
    initialized: bool,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        RandomPolicy {
            rng: Rng::new(seed),
            assignment: Vec::new(),
            order: Vec::new(),
            initialized: false,
        }
    }

    fn init(&mut self, ctx: &PlanContext) {
        let n = ctx.jobs.len();
        self.assignment = vec![None; n];
        for s in ctx.jobs {
            // draw uniformly over the FEASIBLE (tech, gpus, class) grid
            let mut options = Vec::new();
            for t in 0..ctx.profiles.n_techniques {
                for ci in 0..ctx.profiles.n_classes() {
                    for &g in &ctx.profiles.class_gpu_options[ci] {
                        if ctx.profiles.step_time(s.job.id, t, g, ci).is_some()
                        {
                            options.push((t, g, ci));
                        }
                    }
                }
            }
            if !options.is_empty() {
                let pick = *self.rng.choice(&options);
                self.assignment[s.job.id] = Some(pick);
            }
        }
        self.order = (0..n).collect();
        self.rng.shuffle(&mut self.order);
        self.initialized = true;
    }
}

impl Policy for RandomPolicy {
    fn name(&self) -> &'static str {
        "random"
    }

    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
        if !self.initialized {
            self.init(ctx);
        }
        let mut free = ctx.free.clone();
        let mut out = Vec::new();
        for &job_id in &self.order {
            let Some(s) = ctx.jobs.get(job_id) else { continue };
            if !s.is_pending() {
                continue;
            }
            let Some((tech, gpus, class)) = self.assignment[job_id] else {
                continue;
            };
            if free.place(class, gpus).is_some() {
                out.push(Launch { job_id, tech, gpus, class });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::sim::engine::{simulate, SimConfig};
    use crate::trials::profile_analytic;
    use crate::workload::wikitext_workload;

    #[test]
    fn completes_and_is_seed_deterministic() {
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let a = simulate(&jobs, &profiles, &cluster, &mut RandomPolicy::new(7),
                         &SimConfig::default());
        let b = simulate(&jobs, &profiles, &cluster, &mut RandomPolicy::new(7),
                         &SimConfig::default());
        let c = simulate(&jobs, &profiles, &cluster, &mut RandomPolicy::new(8),
                         &SimConfig::default());
        assert_eq!(a.makespan_s, b.makespan_s);
        assert!(a.finish_times.len() == 12 && c.finish_times.len() == 12);
    }

    #[test]
    fn different_seeds_usually_differ() {
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let ms: Vec<f64> = (0..4)
            .map(|s| {
                simulate(&jobs, &profiles, &cluster,
                         &mut RandomPolicy::new(s), &SimConfig::default())
                    .makespan_s
            })
            .collect();
        let distinct = ms
            .iter()
            .map(|m| (m * 1000.0) as i64)
            .collect::<std::collections::BTreeSet<_>>();
        assert!(distinct.len() > 1);
    }
}
