//! Optimus baseline (Peng et al., EuroSys'18; paper §3): greedy
//! marginal-gain GPU allocation. GPUs are handed out one (small quantum)
//! at a time to the job whose estimated remaining runtime improves most
//! per GPU. Parallelism per job = fastest feasible technique at the
//! assigned count.
//!
//! `Optimus` re-plans only when jobs complete (GPUs free up);
//! `OptimusDynamic` adds the same fixed-interval introspection mechanism
//! Saturn uses (checkpoint + full replan), isolating the value of the
//! *joint MILP* from the value of *introspection* in Table 2.

use crate::sim::engine::{Launch, PlanContext, Policy};

pub(crate) fn greedy_allocation(ctx: &PlanContext) -> Vec<Launch> {
    // candidate jobs: pending, with at least one feasible plan
    let pending: Vec<usize> = ctx
        .jobs
        .iter()
        .filter(|s| s.is_pending())
        .map(|s| s.job.id)
        .collect();
    if pending.is_empty() {
        return Vec::new();
    }
    let n_classes = ctx.profiles.n_classes();
    // per-class GPU budgets: Optimus hands out quanta within a class (a
    // job's collective group never spans classes)
    let mut budget: Vec<u32> =
        (0..n_classes).map(|ci| ctx.free.class_free(ci)).collect();
    // job -> (class, gpus); the first quantum picks the class
    let mut alloc: Vec<Option<(usize, u32)>> = vec![None; ctx.jobs.len()];

    // remaining runtime for job j at (class, g) (None: infeasible)
    let runtime = |job_id: usize, class: usize, g: u32| -> Option<f64> {
        let steps = ctx.jobs[job_id].remaining_steps() as f64;
        ctx.profiles.best_at(job_id, g, class).map(|(_, t)| t * steps)
    };

    // Optimus quantum: step each job up its class's allocation ladder
    loop {
        // (job, class, next_g, gain/gpu)
        let mut best: Option<(usize, usize, u32, f64)> = None;
        for &j in &pending {
            match alloc[j] {
                None => {
                    // first quantum: the smallest feasible rung of EVERY
                    // class competes; gain prioritizes by resulting
                    // throughput (making the job runnable at all)
                    for (ci, &cap) in budget.iter().enumerate() {
                        let next = ctx.profiles.class_gpu_options[ci]
                            .iter()
                            .copied()
                            .find(|&g| runtime(j, ci, g).is_some());
                        let Some(next) = next else { continue };
                        if next > cap {
                            continue;
                        }
                        let next_rt = runtime(j, ci, next)
                            .expect("feasibility checked above");
                        let gain = 1e12 / next_rt.max(1e-9);
                        if gain > 0.0
                            && best.map(|b| gain > b.3).unwrap_or(true)
                        {
                            best = Some((j, ci, next, gain));
                        }
                    }
                }
                Some((ci, cur)) => {
                    // next FEASIBLE rung within the assigned class (e.g.
                    // GPT-J may be infeasible below 8 GPUs)
                    let next = ctx.profiles.class_gpu_options[ci]
                        .iter()
                        .copied()
                        .find(|&g| g > cur && runtime(j, ci, g).is_some());
                    let Some(next) = next else { continue };
                    let delta_g = next - cur;
                    if delta_g > budget[ci] {
                        continue;
                    }
                    let cur_rt = match runtime(j, ci, cur) {
                        Some(t) => t,
                        None => f64::INFINITY,
                    };
                    let next_rt = runtime(j, ci, next)
                        .expect("feasibility checked above");
                    let gain = if cur_rt.is_infinite() {
                        1e12 / next_rt.max(1e-9)
                    } else {
                        (cur_rt - next_rt).max(0.0) / delta_g as f64
                    };
                    if gain > 0.0 && best.map(|b| gain > b.3).unwrap_or(true)
                    {
                        best = Some((j, ci, next, gain));
                    }
                }
            }
        }
        let Some((j, ci, next, _)) = best else { break };
        budget[ci] -= next - alloc[j].map(|(_, g)| g).unwrap_or(0);
        alloc[j] = Some((ci, next));
    }

    // realize: check placement feasibility in allocation order. When
    // capacity is short, the objective decides who places first
    // (PlanContext::objective): least slack under tardiness, most
    // weight-per-second under the JCT blend; makespan keeps the
    // historical biggest-allocation-first order bit for bit.
    let mut free = ctx.free.clone();
    let mut out = Vec::new();
    let mut jobs_sorted = pending.clone();
    let urgency = |j: usize| {
        let s = &ctx.jobs[j];
        let rt = alloc[j]
            .and_then(|(ci, g)| runtime(j, ci, g))
            .unwrap_or(f64::INFINITY);
        ctx.objective
            .urgency_key(s.priority, rt, s.arrival_s, s.deadline_s, ctx.now)
    };
    jobs_sorted.sort_by(|&a, &b| {
        let historical = alloc[b]
            .map(|(_, g)| g)
            .unwrap_or(0)
            .cmp(&alloc[a].map(|(_, g)| g).unwrap_or(0));
        match (urgency(a), urgency(b)) {
            (Some(ka), Some(kb)) => ka
                .partial_cmp(&kb)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(historical),
            _ => historical,
        }
    });
    for j in jobs_sorted {
        let Some((ci, g)) = alloc[j] else { continue };
        if let Some((tech, _)) = ctx.profiles.best_at(j, g, ci) {
            if free.place(ci, g).is_some() {
                out.push(Launch { job_id: j, tech, gpus: g, class: ci });
            }
        }
    }
    out
}

#[derive(Default)]
pub struct Optimus;

impl Policy for Optimus {
    fn name(&self) -> &'static str {
        "optimus"
    }

    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
        greedy_allocation(ctx)
    }
}

pub struct OptimusDynamic {
    pub introspect_every_s: f64,
}

impl Default for OptimusDynamic {
    fn default() -> Self {
        OptimusDynamic { introspect_every_s: 3600.0 }
    }
}

impl Policy for OptimusDynamic {
    fn name(&self) -> &'static str {
        "optimus-dynamic"
    }

    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
        greedy_allocation(ctx)
    }

    fn introspection_interval(&self) -> Option<f64> {
        Some(self.introspect_every_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::sim::engine::{simulate, SimConfig};
    use crate::trials::profile_analytic;
    use crate::workload::{imagenet_workload, wikitext_workload};

    fn run(policy: &mut dyn Policy, nodes: u32, vision: bool) -> f64 {
        let jobs = if vision { imagenet_workload() } else { wikitext_workload() };
        let cluster = ClusterSpec::p4d(nodes);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        simulate(&jobs, &profiles, &cluster, policy, &SimConfig::default())
            .makespan_s
    }

    #[test]
    fn optimus_completes() {
        assert!(run(&mut Optimus, 1, false) > 0.0);
    }

    #[test]
    fn dynamic_beats_static() {
        // the paper's Table 2 ordering: Optimus-Dynamic < Optimus
        let s = run(&mut Optimus, 1, false);
        let d = run(&mut OptimusDynamic::default(), 1, false);
        assert!(d <= s * 1.05, "dynamic {d} vs static {s}");
    }

    #[test]
    fn optimus_shares_the_cluster() {
        // unlike CurrentPractice, Optimus runs multiple jobs concurrently:
        // utilization-driven makespan must beat pure sequencing on vision
        let jobs = imagenet_workload();
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let r = simulate(&jobs, &profiles, &cluster, &mut Optimus,
                         &SimConfig::default());
        assert!(r.launches >= 12);
    }
}
