//! Online variants of the §3 baselines for the streaming setting, so
//! `bench_online` compares Saturn's event-driven joint re-solves against
//! the same allocation philosophies under identical arrival traces
//! (DESIGN.md §Online).
//!
//!  * [`OnlineCurrentPractice`] — FIFO-by-priority, one whole node per
//!    job, no elasticity: arrivals queue until a node frees up.
//!  * [`OnlineOptimus`] — Optimus' greedy marginal-gain allocation,
//!    re-run with preempt-and-replan at every arrival/departure event
//!    (the natural online extension of `OptimusDynamic`).

use crate::baselines::current_practice::best_free_node;
use crate::baselines::optimus::greedy_allocation;
use crate::objective::Objective;
use crate::sim::engine::{JobProgress, Launch, PlanContext, Policy};

/// FIFO whole-node scheduling with tenant priorities: the highest-priority
/// pending job (ties: earliest id = earliest arrival) takes the next free
/// node. Running jobs are never disturbed.
///
/// The queue order is objective-aware (`PlanContext::objective`) so the
/// baseline competes under the same goal as Saturn: `tardiness` serves
/// earliest-deadline-first, `wjct` serves the highest weight per
/// remaining step; `makespan` keeps the historical priority-then-id
/// order bit for bit.
#[derive(Default)]
pub struct OnlineCurrentPractice;

/// The FIFO baseline's queue key under a non-makespan objective
/// (`None` = historical order). The baseline never profiles runtimes,
/// so EDF uses the raw deadline instant and the JCT blend uses
/// remaining steps as its work proxy.
fn fifo_urgency(objective: &Objective, s: &JobProgress, now: f64)
    -> Option<f64> {
    match *objective {
        Objective::Makespan => None,
        // the alpha = 1 endpoint IS makespan: keep its ordering here
        // too (matches Objective::urgency_key's degeneracy)
        Objective::WeightedJct { alpha } if alpha >= 1.0 => None,
        Objective::WeightedTardiness { .. } => Some(
            s.deadline_s
                .map(|d| s.arrival_s + d - now)
                .unwrap_or(f64::INFINITY),
        ),
        Objective::WeightedJct { .. } => Some(
            -(s.priority / (s.remaining_steps() as f64).max(1.0)),
        ),
    }
}

impl Policy for OnlineCurrentPractice {
    fn name(&self) -> &'static str {
        "online-current-practice"
    }

    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
        let mut pending: Vec<_> =
            ctx.jobs.iter().filter(|s| s.is_pending()).collect();
        pending.sort_by(|a, b| {
            let historical = b
                .priority
                .partial_cmp(&a.priority)
                .unwrap()
                .then(a.job.id.cmp(&b.job.id));
            match (fifo_urgency(&ctx.objective, a, ctx.now),
                   fifo_urgency(&ctx.objective, b, ctx.now)) {
                (Some(ka), Some(kb)) => ka
                    .partial_cmp(&kb)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(historical),
                _ => historical,
            }
        });
        let mut free = ctx.free.clone();
        let mut out = Vec::new();
        for s in pending {
            if let Some((class, tech, g)) = best_free_node(ctx, &free, s.job.id)
            {
                if free.place(class, g).is_some() {
                    out.push(Launch { job_id: s.job.id, tech, gpus: g, class });
                }
            }
        }
        out
    }
}

/// Optimus with event-driven elasticity: every arrival and departure
/// preempts the cluster and re-runs the greedy marginal-gain allocation
/// over all unfinished jobs (checkpoint lag charged on shape changes by
/// the engine). Optional periodic introspection on top.
#[derive(Default)]
pub struct OnlineOptimus {
    pub introspect_every_s: Option<f64>,
}

impl Policy for OnlineOptimus {
    fn name(&self) -> &'static str {
        "online-optimus"
    }

    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
        greedy_allocation(ctx)
    }

    fn introspection_interval(&self) -> Option<f64> {
        self.introspect_every_s
    }

    fn replan_on_events(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::sim::engine::{simulate_online, SimConfig};
    use crate::trials::profile_analytic;
    use crate::workload::{generate_trace, TraceConfig};

    fn setup() -> (crate::workload::Trace, crate::trials::ProfileTable,
                   ClusterSpec) {
        let trace = generate_trace(&TraceConfig {
            seed: 11,
            multijobs: 3,
            ..Default::default()
        });
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let jobs: Vec<_> = trace.jobs.iter().map(|o| o.job.clone()).collect();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        (trace, profiles, cluster)
    }

    #[test]
    fn online_current_practice_completes_stream() {
        let (trace, profiles, cluster) = setup();
        let r = simulate_online(&trace.jobs, None, &profiles, &cluster,
                                &mut OnlineCurrentPractice,
                                &SimConfig::default());
        assert_eq!(r.finish_times.len(), trace.jobs.len());
        assert_eq!(r.preemptions, 0, "FIFO must not preempt");
        assert!(r.peak_gpus <= cluster.total_gpus());
    }

    #[test]
    fn online_optimus_completes_stream_elastically() {
        let (trace, profiles, cluster) = setup();
        let r = simulate_online(&trace.jobs, None, &profiles, &cluster,
                                &mut OnlineOptimus::default(),
                                &SimConfig::default());
        assert_eq!(r.finish_times.len(), trace.jobs.len());
        assert!(r.peak_gpus <= cluster.total_gpus());
        // elastic sharing launches more than one job concurrently at some
        // point, so total launches >= job count
        assert!(r.launches >= trace.jobs.len());
    }

    #[test]
    fn priorities_reorder_the_fifo_queue() {
        // two jobs arriving together, one high priority: with a single
        // node, the high-priority one must run first
        let (mut trace, profiles, cluster) = setup();
        for oj in trace.jobs.iter_mut() {
            oj.arrival_s = 0.0;
            oj.priority = 1.0;
        }
        let last = trace.jobs.len() - 1;
        trace.jobs[last].priority = 10.0;
        let r = simulate_online(&trace.jobs, None, &profiles, &cluster,
                                &mut OnlineCurrentPractice,
                                &SimConfig::default());
        let first_departure = r
            .finish_times
            .iter()
            .cloned()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert_eq!(first_departure.0, last,
                   "high-priority job did not run first: {:?}",
                   r.finish_times);
    }
}
