//! "Current Practice" baseline (paper §3): each job gets ALL GPUs of one
//! node, jobs run in sequence per node, task parallelism across nodes.
//! The parallelism technique is whatever the practitioner would reach for:
//! the fastest feasible one at full-node width (practitioners tune their
//! single job well — the inefficiency is in the one-job-at-a-time

//! resource usage, which is exactly what the paper critiques).

use crate::sim::engine::{Launch, PlanContext, Policy};
use crate::sim::placement::FreeState;

/// The fastest whole-node (class, tech, gpus) for `job` among classes
/// that still have a free node. Shared by the batch and online
/// current-practice baselines.
pub(crate) fn best_free_node(ctx: &PlanContext, free: &FreeState,
                             job: usize) -> Option<(usize, usize, u32)> {
    let mut best: Option<(usize, usize, u32, f64)> = None;
    for ci in 0..ctx.cluster.n_classes() {
        let g = ctx.cluster.class(ci).node.gpus_per_node;
        if !free.can_place(ci, g) {
            continue;
        }
        if let Some((tech, t)) = ctx.profiles.best_at(job, g, ci) {
            if best.map(|b| t < b.3).unwrap_or(true) {
                best = Some((ci, tech, g, t));
            }
        }
    }
    best.map(|(ci, tech, g, _)| (ci, tech, g))
}

#[derive(Default)]
pub struct CurrentPractice;

impl Policy for CurrentPractice {
    fn name(&self) -> &'static str {
        "current-practice"
    }

    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
        let mut free = ctx.free.clone();
        let mut out = Vec::new();
        // FIFO over pending jobs; one whole node each. On a mixed fleet
        // the practitioner grabs the fastest class that has a free node
        // (everyone asks for the H100s first — exactly the contention the
        // joint solver is supposed to beat).
        for s in ctx.jobs.iter().filter(|s| s.is_pending()) {
            if let Some((class, tech, g)) = best_free_node(ctx, &free, s.job.id)
            {
                if free.place(class, g).is_some() {
                    out.push(Launch { job_id: s.job.id, tech, gpus: g, class });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::sim::engine::{simulate, SimConfig};
    use crate::trials::profile_analytic;
    use crate::workload::wikitext_workload;

    #[test]
    fn serializes_on_one_node() {
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let r = simulate(&jobs, &profiles, &cluster, &mut CurrentPractice,
                         &SimConfig::default());
        // makespan equals the sum of full-node runtimes (pure sequence)
        let expected: f64 = jobs
            .iter()
            .map(|j| {
                let (t, _) = profiles.best_at(j.id, 8, 0).unwrap();
                profiles.step_time(j.id, t, 8, 0).unwrap()
                    * j.total_steps() as f64
            })
            .sum();
        assert!((r.makespan_s - expected).abs() / expected < 1e-9);
    }

    #[test]
    fn mixed_fleet_grabs_the_fast_class_first() {
        // one A100 node + one H100 node: whole-node FIFO still completes
        // everything, and at least one job lands on each class (twelve
        // jobs cannot all fit the single H100 node at once)
        let jobs = wikitext_workload();
        let cluster = ClusterSpec::hetero(1, 1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let r = simulate(&jobs, &profiles, &cluster, &mut CurrentPractice,
                         &SimConfig::default());
        assert_eq!(r.finish_times.len(), 12);
        assert!(r.gpu_utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn two_nodes_halve_ish() {
        let jobs = wikitext_workload();
        let lib = default_library();
        let c1 = ClusterSpec::p4d(1);
        let c2 = ClusterSpec::p4d(2);
        let p1 = profile_analytic(&jobs, &lib, &c1);
        let p2 = profile_analytic(&jobs, &lib, &c2);
        let r1 = simulate(&jobs, &p1, &c1, &mut CurrentPractice,
                          &SimConfig::default());
        let r2 = simulate(&jobs, &p2, &c2, &mut CurrentPractice,
                          &SimConfig::default());
        assert!(r2.makespan_s < r1.makespan_s * 0.65);
        assert!(r2.makespan_s > r1.makespan_s * 0.40);
    }
}
