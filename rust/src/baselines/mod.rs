//! The four baselines of paper §3: Current Practice, Random, Optimus, and
//! Optimus-Dynamic. All implement `sim::Policy`, so Table 2 compares them
//! and Saturn under identical simulator semantics.

pub mod current_practice;
pub mod online;
pub mod optimus;
pub mod random;

pub use current_practice::CurrentPractice;
pub use online::{OnlineCurrentPractice, OnlineOptimus};
pub use optimus::{Optimus, OptimusDynamic};
pub use random::RandomPolicy;
