//! # Saturn — Efficient Multi-Large-Model Deep Learning (reproduction)
//!
//! A Rust + JAX + Pallas reproduction of *Saturn* (Nagrecha & Kumar, 2023):
//! a data system that jointly optimizes **parallelism selection**, **GPU
//! allocation**, and **scheduling** for multi-large-model training (model
//! selection / HPO over large models).
//!
//! Three-layer architecture (Python never on the execution path):
//!  * **L3 (this crate)** — the Parallelism Library ([`parallelism`]), the
//!    Trial Runner ([`trials`]), the joint MILP Solver with introspection
//!    ([`saturn`], [`solver`]), the online scheduling subsystem
//!    ([`online`], streaming arrivals + early-stopping departures), the
//!    performance-model layer ([`perf`], estimate-vs-truth split with
//!    drift and online correction), the baselines ([`baselines`]), the
//!    cluster simulator ([`sim`]), the observability flight recorder
//!    ([`obs`], structured tracing + metrics), and the PJRT execution
//!    runtime ([`runtime`]).
//!  * **L2** — `python/compile/model.py`: GPT-mini fwd/bwd+AdamW in JAX,
//!    AOT-lowered to HLO text in `artifacts/`.
//!  * **L1** — `python/compile/kernels/`: Pallas flash-attention, fused
//!    LayerNorm and fused AdamW kernels (interpret=True).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results (Table 2 et al.).

pub mod baselines;
pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod faults;
pub mod models;
pub mod objective;
pub mod obs;
pub mod online;
pub mod parallelism;
pub mod perf;
pub mod runtime;
pub mod saturn;
pub mod sim;
pub mod solver;
pub mod trials;
pub mod util;
pub mod workload;
