//! Timeline analysis over a flight-recorder journal: the engine behind
//! `saturn trace-summarize`. Everything here is derived from the JSONL
//! journal ALONE — phase-time breakdown, re-solve cause histogram,
//! queue-depth and decision-latency tails, per-bucket GPU utilization —
//! so a trace file is a self-contained artifact.

use crate::obs::metrics::Histogram;
use crate::obs::trace::{paired_spans, validate, TraceEvent};
use crate::util::json::Json;

use std::collections::BTreeMap;

/// Aggregated wall time for one solver phase.
#[derive(Debug, Clone)]
pub struct PhaseRow {
    pub name: String,
    pub count: usize,
    pub total_wall_s: f64,
}

#[derive(Debug)]
pub struct TraceSummary {
    pub events: usize,
    /// Sim-time horizon (run_end makespan when present, else max stamp).
    pub horizon_s: f64,
    /// Fleet size from the run_begin record (0 when absent).
    pub total_gpus: f64,
    /// Lifecycle instant counts by name (arrival, launch, complete, ...).
    pub lifecycle: Vec<(String, usize)>,
    /// Plan-call causes (every policy, every `sched/plan` span).
    pub plan_causes: Vec<(String, usize)>,
    /// Joint re-solve causes (`solver/resolve` spans). Journals written
    /// with the incremental path on carry a `delta` flag on each
    /// resolve span; those causes split into `cause (delta)` /
    /// `cause (full)` rows. Older journals (no flag) keep the plain
    /// cause rows.
    pub resolve_causes: Vec<(String, usize)>,
    /// `sched/coalesce` instants: events the debounce window folded
    /// into a later re-solve (0 on journals predating the feature).
    pub coalesced: usize,
    /// Solver phase spans aggregated by name, sorted by total wall desc.
    pub phases: Vec<PhaseRow>,
    /// Wall duration of `sched/plan` spans (policy decision latency).
    pub decision: Histogram,
    /// Wall duration of joint re-solves (`solver/resolve`, falling back
    /// to `solver/solve` for batch `plan` traces).
    pub solve: Histogram,
    /// Pending-queue depth sampled at each plan call.
    pub queue_depth: Histogram,
    /// (bucket start sim-time, mean busy GPUs over the bucket).
    pub utilization: Vec<(f64, f64)>,
}

const UTIL_BUCKETS: usize = 12;

/// Validate a journal and derive the report model from it.
pub fn summarize(events: &[TraceEvent]) -> Result<TraceSummary, String> {
    validate(events)?;
    let spans = paired_spans(events)?;

    let mut total_gpus = 0.0;
    let mut horizon_s: f64 = 0.0;
    let mut lifecycle: BTreeMap<String, usize> = BTreeMap::new();
    let mut coalesced = 0usize;
    let mut queue_depth = Histogram::new();
    let mut busy: Vec<(f64, f64)> = Vec::new();
    for e in events {
        horizon_s = horizon_s.max(e.t_s);
        match (e.cat.as_str(), e.name.as_str()) {
            ("meta", "run_begin") => {
                if let Some(g) = e.args.get("gpus").and_then(Json::as_f64)
                {
                    total_gpus = g;
                }
            }
            ("meta", "run_end") => {
                if let Some(m) =
                    e.args.get("makespan_s").and_then(Json::as_f64)
                {
                    horizon_s = horizon_s.max(m);
                }
            }
            ("job", name) => {
                *lifecycle.entry(name.to_string()).or_insert(0) += 1;
            }
            ("sched", "coalesce") => {
                coalesced += 1;
            }
            ("metrics", "busy_gpus") => {
                if let Some(b) =
                    e.args.get("total").and_then(Json::as_f64)
                {
                    busy.push((e.t_s, b));
                }
            }
            _ => {}
        }
    }

    let mut plan_causes: BTreeMap<String, usize> = BTreeMap::new();
    let mut resolve_causes: BTreeMap<String, usize> = BTreeMap::new();
    let mut phase_agg: BTreeMap<String, (usize, f64)> = BTreeMap::new();
    let mut decision = Histogram::new();
    let mut solve = Histogram::new();
    let mut top_solve = Histogram::new();
    for s in &spans {
        match (s.cat.as_str(), s.name.as_str()) {
            ("sched", "plan") => {
                let cause = s
                    .args
                    .get("cause")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown");
                *plan_causes.entry(cause.to_string()).or_insert(0) += 1;
                if let Some(d) = s.wall_dur_s() {
                    decision.observe(d.max(0.0));
                }
                if let Some(p) =
                    s.args.get("pending").and_then(Json::as_f64)
                {
                    queue_depth.observe(p);
                }
            }
            ("solver", name) => {
                let agg = phase_agg
                    .entry(name.to_string())
                    .or_insert((0, 0.0));
                agg.0 += 1;
                if let Some(d) = s.wall_dur_s() {
                    agg.1 += d.max(0.0);
                }
                if name == "resolve" {
                    let cause = s
                        .args
                        .get("cause")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown");
                    // incremental-era journals tag each re-solve with
                    // the path taken; older journals have no flag and
                    // keep the plain cause row
                    let key = match s
                        .args
                        .get("delta")
                        .and_then(Json::as_bool)
                    {
                        Some(true) => format!("{cause} (delta)"),
                        Some(false) => format!("{cause} (full)"),
                        None => cause.to_string(),
                    };
                    *resolve_causes.entry(key).or_insert(0) += 1;
                    if let Some(d) = s.wall_dur_s() {
                        solve.observe(d.max(0.0));
                    }
                } else if name == "solve" {
                    if let Some(d) = s.wall_dur_s() {
                        top_solve.observe(d.max(0.0));
                    }
                }
            }
            _ => {}
        }
    }
    if solve.is_empty() {
        solve = top_solve;
    }

    let mut phases: Vec<PhaseRow> = phase_agg
        .into_iter()
        .map(|(name, (count, total_wall_s))| PhaseRow {
            name,
            count,
            total_wall_s,
        })
        .collect();
    phases.sort_by(|a, b| {
        b.total_wall_s
            .partial_cmp(&a.total_wall_s)
            .unwrap_or(std::cmp::Ordering::Equal)
    });

    Ok(TraceSummary {
        events: events.len(),
        horizon_s,
        total_gpus,
        lifecycle: lifecycle.into_iter().collect(),
        plan_causes: plan_causes.into_iter().collect(),
        resolve_causes: resolve_causes.into_iter().collect(),
        coalesced,
        phases,
        decision,
        solve,
        queue_depth,
        utilization: utilization_timeline(&busy, horizon_s),
    })
}

/// Step-integrate `metrics/busy_gpus` samples into fixed sim-time
/// buckets: each sample holds from its stamp to the next one's.
fn utilization_timeline(
    busy: &[(f64, f64)],
    horizon_s: f64,
) -> Vec<(f64, f64)> {
    if busy.is_empty() || horizon_s <= 0.0 {
        return Vec::new();
    }
    let width = horizon_s / UTIL_BUCKETS as f64;
    let mut area = vec![0.0f64; UTIL_BUCKETS];
    for (i, &(t0, b)) in busy.iter().enumerate() {
        let t1 = busy
            .get(i + 1)
            .map(|&(t, _)| t)
            .unwrap_or(horizon_s)
            .min(horizon_s);
        let (mut lo, hi) = (t0.min(horizon_s), t1);
        while lo < hi {
            let k = ((lo / width) as usize).min(UTIL_BUCKETS - 1);
            let edge = (width * (k + 1) as f64).min(hi);
            area[k] += b * (edge - lo);
            lo = edge;
        }
    }
    area.iter()
        .enumerate()
        .map(|(k, a)| (width * k as f64, a / width))
        .collect()
}

fn fmt_ms(x: f64) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{:.2}", x * 1e3)
    }
}

fn push_tail(out: &mut String, label: &str, h: &Histogram) {
    if h.is_empty() {
        out.push_str(&format!("{label}: no wall-stamped samples\n"));
        return;
    }
    out.push_str(&format!(
        "{label}: n={} p50={} p90={} p95={} p99={} max={} ms\n",
        h.count() as u64,
        fmt_ms(h.percentile(0.50)),
        fmt_ms(h.percentile(0.90)),
        fmt_ms(h.percentile(0.95)),
        fmt_ms(h.percentile(0.99)),
        fmt_ms(h.max()),
    ));
}

fn push_causes(out: &mut String, title: &str, causes: &[(String, usize)]) {
    if causes.is_empty() {
        return;
    }
    out.push_str(&format!("{title}:\n"));
    for (cause, n) in causes {
        out.push_str(&format!("  {cause:<14} {n:>6}\n"));
    }
}

/// Human-readable report (the `trace-summarize` stdout).
pub fn render(s: &TraceSummary) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "trace: {} events over {:.2} h sim-time\n",
        s.events,
        s.horizon_s / 3600.0
    ));
    if !s.lifecycle.is_empty() {
        out.push_str("job lifecycle:\n");
        for (name, n) in &s.lifecycle {
            out.push_str(&format!("  {name:<14} {n:>6}\n"));
        }
    }
    push_causes(&mut out, "plan causes", &s.plan_causes);
    push_causes(&mut out, "re-solve causes", &s.resolve_causes);
    if s.coalesced > 0 {
        out.push_str(&format!(
            "coalesced events: {} (debounced into a later re-solve)\n",
            s.coalesced
        ));
    }
    if !s.phases.is_empty() {
        out.push_str(
            "solver phases (wall):\n  \
             phase              count   total_ms    mean_ms\n",
        );
        for p in &s.phases {
            let mean = if p.count > 0 {
                p.total_wall_s / p.count as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "  {:<18} {:>5} {:>10.2} {:>10.3}\n",
                p.name,
                p.count,
                p.total_wall_s * 1e3,
                mean * 1e3
            ));
        }
    }
    push_tail(&mut out, "decision latency", &s.decision);
    push_tail(&mut out, "solve latency", &s.solve);
    if !s.queue_depth.is_empty() {
        out.push_str(&format!(
            "queue depth at plan: p50={:.0} p95={:.0} max={:.0}\n",
            s.queue_depth.percentile(0.50),
            s.queue_depth.percentile(0.95),
            s.queue_depth.max()
        ));
    }
    if !s.utilization.is_empty() {
        let fleet = if s.total_gpus > 0.0 {
            s.total_gpus
        } else {
            s.utilization
                .iter()
                .map(|&(_, b)| b)
                .fold(1.0f64, f64::max)
        };
        out.push_str(&format!(
            "utilization (mean busy GPUs, fleet {fleet:.0}):\n"
        ));
        for &(t0, b) in &s.utilization {
            let frac = (b / fleet).clamp(0.0, 1.0);
            let bar = "#".repeat((frac * 40.0).round() as usize);
            out.push_str(&format!(
                "  {:>8.2}h | {bar:<40} {b:.1}\n",
                t0 / 3600.0
            ));
        }
    }
    out
}

/// JSON form of the report (`trace-summarize --json`).
pub fn to_json(s: &TraceSummary) -> Json {
    let count_map = |xs: &[(String, usize)]| {
        Json::Obj(
            xs.iter()
                .map(|(k, n)| (k.clone(), Json::num(*n as f64)))
                .collect(),
        )
    };
    Json::obj(vec![
        ("events", Json::num(s.events as f64)),
        ("horizon_s", Json::num(s.horizon_s)),
        ("total_gpus", Json::num(s.total_gpus)),
        ("lifecycle", count_map(&s.lifecycle)),
        ("plan_causes", count_map(&s.plan_causes)),
        ("resolve_causes", count_map(&s.resolve_causes)),
        ("coalesced_events", Json::num(s.coalesced as f64)),
        (
            "phases",
            Json::arr(s.phases.iter().map(|p| {
                Json::obj(vec![
                    ("name", Json::str(&p.name)),
                    ("count", Json::num(p.count as f64)),
                    ("total_wall_s", Json::num(p.total_wall_s)),
                ])
            })),
        ),
        ("decision_s", s.decision.to_json()),
        ("solve_s", s.solve.to_json()),
        ("queue_depth", s.queue_depth.to_json()),
        (
            "utilization",
            Json::arr(s.utilization.iter().map(|&(t, b)| {
                Json::obj(vec![
                    ("t_s", Json::num(t)),
                    ("busy_gpus", Json::num(b)),
                ])
            })),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Tracer;

    #[test]
    fn summarize_minimal_journal() {
        let t = Tracer::on();
        t.instant(
            "meta",
            "run_begin",
            Json::obj(vec![("gpus", Json::num(8.0))]),
        );
        t.instant("job", "arrival", Json::obj(vec![]));
        t.begin(
            "sched",
            "plan",
            Json::obj(vec![
                ("cause", Json::str("arrival")),
                ("pending", Json::num(1.0)),
            ]),
        );
        t.end("sched", "plan", Json::obj(vec![]));
        t.set_time(10.0);
        t.instant(
            "metrics",
            "busy_gpus",
            Json::obj(vec![("total", Json::num(4.0))]),
        );
        t.set_time(100.0);
        t.instant("job", "complete", Json::obj(vec![]));
        t.instant(
            "meta",
            "run_end",
            Json::obj(vec![("makespan_s", Json::num(100.0))]),
        );
        let s = summarize(&t.events()).unwrap();
        assert_eq!(s.total_gpus, 8.0);
        assert_eq!(s.horizon_s, 100.0);
        assert_eq!(s.plan_causes, vec![("arrival".to_string(), 1)]);
        assert!(!s.decision.is_empty());
        assert_eq!(s.queue_depth.count(), 1.0);
        assert_eq!(s.utilization.len(), 12);
        let rendered = render(&s);
        assert!(rendered.contains("p99"));
        assert!(rendered.contains("plan causes"));
        let j = to_json(&s);
        assert!(j.get("decision_s").unwrap().get("p99").is_some());
    }

    #[test]
    fn delta_flag_splits_resolve_causes_and_coalesce_is_counted() {
        let t = Tracer::on();
        t.instant(
            "meta",
            "run_begin",
            Json::obj(vec![("gpus", Json::num(8.0))]),
        );
        t.begin(
            "solver",
            "resolve",
            Json::obj(vec![
                ("cause", Json::str("arrival")),
                ("delta", Json::Bool(true)),
            ]),
        );
        t.end("solver", "resolve", Json::obj(vec![]));
        t.begin(
            "solver",
            "resolve",
            Json::obj(vec![
                ("cause", Json::str("arrival")),
                ("delta", Json::Bool(false)),
            ]),
        );
        t.end("solver", "resolve", Json::obj(vec![]));
        // a pre-incremental journal record: no delta flag, plain row
        t.begin(
            "solver",
            "resolve",
            Json::obj(vec![("cause", Json::str("rung"))]),
        );
        t.end("solver", "resolve", Json::obj(vec![]));
        t.instant(
            "sched",
            "coalesce",
            Json::obj(vec![("until", Json::num(30.0))]),
        );
        t.instant(
            "sched",
            "coalesce",
            Json::obj(vec![("until", Json::num(31.0))]),
        );
        let s = summarize(&t.events()).unwrap();
        assert_eq!(s.coalesced, 2);
        assert_eq!(
            s.resolve_causes,
            vec![
                ("arrival (delta)".to_string(), 1),
                ("arrival (full)".to_string(), 1),
                ("rung".to_string(), 1),
            ]
        );
        let rendered = render(&s);
        assert!(rendered.contains("coalesced events: 2"));
        assert!(rendered.contains("arrival (delta)"));
        let j = to_json(&s);
        assert_eq!(
            j.get("coalesced_events").and_then(Json::as_f64),
            Some(2.0)
        );
    }

    #[test]
    fn utilization_step_integration() {
        // busy 4 GPUs over [0,60), 8 over [60,120); horizon 120
        let samples = vec![(0.0, 4.0), (60.0, 8.0)];
        let tl = utilization_timeline(&samples, 120.0);
        assert_eq!(tl.len(), 12);
        assert!((tl[0].1 - 4.0).abs() < 1e-9);
        assert!((tl[11].1 - 8.0).abs() < 1e-9);
    }
}
