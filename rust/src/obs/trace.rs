//! Flight-recorder event journal: structured spans + instants stamped
//! with deterministic sim-time and (optionally) wall-time.
//!
//! The `Tracer` is the single sink every layer records through: the
//! engine stamps job lifecycle instants, the policies stamp re-solve
//! spans with cause attribution, and the solver stamps per-phase spans
//! (candidate generation, LP root, branch-and-bound, rolling windows,
//! local search). A disabled tracer (`Tracer::off()`, the default) is a
//! `None` behind the handle — `is_enabled()` is one branch and no
//! emission site allocates, so replays with tracing off are bit-identical
//! to untraced runs.
//!
//! Determinism contract: event `t_s` comes from an internal sim-time
//! register that only the engine advances (`set_time`), so spans emitted
//! deep inside the solver inherit the decision's sim-time and the journal
//! is reproducible event-for-event given the same seeds. Wall stamps are
//! measured from the tracer's epoch and never feed back into scheduling;
//! `Tracer::deterministic()` omits them entirely so journal BYTES are
//! stable across machines.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Chrome `trace_event` phase: `B`egin / `E`nd spans, `I`nstants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventPhase {
    Begin,
    End,
    Instant,
}

impl EventPhase {
    pub fn code(self) -> &'static str {
        match self {
            EventPhase::Begin => "B",
            EventPhase::End => "E",
            EventPhase::Instant => "I",
        }
    }

    pub fn parse(s: &str) -> Option<EventPhase> {
        match s {
            "B" => Some(EventPhase::Begin),
            "E" => Some(EventPhase::End),
            "I" => Some(EventPhase::Instant),
            _ => None,
        }
    }
}

/// One journal record. `seq` is a strictly increasing emission index
/// (ties on `t_s` are common — many events fire at one sim instant),
/// `t_s` is deterministic sim-time, `wall_s` is optional wall-clock
/// seconds since the tracer's epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub seq: u64,
    pub t_s: f64,
    pub wall_s: Option<f64>,
    pub phase: EventPhase,
    pub cat: String,
    pub name: String,
    pub args: Json,
}

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("seq", Json::num(self.seq as f64)),
            ("t", Json::num(self.t_s)),
        ];
        if let Some(w) = self.wall_s {
            pairs.push(("wall", Json::num(w)));
        }
        pairs.push(("ph", Json::str(self.phase.code())));
        pairs.push(("cat", Json::str(&self.cat)));
        pairs.push(("name", Json::str(&self.name)));
        pairs.push(("args", self.args.clone()));
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<TraceEvent, String> {
        let seq = v
            .get("seq")
            .and_then(Json::as_f64)
            .ok_or("missing 'seq'")? as u64;
        let t_s =
            v.get("t").and_then(Json::as_f64).ok_or("missing 't'")?;
        let wall_s = v.get("wall").and_then(Json::as_f64);
        let phase = v
            .get("ph")
            .and_then(Json::as_str)
            .and_then(EventPhase::parse)
            .ok_or("bad 'ph' (want B/E/I)")?;
        let cat = v
            .get("cat")
            .and_then(Json::as_str)
            .ok_or("missing 'cat'")?
            .to_string();
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or("missing 'name'")?
            .to_string();
        let args =
            v.get("args").cloned().unwrap_or(Json::obj(Vec::new()));
        Ok(TraceEvent { seq, t_s, wall_s, phase, cat, name, args })
    }
}

#[derive(Debug)]
struct State {
    now_s: f64,
    seq: u64,
    events: Vec<TraceEvent>,
}

#[derive(Debug)]
struct Inner {
    epoch: Instant,
    wall: bool,
    state: Mutex<State>,
}

/// Cheap cloneable handle; clones share one journal buffer. The default
/// (`Tracer::off()`) carries no buffer at all, so the disabled hot path
/// is a single `Option` check.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<Inner>>,
}

impl Tracer {
    /// Disabled sink — every emission is a no-op.
    pub fn off() -> Tracer {
        Tracer { inner: None }
    }

    /// Enabled with wall-clock stamps (CLI default for `--trace`).
    pub fn on() -> Tracer {
        Tracer::enabled(true)
    }

    /// Enabled WITHOUT wall stamps: journal bytes depend only on the
    /// seeds, so two runs of the same scenario diff clean.
    pub fn deterministic() -> Tracer {
        Tracer::enabled(false)
    }

    fn enabled(wall: bool) -> Tracer {
        Tracer {
            inner: Some(Arc::new(Inner {
                epoch: Instant::now(),
                wall,
                state: Mutex::new(State {
                    now_s: 0.0,
                    seq: 0,
                    events: Vec::new(),
                }),
            })),
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Advance the sim-time register (engine only). Clamped monotone so
    /// stale callers can never rewind the journal clock.
    pub fn set_time(&self, t_s: f64) {
        if let Some(inner) = &self.inner {
            let mut st = inner.state.lock().unwrap();
            if t_s > st.now_s {
                st.now_s = t_s;
            }
        }
    }

    fn emit(&self, phase: EventPhase, cat: &str, name: &str, args: Json) {
        if let Some(inner) = &self.inner {
            let mut st = inner.state.lock().unwrap();
            // stamp under the lock: wall times must be monotone in seq
            // order even with concurrent emitters (validate enforces it)
            let wall_s = if inner.wall {
                Some(inner.epoch.elapsed().as_secs_f64())
            } else {
                None
            };
            let ev = TraceEvent {
                seq: st.seq,
                t_s: st.now_s,
                wall_s,
                phase,
                cat: cat.to_string(),
                name: name.to_string(),
                args,
            };
            st.seq += 1;
            st.events.push(ev);
        }
    }

    /// Point event at the current sim-time.
    pub fn instant(&self, cat: &str, name: &str, args: Json) {
        self.emit(EventPhase::Instant, cat, name, args);
    }

    /// Open a span. Every `begin` must be matched by an `end` with the
    /// same `(cat, name)` — `validate` enforces the pairing.
    pub fn begin(&self, cat: &str, name: &str, args: Json) {
        self.emit(EventPhase::Begin, cat, name, args);
    }

    pub fn end(&self, cat: &str, name: &str, args: Json) {
        self.emit(EventPhase::End, cat, name, args);
    }

    /// Snapshot of the journal so far.
    pub fn events(&self) -> Vec<TraceEvent> {
        match &self.inner {
            Some(inner) => inner.state.lock().unwrap().events.clone(),
            None => Vec::new(),
        }
    }

    /// Drain the journal (leaves seq/time registers running).
    pub fn take(&self) -> Vec<TraceEvent> {
        match &self.inner {
            Some(inner) => {
                std::mem::take(&mut inner.state.lock().unwrap().events)
            }
            None => Vec::new(),
        }
    }
}

/// One line per event; the canonical on-disk journal format.
pub fn write_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Parse a JSONL journal; empty lines are skipped, errors carry the
/// 1-based line number.
pub fn parse_jsonl(s: &str) -> Result<Vec<TraceEvent>, String> {
    let mut out = Vec::new();
    for (i, line) in s.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| format!("line {}: {e}", i + 1))?;
        out.push(
            TraceEvent::from_json(&v)
                .map_err(|e| format!("line {}: {e}", i + 1))?,
        );
    }
    Ok(out)
}

/// Chrome `trace_event` JSON (Perfetto-loadable). All events land on the
/// sim timeline (pid 0 / tid 0, microseconds of sim-time); span events
/// that carry wall stamps are duplicated on a wall-clock track (tid 1)
/// so solver phases can be read in real milliseconds too.
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    let mut arr = Vec::new();
    for e in events {
        arr.push(Json::obj(vec![
            ("name", Json::str(&e.name)),
            ("cat", Json::str(&e.cat)),
            ("ph", Json::str(e.phase.code())),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(0.0)),
            ("ts", Json::num(e.t_s * 1e6)),
            ("args", e.args.clone()),
        ]));
        let wall_dup =
            e.wall_s.filter(|_| e.phase != EventPhase::Instant);
        if let Some(w) = wall_dup {
            arr.push(Json::obj(vec![
                ("name", Json::str(&e.name)),
                ("cat", Json::str(&e.cat)),
                ("ph", Json::str(e.phase.code())),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(1.0)),
                ("ts", Json::num(w * 1e6)),
                ("args", e.args.clone()),
            ]));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(arr)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// A paired begin/end span recovered from the journal.
#[derive(Debug, Clone)]
pub struct Span {
    pub cat: String,
    pub name: String,
    pub t0_s: f64,
    pub t1_s: f64,
    pub wall0_s: Option<f64>,
    pub wall1_s: Option<f64>,
    /// Nesting depth at `begin` (0 = top-level span).
    pub depth: usize,
    pub args: Json,
    pub end_args: Json,
}

impl Span {
    /// Wall duration when both stamps are present.
    pub fn wall_dur_s(&self) -> Option<f64> {
        match (self.wall0_s, self.wall1_s) {
            (Some(a), Some(b)) => Some(b - a),
            _ => None,
        }
    }
}

/// Pair up begin/end events (strict stack discipline per journal order).
/// Returned in END order. Errors on mismatched or unbalanced spans.
pub fn paired_spans(events: &[TraceEvent]) -> Result<Vec<Span>, String> {
    let mut stack: Vec<&TraceEvent> = Vec::new();
    let mut out = Vec::new();
    for e in events {
        match e.phase {
            EventPhase::Begin => stack.push(e),
            EventPhase::End => {
                let b = stack.pop().ok_or_else(|| {
                    format!(
                        "seq {}: end {}/{} with no open span",
                        e.seq, e.cat, e.name
                    )
                })?;
                if b.cat != e.cat || b.name != e.name {
                    return Err(format!(
                        "seq {}: end {}/{} closes {}/{}",
                        e.seq, e.cat, e.name, b.cat, b.name
                    ));
                }
                out.push(Span {
                    cat: b.cat.clone(),
                    name: b.name.clone(),
                    t0_s: b.t_s,
                    t1_s: e.t_s,
                    wall0_s: b.wall_s,
                    wall1_s: e.wall_s,
                    depth: stack.len(),
                    args: b.args.clone(),
                    end_args: e.args.clone(),
                });
            }
            EventPhase::Instant => {}
        }
    }
    if let Some(b) = stack.pop() {
        return Err(format!(
            "unclosed span {}/{} (seq {})",
            b.cat, b.name, b.seq
        ));
    }
    Ok(out)
}

/// Journal invariants: strictly increasing `seq`, monotone sim-time,
/// monotone wall-time, balanced spans.
pub fn validate(events: &[TraceEvent]) -> Result<(), String> {
    let mut last_seq: Option<u64> = None;
    let mut last_t = f64::NEG_INFINITY;
    let mut last_wall = f64::NEG_INFINITY;
    for e in events {
        if let Some(s) = last_seq {
            if e.seq <= s {
                return Err(format!(
                    "seq not increasing: {} after {s}",
                    e.seq
                ));
            }
        }
        last_seq = Some(e.seq);
        if e.t_s < last_t {
            return Err(format!(
                "sim-time rewound at seq {}: {} < {last_t}",
                e.seq, e.t_s
            ));
        }
        last_t = e.t_s;
        if let Some(w) = e.wall_s {
            if w < last_wall {
                return Err(format!(
                    "wall-time rewound at seq {}: {w} < {last_wall}",
                    e.seq
                ));
            }
            last_wall = w;
        }
    }
    paired_spans(events)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_tracer_is_inert() {
        let t = Tracer::off();
        assert!(!t.is_enabled());
        t.set_time(5.0);
        t.instant("a", "b", Json::obj(vec![]));
        t.begin("a", "b", Json::obj(vec![]));
        t.end("a", "b", Json::obj(vec![]));
        assert!(t.events().is_empty());
    }

    #[test]
    fn spans_pair_and_validate() {
        let t = Tracer::deterministic();
        t.set_time(1.0);
        t.begin("solver", "solve", Json::obj(vec![]));
        t.begin("solver", "lp_root", Json::obj(vec![]));
        t.end("solver", "lp_root", Json::obj(vec![]));
        t.set_time(2.0);
        t.instant("job", "complete", Json::obj(vec![]));
        t.end("solver", "solve", Json::obj(vec![]));
        let evs = t.events();
        validate(&evs).unwrap();
        let spans = paired_spans(&evs).unwrap();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "lp_root");
        assert_eq!(spans[0].depth, 1);
        assert_eq!(spans[1].name, "solve");
        assert_eq!(spans[1].depth, 0);
        assert_eq!(spans[1].t0_s, 1.0);
        assert_eq!(spans[1].t1_s, 2.0);
    }

    #[test]
    fn unbalanced_spans_rejected() {
        let t = Tracer::deterministic();
        t.begin("a", "x", Json::obj(vec![]));
        assert!(validate(&t.events()).is_err());
        let t2 = Tracer::deterministic();
        t2.begin("a", "x", Json::obj(vec![]));
        t2.end("a", "y", Json::obj(vec![]));
        assert!(validate(&t2.events()).is_err());
    }

    #[test]
    fn jsonl_round_trip() {
        let t = Tracer::on();
        t.set_time(0.5);
        t.begin(
            "sched",
            "plan",
            Json::obj(vec![("cause", Json::str("arrival"))]),
        );
        t.end(
            "sched",
            "plan",
            Json::obj(vec![("launches", Json::num(3.0))]),
        );
        t.instant("job", "launch", Json::obj(vec![]));
        let evs = t.events();
        let text = write_jsonl(&evs);
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(evs, back);
    }

    #[test]
    fn set_time_is_monotone() {
        let t = Tracer::deterministic();
        t.set_time(3.0);
        t.set_time(1.0); // stale caller must not rewind
        t.instant("a", "b", Json::obj(vec![]));
        assert_eq!(t.events()[0].t_s, 3.0);
    }

    #[test]
    fn chrome_trace_shape() {
        let t = Tracer::on();
        t.begin("solver", "solve", Json::obj(vec![]));
        t.end("solver", "solve", Json::obj(vec![]));
        t.instant("job", "arrival", Json::obj(vec![]));
        let v = chrome_trace(&t.events());
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 span events duplicated on the wall track + 1 instant
        assert_eq!(evs.len(), 5);
        assert!(evs.iter().all(|e| e.get("ph").is_some()));
    }
}
