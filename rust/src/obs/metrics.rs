//! Process-wide metrics: counters, gauges, and log-bucketed histograms
//! with true p50/p95/p99 percentiles (the end-of-run means in
//! `OnlineMetrics` hide exactly the tail the ROADMAP's service-loop
//! work cares about).
//!
//! The histogram is HdrHistogram-flavoured: geometric buckets growing by
//! `2^(1/8)` (8 sub-buckets per octave, ~9% relative error) from 1 ns up
//! past 1e9 s, with f64 WEIGHTED counts so duration-weighted series
//! (e.g. queue depth over time) use the same machinery. Percentiles
//! interpolate linearly inside the winning bucket and clamp to the
//! observed `[min, max]`.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

/// Smallest representable observation (1 ns); below this lands in the
/// underflow bucket.
const BUCKET_MIN: f64 = 1e-9;
/// Sub-buckets per octave (relative error ~ `2^(1/8)-1` ~ 9%).
const SUB_BUCKETS: usize = 8;
/// 60 octaves x 8: covers 1e-9 .. ~1.15e9.
const N_BUCKETS: usize = 60 * SUB_BUCKETS;

/// Log-bucketed histogram over non-negative observations.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<f64>,
    underflow: f64,
    total: f64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0.0; N_BUCKETS],
            underflow: 0.0,
            total: 0.0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_index(x: f64) -> Option<usize> {
        if x < BUCKET_MIN {
            return None;
        }
        let i = ((x / BUCKET_MIN).log2() * SUB_BUCKETS as f64) as usize;
        Some(i.min(N_BUCKETS - 1))
    }

    fn bucket_lo(i: usize) -> f64 {
        BUCKET_MIN * (i as f64 / SUB_BUCKETS as f64).exp2()
    }

    pub fn observe(&mut self, x: f64) {
        self.observe_weighted(x, 1.0);
    }

    /// Weighted observation (weights <= 0 and NaN are ignored).
    pub fn observe_weighted(&mut self, x: f64, w: f64) {
        if w <= 0.0 || w.is_nan() || x.is_nan() {
            return;
        }
        let x = x.max(0.0);
        match Histogram::bucket_index(x) {
            Some(i) => self.counts[i] += w,
            None => self.underflow += w,
        }
        self.total += w;
        self.sum += x * w;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> f64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total <= 0.0
    }

    pub fn mean(&self) -> f64 {
        if self.is_empty() {
            f64::NAN
        } else {
            self.sum / self.total
        }
    }

    pub fn min(&self) -> f64 {
        if self.is_empty() {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.is_empty() {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Quantile `q` in [0,1]; NaN when empty. Linear interpolation
    /// inside the winning bucket, clamped to the observed range.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.is_empty() {
            return f64::NAN;
        }
        let target = q.clamp(0.0, 1.0) * self.total;
        let mut cum = self.underflow;
        if cum >= target {
            return self.min;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            if c <= 0.0 {
                continue;
            }
            if cum + c >= target {
                let lo = Histogram::bucket_lo(i);
                let mut hi = Histogram::bucket_lo(i + 1);
                if i + 1 == N_BUCKETS {
                    // overflow clamps into the top bucket; stretch it
                    // to the observed max so q=1 stays honest
                    hi = hi.max(self.max);
                }
                let frac = ((target - cum) / c).clamp(0.0, 1.0);
                let v = lo + frac * (hi - lo);
                return v.clamp(self.min, self.max);
            }
            cum += c;
        }
        self.max
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.total)),
            ("mean", Json::num(nan_to_zero(self.mean()))),
            ("min", Json::num(nan_to_zero(self.min()))),
            ("max", Json::num(nan_to_zero(self.max()))),
            ("p50", Json::num(nan_to_zero(self.percentile(0.50)))),
            ("p90", Json::num(nan_to_zero(self.percentile(0.90)))),
            ("p95", Json::num(nan_to_zero(self.percentile(0.95)))),
            ("p99", Json::num(nan_to_zero(self.percentile(0.99)))),
        ])
    }
}

fn nan_to_zero(x: f64) -> f64 {
    if x.is_nan() {
        0.0
    } else {
        x
    }
}

#[derive(Debug, Clone)]
enum Metric {
    Counter(u64),
    Gauge(f64),
    Hist(Box<Histogram>),
}

/// Named metric registry. Kind is fixed by the first write to a name;
/// later writes of a DIFFERENT kind are silently ignored (telemetry
/// must never panic the scheduler).
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut m = self.inner.lock().unwrap();
        let e =
            m.entry(name.to_string()).or_insert(Metric::Counter(0));
        if let Metric::Counter(c) = e {
            *c += by;
        }
    }

    pub fn set_gauge(&self, name: &str, v: f64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert(Metric::Gauge(v));
        if let Metric::Gauge(g) = e {
            *g = v;
        }
    }

    pub fn observe(&self, name: &str, x: f64) {
        let mut m = self.inner.lock().unwrap();
        let e = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Hist(Box::default()));
        if let Metric::Hist(h) = e {
            h.observe(x);
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        match self.inner.lock().unwrap().get(name) {
            Some(Metric::Counter(c)) => *c,
            _ => 0,
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        match self.inner.lock().unwrap().get(name) {
            Some(Metric::Gauge(g)) => Some(*g),
            _ => None,
        }
    }

    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        match self.inner.lock().unwrap().get(name) {
            Some(Metric::Hist(h)) => Some((**h).clone()),
            _ => None,
        }
    }

    pub fn snapshot(&self) -> Json {
        let m = self.inner.lock().unwrap();
        Json::Obj(
            m.iter()
                .map(|(k, v)| {
                    let j = match v {
                        Metric::Counter(c) => Json::num(*c as f64),
                        Metric::Gauge(g) => Json::num(*g),
                        Metric::Hist(h) => h.to_json(),
                    };
                    (k.clone(), j)
                })
                .collect(),
        )
    }
}

/// Process-wide registry. Coarse aggregate telemetry only — parallel
/// test binaries share it, so nothing asserts exact values on it; the
/// engine keeps per-run histograms locally.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_nan() {
        let h = Histogram::new();
        assert!(h.percentile(0.5).is_nan());
        assert!(h.mean().is_nan());
        assert_eq!(h.count(), 0.0);
    }

    #[test]
    fn uniform_percentiles_within_bucket_error() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.observe(i as f64);
        }
        let p50 = h.percentile(0.50);
        let p99 = h.percentile(0.99);
        assert!((p50 - 500.0).abs() / 500.0 < 0.10, "p50={p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.10, "p99={p99}");
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(1.0), 1000.0);
    }

    #[test]
    fn weighted_observations_shift_the_median() {
        let mut h = Histogram::new();
        h.observe_weighted(1.0, 9.0);
        h.observe_weighted(100.0, 1.0);
        assert!(h.percentile(0.5) < 1.2, "{}", h.percentile(0.5));
        assert!((h.mean() - 10.9).abs() < 1e-9);
        h.observe_weighted(5.0, 0.0); // ignored
        assert_eq!(h.count(), 10.0);
    }

    #[test]
    fn tiny_and_huge_values_clamp() {
        let mut h = Histogram::new();
        h.observe(0.0); // underflow bucket
        h.observe(1e12); // clamps to top bucket
        assert_eq!(h.percentile(0.0), 0.0);
        assert_eq!(h.percentile(1.0), 1e12);
    }

    #[test]
    fn registry_kinds_are_sticky() {
        let r = Registry::new();
        r.inc("a", 2);
        r.inc("a", 3);
        assert_eq!(r.counter("a"), 5);
        r.observe("a", 1.0); // wrong kind: ignored
        assert_eq!(r.counter("a"), 5);
        r.set_gauge("g", 7.5);
        assert_eq!(r.gauge("g"), Some(7.5));
        r.observe("h", 2.0);
        r.observe("h", 4.0);
        let h = r.histogram("h").unwrap();
        assert_eq!(h.count(), 2.0);
        assert!(r.snapshot().get("h").unwrap().get("p50").is_some());
    }
}
