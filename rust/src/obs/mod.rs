//! Observability substrate: the flight recorder (§DESIGN 4.6).
//!
//! * [`trace`] — deterministic structured event journal (spans +
//!   instants on sim-time, optional wall stamps), JSONL and Chrome
//!   `trace_event` serialization, pairing/validation helpers.
//! * [`metrics`] — counters, gauges, and log-bucketed histograms with
//!   true tail percentiles; a process-wide registry for coarse
//!   aggregates.
//! * [`summary`] — journal → report: phase-time breakdown, re-solve
//!   cause histogram, utilization timeline, tail-latency tables
//!   (`saturn trace-summarize`).
//!
//! Everything — Saturn and every baseline, the engine, the MILP — logs
//! through one `Tracer` handle threaded via `SimConfig`/`PlanContext`.
//! With the tracer off (the default) every emission site is a single
//! branch: replays are bit-identical to untraced builds.

pub mod metrics;
pub mod summary;
pub mod trace;

pub use trace::{TraceEvent, Tracer};
