//! `OnlineSaturn`: the joint MILP solver operated as an event-driven
//! online scheduler (DESIGN.md §Online).
//!
//! The engine preempts-and-replans at every arrival/departure event
//! (plus optional periodic introspection); this policy re-runs the joint
//! solve over the *unfinished* jobs only when the unfinished set actually
//! changed, warm-starting branch-and-bound from the previous plan so
//! event-rate re-solving stays cheap. Migration hysteresis keeps running
//! jobs on their allocation unless the fresh plan is decisively better —
//! the engine charges the checkpoint penalty whenever a relaunched job's
//! (technique, gpus) changed.

use std::time::Instant;

use crate::saturn::introspect::{apply_migration_hysteresis,
                                degraded_capacities, drift_resolve_due,
                                launch_from_plan, objective_terms,
                                DEFAULT_DRIFT_THRESHOLD};
use crate::obs::metrics::Histogram;
use crate::saturn::incremental::IncrementalSolver;
use crate::saturn::plan::SaturnPlan;
use crate::saturn::solver::{solve_joint_budgeted, SolveBudget, SolverMode,
                            SolverStats};
use crate::sim::engine::{Launch, PlanContext, Policy, ReplanCause};
use crate::util::json::Json;

pub struct OnlineSaturn {
    mode: SolverMode,
    /// Optional periodic introspection on top of event-driven replanning.
    pub introspect_every_s: Option<f64>,
    /// See `SaturnPolicy::migration_threshold`.
    pub migration_threshold: f64,
    /// Warm-start re-solves from the previous plan (ablation knob; the
    /// bench compares warm vs cold on identical events).
    pub warm_start: bool,
    /// When the unfinished set outgrows this many jobs, a `Joint` policy
    /// transparently switches that re-solve to the rolling-horizon
    /// decomposition (`SolverMode::rolling_default`) so event-rate
    /// re-solving stays interactive at 100+ concurrent jobs.
    pub rolling_threshold: usize,
    /// See `SaturnPolicy::drift_threshold`: re-solve when the estimate
    /// layer reports fresh observations whose observed/estimated
    /// mismatch crossed this |ln ratio| — the drift counterpart of the
    /// arrival/departure triggers. `None` disables.
    pub drift_threshold: Option<f64>,
    /// Re-solves fired by the drift trigger alone.
    pub drift_resolves: usize,
    /// Failure-aware mode (default): `ReplanCause::Failure` events
    /// bypass the plan cache and re-solves read the fleet's DEGRADED
    /// per-class capacities ([`degraded_capacities`]). `false` is the
    /// failure-blind ablation arm of `bench_faults` — stale caches and
    /// static capacity rows, as if the scheduler never heard of the
    /// outage.
    pub failure_aware: bool,
    /// Incremental re-optimization (DESIGN.md §4.9): retain the last
    /// re-solve's column-generation state and replay events as deltas
    /// when the dirty-set heuristic allows. `false` (the default)
    /// preserves the historical from-scratch path bit for bit.
    pub incremental: bool,
    /// Anytime budget applied to EVERY re-solve's MILP dispatches:
    /// wall-clock deadline in milliseconds (`--resolve-budget-ms`).
    pub resolve_budget_ms: Option<f64>,
    /// Anytime budget: branch-and-bound node allowance per re-solve.
    pub node_budget: Option<usize>,
    inc: IncrementalSolver,
    /// Per-re-solve wall time (seconds) across the run — the p50/p99
    /// the benches report alongside decision latency.
    solve_wall: Histogram,
    last_obs_seen: usize,
    cached: Option<SaturnPlan>,
    last_solve_t: f64,
    decision_s: f64,
    pub last_stats: SolverStats,
    /// Accumulated solver work across every re-solve of the run
    /// (nodes/pivots/warm-basis hit rate; wall_s sums solve time).
    pub total_stats: SolverStats,
    solves: usize,
    warm_solves: usize,
}

impl OnlineSaturn {
    pub fn new(mode: SolverMode) -> Self {
        OnlineSaturn {
            mode,
            introspect_every_s: Some(3600.0),
            migration_threshold: 0.15,
            warm_start: true,
            rolling_threshold: 64,
            drift_threshold: Some(DEFAULT_DRIFT_THRESHOLD),
            drift_resolves: 0,
            failure_aware: true,
            incremental: false,
            resolve_budget_ms: None,
            node_budget: None,
            inc: IncrementalSolver::new(),
            solve_wall: Histogram::new(),
            last_obs_seen: 0,
            cached: None,
            last_solve_t: f64::NEG_INFINITY,
            decision_s: 0.0,
            last_stats: SolverStats::default(),
            total_stats: SolverStats::default(),
            solves: 0,
            warm_solves: 0,
        }
    }

    /// Joint MILP + warm starts + hourly introspection (the paper's
    /// configuration carried over to the streaming setting).
    pub fn paper_default() -> Self {
        Self::new(SolverMode::Joint)
    }

    pub fn solves(&self) -> usize {
        self.solves
    }

    /// How many of those re-solves were seeded from the previous plan.
    pub fn warm_solves(&self) -> usize {
        self.warm_solves
    }

    /// Re-solves served by the incremental delta path.
    pub fn delta_resolves(&self) -> usize {
        self.inc.delta_resolves
    }

    /// Re-solves that went through the full pipeline (always all of
    /// them when `incremental` is off).
    pub fn full_resolves(&self) -> usize {
        if self.incremental {
            self.inc.full_resolves
        } else {
            self.solves
        }
    }

    /// Per-re-solve wall-time distribution (seconds).
    pub fn solve_wall(&self) -> &Histogram {
        &self.solve_wall
    }

    /// Fraction of branch-and-bound node LPs served from a parent basis
    /// via the dual simplex, across every re-solve of the run.
    pub fn warm_hit_rate(&self) -> f64 {
        self.total_stats.warm_hit_rate()
    }

    /// Launch pending jobs from the cached plan: tenant priority first,
    /// then longest-remaining, first-fit with backfill.
    fn launch_from_cache(&self, ctx: &PlanContext) -> Vec<Launch> {
        let Some(plan) = &self.cached else { return Vec::new() };
        launch_from_plan(plan, ctx, true)
    }
}

impl Policy for OnlineSaturn {
    fn name(&self) -> &'static str {
        "online-saturn"
    }

    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
        let t0 = Instant::now();
        let remaining: Vec<(usize, u64)> = ctx
            .jobs
            .iter()
            .filter(|s| s.is_pending())
            .map(|s| (s.job.id, s.remaining_steps()))
            .collect();
        if remaining.is_empty() {
            return Vec::new();
        }

        // Re-solve only when the unfinished set changed since the cached
        // plan (an arrival is missing from it, or a departed/completed
        // job is still in it) or the introspection interval elapsed.
        // Note a completion IS a departure here: the finished job sits in
        // the cached choices, so completions re-solve too — unlike the
        // batch policy, freed capacity is rebalanced across survivors.
        let introspect_due = self
            .introspect_every_s
            .map(|i| ctx.now - self.last_solve_t >= i - 1e-9)
            .unwrap_or(false);
        let drift_due = drift_resolve_due(self.drift_threshold,
                                          self.last_obs_seen, ctx.obs_seen,
                                          ctx.drift_alarm);
        // failure-aware: a fault event invalidates the cached plan (it
        // was solved against a fleet that no longer exists)
        let fault_due =
            self.failure_aware && ctx.cause == ReplanCause::Failure;
        let cache_ok = self
            .cached
            .as_ref()
            .map(|p| {
                // jobs the fleet cannot host at all count as covered:
                // the solve shed them and they must not force a
                // re-solve at every subsequent event
                let covers = remaining.iter().all(|&(id, _)| {
                    p.plan_for(id).is_some()
                        || !ctx.profiles.feasible_anywhere(id)
                });
                let stale = p.choices.iter().any(|jp| {
                    ctx.jobs
                        .get(jp.job_id)
                        .map(|s| s.finished_at.is_some())
                        .unwrap_or(true)
                });
                covers && !stale
            })
            .unwrap_or(false);
        if cache_ok && !introspect_due && !drift_due && !fault_due {
            let launches = self.launch_from_cache(ctx);
            self.decision_s += t0.elapsed().as_secs_f64();
            return launches;
        }
        if drift_due && cache_ok && !introspect_due {
            self.drift_resolves += 1;
        }

        let warm = if self.warm_start { self.cached.as_ref() } else { None };
        // large unfinished sets decompose into rolling windows so the
        // event-rate re-solve stays sub-second (ROADMAP: scale past ~100)
        let mode = if self.mode == SolverMode::Joint
            && remaining.len() > self.rolling_threshold
        {
            SolverMode::rolling_default()
        } else {
            self.mode
        };
        let terms = objective_terms(ctx, &remaining);
        let live = if self.failure_aware {
            degraded_capacities(ctx)
        } else {
            None
        };
        let budget = SolveBudget {
            deadline_ms: self.resolve_budget_ms,
            node_budget: self.node_budget,
        };
        // the dirty-set heuristic decides delta-vs-full BEFORE the span
        // opens so trace-summarize can break the cause histogram down
        let try_delta = self.incremental
            && self.inc.wants_delta(&remaining, ctx.objective,
                                    ctx.cause == ReplanCause::Failure,
                                    live.as_deref());
        if ctx.trace.is_enabled() {
            // refine the engine-attributed cause: a re-solve forced by
            // the drift alarm alone (the cache still covers everything
            // and introspection is not due) is a drift-alarm episode
            let cause = if drift_due && cache_ok && !introspect_due {
                "drift-alarm"
            } else {
                ctx.cause.name()
            };
            ctx.trace.begin(
                "solver",
                "resolve",
                Json::obj(vec![
                    ("policy", Json::str("online-saturn")),
                    ("cause", Json::str(cause)),
                    ("jobs", Json::num(remaining.len() as f64)),
                    ("warm", Json::Bool(warm.is_some())),
                    ("delta", Json::Bool(try_delta)),
                ]),
            );
        }
        let delta_out = if try_delta {
            self.inc.solve_delta(&remaining, ctx.profiles, ctx.cluster,
                                 1.0, warm, ctx.objective, &terms,
                                 ctx.trace, live.as_deref(), budget)
        } else {
            None
        };
        let went_delta = delta_out.is_some();
        let (mut plan, stats) = match delta_out {
            Some(out) => out,
            None => solve_joint_budgeted(&remaining, ctx.profiles,
                                         ctx.cluster, mode, 1.0, warm,
                                         ctx.objective, &terms, ctx.trace,
                                         live.as_deref(), budget),
        };
        if self.incremental && !went_delta {
            // reseed the retained state from the full solve so the NEXT
            // event can go delta
            self.inc.note_full(&remaining, &plan, ctx.objective,
                               live.as_deref());
        }
        if ctx.trace.is_enabled() {
            ctx.trace.end(
                "solver",
                "resolve",
                Json::obj(vec![
                    ("nodes", Json::num(stats.milp_nodes as f64)),
                    ("wall_s", Json::num(stats.wall_s)),
                ]),
            );
        }
        apply_migration_hysteresis(&mut plan, ctx, &remaining,
                                   self.migration_threshold);
        if stats.warm_used {
            self.warm_solves += 1;
        }
        self.total_stats.milp_nodes += stats.milp_nodes;
        self.total_stats.lp_pivots += stats.lp_pivots;
        self.total_stats.warm_hits += stats.warm_hits;
        self.total_stats.warm_misses += stats.warm_misses;
        self.total_stats.windows += stats.windows;
        self.total_stats.wall_s += stats.wall_s;
        self.total_stats.lp_capped += stats.lp_capped;
        self.total_stats.limit_reached += stats.limit_reached;
        self.total_stats.shed_jobs += stats.shed_jobs;
        self.total_stats.greedy_fallbacks += stats.greedy_fallbacks;
        self.total_stats.columns_priced += stats.columns_priced;
        self.total_stats.eta_updates += stats.eta_updates;
        self.total_stats.refactorizations += stats.refactorizations;
        self.total_stats.budget_exhausted += stats.budget_exhausted;
        // partition width and gap describe ONE solve, not a running sum
        self.total_stats.cells = stats.cells;
        self.total_stats.shard_gap =
            self.total_stats.shard_gap.max(stats.shard_gap);
        self.solve_wall.observe(stats.wall_s);
        self.last_stats = stats;
        self.solves += 1;
        self.last_solve_t = ctx.now;
        self.last_obs_seen = ctx.obs_seen;
        self.cached = Some(plan);

        let launches = self.launch_from_cache(ctx);
        self.decision_s += t0.elapsed().as_secs_f64();
        launches
    }

    fn introspection_interval(&self) -> Option<f64> {
        self.introspect_every_s
    }

    fn replan_on_events(&self) -> bool {
        true
    }

    fn decision_time_s(&self) -> f64 {
        self.decision_s
    }

    fn solver_pressure(&self) -> (usize, usize) {
        (self.total_stats.lp_capped, self.total_stats.limit_reached)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::sim::engine::{simulate_online, RungConfig, SimConfig};
    use crate::trials::{profile_analytic, ProfileTable};
    use crate::workload::{generate_trace, Trace, TraceConfig};

    fn setup(seed: u64, multijobs: usize)
        -> (Trace, ProfileTable, ClusterSpec) {
        let trace = generate_trace(&TraceConfig {
            seed,
            multijobs,
            ..Default::default()
        });
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let jobs: Vec<_> = trace.jobs.iter().map(|o| o.job.clone()).collect();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        (trace, profiles, cluster)
    }

    #[test]
    fn completes_stream_and_resolves_on_arrivals() {
        let (trace, profiles, cluster) = setup(5, 3);
        let mut policy = OnlineSaturn::paper_default();
        let r = simulate_online(&trace.jobs, None, &profiles, &cluster,
                                &mut policy, &SimConfig::default());
        assert_eq!(r.finish_times.len(), trace.jobs.len());
        // one solve per multi-job arrival (at least; introspection may add)
        assert!(policy.solves() >= trace.groups,
                "solves {} < groups {}", policy.solves(), trace.groups);
        assert!(r.peak_gpus <= cluster.total_gpus());
    }

    #[test]
    fn warm_starts_are_used_after_the_first_solve() {
        let (trace, profiles, cluster) = setup(6, 4);
        let mut policy = OnlineSaturn::paper_default();
        let _ = simulate_online(&trace.jobs, Some(&RungConfig::halving()),
                                &profiles, &cluster, &mut policy,
                                &SimConfig::default());
        assert!(policy.solves() >= 2);
        assert_eq!(policy.warm_solves(), policy.solves() - 1,
                   "every re-solve after the first must be warm-started");
    }

    #[test]
    fn online_resolves_report_warm_basis_hit_rate() {
        let (trace, profiles, cluster) = setup(6, 4);
        let mut policy = OnlineSaturn::paper_default();
        let _ = simulate_online(&trace.jobs, Some(&RungConfig::halving()),
                                &profiles, &cluster, &mut policy,
                                &SimConfig::default());
        assert!(policy.solves() >= 1);
        assert!(policy.warm_hit_rate() > 0.0,
                "online re-solves never reused a parent basis");
        assert!(policy.total_stats.lp_pivots > 0);
        assert!(policy.total_stats.milp_nodes > 0);
    }

    #[test]
    fn rung_departures_trigger_resolve() {
        let (trace, profiles, cluster) = setup(7, 2);
        let mut with_rungs = OnlineSaturn::paper_default();
        let r = simulate_online(&trace.jobs, Some(&RungConfig::halving()),
                                &profiles, &cluster, &mut with_rungs,
                                &SimConfig::default());
        let mut without = OnlineSaturn::paper_default();
        let r2 = simulate_online(&trace.jobs, None, &profiles, &cluster,
                                 &mut without, &SimConfig::default());
        if !r.early_stopped.is_empty() {
            assert!(with_rungs.solves() > without.solves()
                        || r.makespan_s < r2.makespan_s,
                    "departures neither re-solved nor shortened the run");
        }
    }

    #[test]
    fn incremental_stream_completes_and_uses_delta_resolves() {
        let (trace, profiles, cluster) = setup(6, 4);
        let mut policy = OnlineSaturn::paper_default();
        policy.incremental = true;
        let r = simulate_online(&trace.jobs, Some(&RungConfig::halving()),
                                &profiles, &cluster, &mut policy,
                                &SimConfig::default());
        assert_eq!(r.finish_times.len(), trace.jobs.len());
        assert!(r.peak_gpus <= cluster.total_gpus());
        // every re-solve is accounted to exactly one path
        assert_eq!(policy.delta_resolves() + policy.full_resolves(),
                   policy.solves());
        // rung-kills are single-job departures: the delta path must
        // have served at least one of them
        assert!(policy.delta_resolves() > 0,
                "no event went through the delta path (full={} solves={})",
                policy.full_resolves(), policy.solves());
        assert_eq!(policy.solve_wall().count(), policy.solves() as f64);
    }

    #[test]
    fn incremental_replay_is_bit_identical() {
        let (trace, profiles, cluster) = setup(11, 3);
        let rungs = RungConfig::halving();
        let run = || {
            let mut p = OnlineSaturn::paper_default();
            p.incremental = true;
            simulate_online(&trace.jobs, Some(&rungs), &profiles, &cluster,
                            &mut p, &SimConfig::default())
        };
        let (a, b) = (run(), run());
        assert_eq!(a.finish_times, b.finish_times);
        assert_eq!(a.jct_s, b.jct_s);
        assert_eq!(a.early_stopped, b.early_stopped);
        assert_eq!(a.migrations, b.migrations);
    }

    #[test]
    fn online_replay_is_bit_identical() {
        let (trace, profiles, cluster) = setup(42, 3);
        let rungs = RungConfig::halving();
        let run = || {
            let mut p = OnlineSaturn::paper_default();
            simulate_online(&trace.jobs, Some(&rungs), &profiles, &cluster,
                            &mut p, &SimConfig::default())
        };
        let (a, b) = (run(), run());
        assert_eq!(a.finish_times, b.finish_times);
        assert_eq!(a.jct_s, b.jct_s);
        assert_eq!(a.early_stopped, b.early_stopped);
        assert_eq!(a.migrations, b.migrations);
    }
}
