//! The online scheduling subsystem (DESIGN.md §Online): streaming
//! multi-job arrivals, elastic event-driven re-optimization, and
//! early-stopping departures, with an apples-to-apples harness comparing
//! [`OnlineSaturn`] against the online baselines on identical traces.
//!
//! Layering mirrors `exp/` for the batch setting: `workload::arrivals`
//! generates traces, `sim::simulate_online` executes them, and this
//! module owns the system registry, JCT metrics, and the warm-vs-cold
//! re-solve probe that `bench_online` and the `saturn online` CLI share.

pub mod scheduler;

pub use scheduler::OnlineSaturn;

use crate::baselines::{OnlineCurrentPractice, OnlineOptimus};
use crate::cluster::ClusterSpec;
use crate::objective::Objective;
use crate::parallelism::default_library;
use crate::perf::PerfModel;
use crate::saturn::solver::{solve_joint_warm, SolverMode, SolverStats};
use crate::sim::engine::{simulate_online_perf, OnlineSimResult, RungConfig,
                         SimConfig};
use crate::trials::{profile_analytic, ProfileTable};
use crate::util::json::Json;
use crate::workload::Trace;

pub const ONLINE_SYSTEMS: [&str; 3] =
    ["online-current-practice", "online-optimus", "online-saturn"];

/// Scheduler-quality metrics of one (trace, system) run.
#[derive(Debug, Clone)]
pub struct OnlineMetrics {
    pub system: &'static str,
    pub avg_jct_s: f64,
    pub p95_jct_s: f64,
    /// Mean JCT weighted by tenant priority.
    pub weighted_jct_s: f64,
    pub makespan_s: f64,
    pub gpu_utilization: f64,
    pub completed: usize,
    pub early_stopped: usize,
    pub deadline_misses: usize,
    /// Sum over completed deadlined jobs of `(finish - deadline)+`.
    pub total_tardiness_s: f64,
    /// Priority-weighted mean tardiness (same denominator as
    /// `weighted_jct_s`; see `OnlineSimResult::weighted_tardiness_s`).
    pub weighted_tardiness_s: f64,
    pub preemptions: usize,
    pub migrations: usize,
    pub decision_s: f64,
    /// Median per-`plan()` decision latency (wall seconds; 0 when no
    /// decisions were timed).
    pub decision_p50_s: f64,
    /// p99 per-`plan()` decision latency (wall seconds).
    pub decision_p99_s: f64,
    /// Joint re-solves (Saturn only).
    pub solves: Option<usize>,
    /// Warm-started re-solves among them (Saturn only).
    pub warm_solves: Option<usize>,
    /// Fraction of branch-and-bound node LPs served from a parent basis
    /// via dual simplex, across the run (Saturn only).
    pub warm_hit_rate: Option<f64>,
    /// Total simplex pivots across every re-solve (Saturn only).
    pub lp_pivots: Option<usize>,
    /// Node LPs that hit the simplex iteration cap (solver stress under
    /// event-rate/drift-triggered re-solves; 0 for solver-free systems).
    pub lp_capped: usize,
    /// MILP solves stopped by a node/time limit across the run.
    pub milp_limit_reached: usize,
    /// Observations the engine delivered to the estimate layer.
    pub observations: usize,
    /// Mean |ln(observed/estimated)| across those observations.
    pub estimate_mae: f64,
    /// Re-solves fired by the drift trigger alone (Saturn only).
    pub drift_resolves: Option<usize>,
    /// Node-down events the run hit (fault layer; 0 without faults).
    pub failures: usize,
    /// Jobs killed by node deaths or crash hazards (checkpoint
    /// rollbacks).
    pub fault_preemptions: usize,
    /// GPU-seconds re-run because fault kills rolled progress back past
    /// the last periodic checkpoint.
    pub lost_work_gpu_s: f64,
    /// Mean seconds from a fault kill to the victim's relaunch.
    pub mean_recovery_s: f64,
    /// (busy - lost) GPU-seconds over capacity x makespan; equals
    /// `gpu_utilization` when faults are off.
    pub goodput: f64,
    /// Plan selections that degraded to the greedy heuristic
    /// (`SolverStats::greedy_fallbacks`, Saturn only) — the visible
    /// count of "solver kept going instead of keeping up".
    pub solver_fallbacks: Option<usize>,
    /// Candidate columns priced into column-generation restricted
    /// masters across the run (Saturn only; 0 unless a sharded/colgen
    /// solve ran).
    pub columns_priced: Option<usize>,
    /// Product-form eta updates across every node LP (Saturn only) —
    /// the cheap-path counter of the Forrest–Tomlin basis maintenance.
    pub eta_updates: Option<usize>,
    /// From-scratch basis factorizations across every node LP (Saturn
    /// only) — warm entries plus spike/drift-triggered eta collapses.
    pub refactorizations: Option<usize>,
    /// Cells the most recent sharded solve partitioned the queue into
    /// (Saturn only; 0 = unsharded).
    pub solver_cells: Option<usize>,
    /// Worst bound-relative shard optimality gap seen across the run's
    /// sharded solves (Saturn only; 0 = unsharded or no measurable gap).
    pub shard_gap: Option<f64>,
    /// Re-solves served by the incremental delta path (Saturn only;
    /// 0 unless `--incremental on`).
    pub delta_resolves: Option<usize>,
    /// Re-solves that ran the full from-scratch pipeline (Saturn only;
    /// equals `solves` when the incremental path is off).
    pub full_resolves: Option<usize>,
    /// MILP dispatches truncated by the anytime budget
    /// (`SolverStats::budget_exhausted`, Saturn only).
    pub budget_exhausted: Option<usize>,
    /// Median per-re-solve wall time (seconds; Saturn only) — the
    /// solver-side complement of the engine's decision latency.
    pub solve_p50_s: Option<f64>,
    /// p99 per-re-solve wall time (seconds; Saturn only).
    pub solve_p99_s: Option<f64>,
    /// Arrival instants the engine's debounce window folded into a
    /// later replan (`SimConfig::coalesce_window_s`; 0 when off).
    pub coalesced_events: usize,
}

impl OnlineMetrics {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("system", Json::str(self.system)),
            ("avg_jct_s", Json::num(self.avg_jct_s)),
            ("p95_jct_s", Json::num(self.p95_jct_s)),
            ("weighted_jct_s", Json::num(self.weighted_jct_s)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("gpu_utilization", Json::num(self.gpu_utilization)),
            ("completed", Json::num(self.completed as f64)),
            ("early_stopped", Json::num(self.early_stopped as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("total_tardiness_s", Json::num(self.total_tardiness_s)),
            ("weighted_tardiness_s",
             Json::num(self.weighted_tardiness_s)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("migrations", Json::num(self.migrations as f64)),
            ("decision_s", Json::num(self.decision_s)),
            ("decision_p50_s", Json::num(self.decision_p50_s)),
            ("decision_p99_s", Json::num(self.decision_p99_s)),
            ("solves", match self.solves {
                Some(s) => Json::num(s as f64),
                None => Json::Null,
            }),
            ("warm_solves", match self.warm_solves {
                Some(s) => Json::num(s as f64),
                None => Json::Null,
            }),
            ("warm_hit_rate", match self.warm_hit_rate {
                Some(r) => Json::num(r),
                None => Json::Null,
            }),
            ("lp_pivots", match self.lp_pivots {
                Some(p) => Json::num(p as f64),
                None => Json::Null,
            }),
            ("lp_capped", Json::num(self.lp_capped as f64)),
            ("milp_limit_reached",
             Json::num(self.milp_limit_reached as f64)),
            ("observations", Json::num(self.observations as f64)),
            ("estimate_mae", Json::num(self.estimate_mae)),
            ("drift_resolves", match self.drift_resolves {
                Some(d) => Json::num(d as f64),
                None => Json::Null,
            }),
            ("failures", Json::num(self.failures as f64)),
            ("fault_preemptions",
             Json::num(self.fault_preemptions as f64)),
            ("lost_work_gpu_s", Json::num(self.lost_work_gpu_s)),
            ("mean_recovery_s", Json::num(self.mean_recovery_s)),
            ("goodput", Json::num(self.goodput)),
            ("solver_fallbacks", match self.solver_fallbacks {
                Some(f) => Json::num(f as f64),
                None => Json::Null,
            }),
            ("columns_priced", match self.columns_priced {
                Some(c) => Json::num(c as f64),
                None => Json::Null,
            }),
            ("eta_updates", match self.eta_updates {
                Some(e) => Json::num(e as f64),
                None => Json::Null,
            }),
            ("refactorizations", match self.refactorizations {
                Some(r) => Json::num(r as f64),
                None => Json::Null,
            }),
            ("solver_cells", match self.solver_cells {
                Some(c) => Json::num(c as f64),
                None => Json::Null,
            }),
            ("shard_gap", match self.shard_gap {
                Some(g) => Json::num(g),
                None => Json::Null,
            }),
            ("delta_resolves", match self.delta_resolves {
                Some(d) => Json::num(d as f64),
                None => Json::Null,
            }),
            ("full_resolves", match self.full_resolves {
                Some(f) => Json::num(f as f64),
                None => Json::Null,
            }),
            ("budget_exhausted", match self.budget_exhausted {
                Some(b) => Json::num(b as f64),
                None => Json::Null,
            }),
            ("solve_p50_s", match self.solve_p50_s {
                Some(s) => Json::num(s),
                None => Json::Null,
            }),
            ("solve_p99_s", match self.solve_p99_s {
                Some(s) => Json::num(s),
                None => Json::Null,
            }),
            ("coalesced_events",
             Json::num(self.coalesced_events as f64)),
        ])
    }
}

/// Online-Saturn hot-path knobs (ISSUE 10): the CLI's `--incremental`,
/// `--resolve-budget-ms`, and node-budget flags bundled for
/// [`run_trace_knobs`]. The default (everything off) reproduces
/// [`run_trace_sim`] bit for bit.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OnlineKnobs {
    /// Retain colgen state across events and re-solve as deltas.
    pub incremental: bool,
    /// Anytime wall-clock budget per re-solve, milliseconds.
    pub resolve_budget_ms: Option<f64>,
    /// Anytime branch-and-bound node budget per re-solve.
    pub node_budget: Option<usize>,
}

/// Profile every job of a trace against the cluster (arrival metadata
/// does not affect per-job cost models, so one table serves the run).
pub fn profile_trace(trace: &Trace, cluster: &ClusterSpec) -> ProfileTable {
    let lib = default_library();
    let jobs: Vec<_> = trace.jobs.iter().map(|o| o.job.clone()).collect();
    profile_analytic(&jobs, &lib, cluster)
}

/// Execute one (trace, system) cell and reduce it to metrics, with a
/// perfect performance model (truth == estimate == profiled).
pub fn run_trace(trace: &Trace, rungs: Option<&RungConfig>,
                 profiles: &ProfileTable, cluster: &ClusterSpec,
                 system: &str, mode: SolverMode)
    -> (OnlineSimResult, OnlineMetrics) {
    let mut perf = PerfModel::exact(profiles);
    run_trace_perf(trace, rungs, &mut perf, cluster, system, mode, None)
}

/// Execute one (trace, system) cell against an explicit performance
/// model — the drift harness `bench_drift` and `saturn online --drift`
/// share. `perf` must be freshly constructed per call (the estimate
/// layer learns during the run). `drift_threshold` overrides the Saturn
/// policies' drift-triggered re-solve knob (`None` keeps the default).
pub fn run_trace_perf(trace: &Trace, rungs: Option<&RungConfig>,
                      perf: &mut PerfModel, cluster: &ClusterSpec,
                      system: &str, mode: SolverMode,
                      drift_threshold: Option<Option<f64>>)
    -> (OnlineSimResult, OnlineMetrics) {
    run_trace_obj(trace, rungs, perf, cluster, system, mode,
                  drift_threshold, Objective::Makespan)
}

/// As [`run_trace_perf`], with an explicit scheduling [`Objective`]
/// handed to every policy through the engine's `PlanContext` — the
/// `--objective` CLI path and `bench_objective` route here.
/// `Objective::Makespan` reproduces [`run_trace_perf`] bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn run_trace_obj(trace: &Trace, rungs: Option<&RungConfig>,
                     perf: &mut PerfModel, cluster: &ClusterSpec,
                     system: &str, mode: SolverMode,
                     drift_threshold: Option<Option<f64>>,
                     objective: Objective)
    -> (OnlineSimResult, OnlineMetrics) {
    let cfg = SimConfig { objective, ..SimConfig::default() };
    run_trace_sim(trace, rungs, perf, cluster, system, mode,
                  drift_threshold, &cfg)
}

/// As [`run_trace_obj`], against an explicit engine [`SimConfig`] — the
/// flight-recorder path (`saturn online --trace`) routes here so the
/// `SimConfig::trace` handle reaches the engine and every policy. With
/// the default config this reproduces [`run_trace_obj`] bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn run_trace_sim(trace: &Trace, rungs: Option<&RungConfig>,
                     perf: &mut PerfModel, cluster: &ClusterSpec,
                     system: &str, mode: SolverMode,
                     drift_threshold: Option<Option<f64>>,
                     cfg: &SimConfig)
    -> (OnlineSimResult, OnlineMetrics) {
    run_trace_knobs(trace, rungs, perf, cluster, system, mode,
                    drift_threshold, cfg, OnlineKnobs::default())
}

/// As [`run_trace_sim`], with the online-Saturn hot-path [`OnlineKnobs`]
/// applied (incremental re-solves, anytime budgets). Non-Saturn systems
/// ignore the knobs; the default knobs reproduce [`run_trace_sim`] bit
/// for bit.
#[allow(clippy::too_many_arguments)]
pub fn run_trace_knobs(trace: &Trace, rungs: Option<&RungConfig>,
                       perf: &mut PerfModel, cluster: &ClusterSpec,
                       system: &str, mode: SolverMode,
                       drift_threshold: Option<Option<f64>>,
                       cfg: &SimConfig, knobs: OnlineKnobs)
    -> (OnlineSimResult, OnlineMetrics) {
    let (result, sys, solver_probe) = match system {
        "online-current-practice" => {
            let mut p = OnlineCurrentPractice;
            let r = simulate_online_perf(&trace.jobs, rungs, perf, cluster,
                                         &mut p, cfg);
            (r, ONLINE_SYSTEMS[0], None)
        }
        "online-optimus" => {
            let mut p = OnlineOptimus::default();
            let r = simulate_online_perf(&trace.jobs, rungs, perf, cluster,
                                         &mut p, cfg);
            (r, ONLINE_SYSTEMS[1], None)
        }
        "online-saturn" => {
            let mut p = OnlineSaturn::new(mode);
            if let Some(th) = drift_threshold {
                p.drift_threshold = th;
            }
            p.incremental = knobs.incremental;
            p.resolve_budget_ms = knobs.resolve_budget_ms;
            p.node_budget = knobs.node_budget;
            let r = simulate_online_perf(&trace.jobs, rungs, perf, cluster,
                                         &mut p, cfg);
            let probe = saturn_probe(&p);
            (r, ONLINE_SYSTEMS[2], Some(probe))
        }
        other => panic!("unknown online system '{other}' \
                         (online-current-practice|online-optimus|online-saturn)"),
    };
    let metrics = assemble_metrics(trace, &result, sys, solver_probe);
    (result, metrics)
}

/// As the online-Saturn arm of [`run_trace_sim`], with the policy's
/// failure awareness pinned — the `bench_faults` aware-vs-blind pair and
/// the `--faults` CLI path route here. With `failure_aware = true` and a
/// fault-free [`SimConfig`] this reproduces [`run_trace_sim`] bit for
/// bit (a blind policy never sees a `ReplanCause::Failure` either, so
/// the flag only matters once faults actually fire).
pub fn run_trace_faults(trace: &Trace, rungs: Option<&RungConfig>,
                        perf: &mut PerfModel, cluster: &ClusterSpec,
                        mode: SolverMode, cfg: &SimConfig,
                        failure_aware: bool)
    -> (OnlineSimResult, OnlineMetrics) {
    let mut p = OnlineSaturn::new(mode);
    p.failure_aware = failure_aware;
    let result = simulate_online_perf(&trace.jobs, rungs, perf, cluster,
                                      &mut p, cfg);
    let probe = saturn_probe(&p);
    let metrics = assemble_metrics(trace, &result, ONLINE_SYSTEMS[2],
                                   Some(probe));
    (result, metrics)
}

/// Saturn-only diagnostics lifted off the policy's accumulated
/// [`SolverStats`] at the end of a run.
#[derive(Debug, Clone, Copy)]
struct SaturnProbe {
    solves: usize,
    warm_solves: usize,
    warm_hit_rate: f64,
    lp_pivots: usize,
    drift_resolves: usize,
    greedy_fallbacks: usize,
    columns_priced: usize,
    eta_updates: usize,
    refactorizations: usize,
    cells: usize,
    shard_gap: f64,
    delta_resolves: usize,
    full_resolves: usize,
    budget_exhausted: usize,
    solve_p50_s: f64,
    solve_p99_s: f64,
}

fn saturn_probe(p: &OnlineSaturn) -> SaturnProbe {
    let finite = |x: f64| if x.is_nan() { 0.0 } else { x };
    SaturnProbe {
        solves: p.solves(),
        warm_solves: p.warm_solves(),
        warm_hit_rate: p.warm_hit_rate(),
        lp_pivots: p.total_stats.lp_pivots,
        drift_resolves: p.drift_resolves,
        greedy_fallbacks: p.total_stats.greedy_fallbacks,
        columns_priced: p.total_stats.columns_priced,
        eta_updates: p.total_stats.eta_updates,
        refactorizations: p.total_stats.refactorizations,
        cells: p.total_stats.cells,
        shard_gap: p.total_stats.shard_gap,
        delta_resolves: p.delta_resolves(),
        full_resolves: p.full_resolves(),
        budget_exhausted: p.total_stats.budget_exhausted,
        solve_p50_s: finite(p.solve_wall().percentile(0.50)),
        solve_p99_s: finite(p.solve_wall().percentile(0.99)),
    }
}

fn assemble_metrics(trace: &Trace, result: &OnlineSimResult,
                    sys: &'static str, solver_probe: Option<SaturnProbe>)
    -> OnlineMetrics {
    let total_w: f64 = trace.jobs.iter().map(|j| j.priority).sum();
    let weighted = if total_w > 0.0 {
        result
            .jct_s
            .iter()
            .map(|&(id, jct)| trace.jobs[id].priority * jct)
            .sum::<f64>()
            / total_w
    } else {
        0.0
    };
    OnlineMetrics {
        system: sys,
        avg_jct_s: result.avg_jct_s(),
        p95_jct_s: result.p95_jct_s(),
        weighted_jct_s: weighted,
        makespan_s: result.makespan_s,
        gpu_utilization: result.gpu_utilization,
        completed: result.completed.len(),
        early_stopped: result.early_stopped.len(),
        deadline_misses: result.deadline_misses,
        total_tardiness_s: result.total_tardiness_s,
        weighted_tardiness_s: result.weighted_tardiness_s,
        preemptions: result.preemptions,
        migrations: result.migrations,
        decision_s: result.policy_decision_s,
        decision_p50_s: result.decision_p50_s,
        decision_p99_s: result.decision_p99_s,
        solves: solver_probe.map(|p| p.solves),
        warm_solves: solver_probe.map(|p| p.warm_solves),
        warm_hit_rate: solver_probe.map(|p| p.warm_hit_rate),
        lp_pivots: solver_probe.map(|p| p.lp_pivots),
        lp_capped: result.lp_capped,
        milp_limit_reached: result.milp_limit_reached,
        observations: result.observations,
        estimate_mae: result.estimate_mae,
        drift_resolves: solver_probe.map(|p| p.drift_resolves),
        failures: result.failures,
        fault_preemptions: result.fault_preemptions,
        lost_work_gpu_s: result.lost_work_gpu_s,
        mean_recovery_s: result.mean_recovery_s,
        goodput: result.goodput,
        solver_fallbacks: solver_probe.map(|p| p.greedy_fallbacks),
        columns_priced: solver_probe.map(|p| p.columns_priced),
        eta_updates: solver_probe.map(|p| p.eta_updates),
        refactorizations: solver_probe.map(|p| p.refactorizations),
        solver_cells: solver_probe.map(|p| p.cells),
        shard_gap: solver_probe.map(|p| p.shard_gap),
        delta_resolves: solver_probe.map(|p| p.delta_resolves),
        full_resolves: solver_probe.map(|p| p.full_resolves),
        budget_exhausted: solver_probe.map(|p| p.budget_exhausted),
        solve_p50_s: solver_probe.map(|p| p.solve_p50_s),
        solve_p99_s: solver_probe.map(|p| p.solve_p99_s),
        coalesced_events: result.coalesced_events,
    }
}

/// Warm-vs-cold re-solve comparison on one identical arrival event.
#[derive(Debug, Clone)]
pub struct WarmColdProbe {
    pub jobs_before: usize,
    pub jobs_after: usize,
    pub cold: SolverStats,
    pub warm: SolverStats,
    pub cold_makespan_s: f64,
    pub warm_makespan_s: f64,
}

/// Replays the moment the LAST multi-job of a trace arrives: solve the
/// pre-arrival set, then re-solve the post-arrival set twice — cold, and
/// warm-started from the pre-arrival plan. Both re-solves see the exact
/// same inputs, isolating the incumbent-seeding effect (bench_online
/// reports wall time and branch-and-bound node counts for both).
pub fn warm_cold_probe(trace: &Trace, profiles: &ProfileTable,
                       cluster: &ClusterSpec) -> WarmColdProbe {
    let last_group = trace.groups.saturating_sub(1);
    let before: Vec<(usize, u64)> = trace
        .jobs
        .iter()
        .filter(|o| o.group < last_group)
        .map(|o| (o.job.id, o.job.total_steps()))
        .collect();
    let after: Vec<(usize, u64)> = trace
        .jobs
        .iter()
        .map(|o| (o.job.id, o.job.total_steps()))
        .collect();
    let (prev_plan, _) = solve_joint_warm(&before, profiles, cluster,
                                          SolverMode::Joint, 1.0, None);
    let (cold_plan, cold) = solve_joint_warm(&after, profiles, cluster,
                                             SolverMode::Joint, 1.0, None);
    let (warm_plan, warm) = solve_joint_warm(&after, profiles, cluster,
                                             SolverMode::Joint, 1.0,
                                             Some(&prev_plan));
    WarmColdProbe {
        jobs_before: before.len(),
        jobs_after: after.len(),
        cold,
        warm,
        cold_makespan_s: cold_plan.predicted_makespan_s,
        warm_makespan_s: warm_plan.predicted_makespan_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_trace, TraceConfig};

    fn trace() -> (Trace, ProfileTable, ClusterSpec) {
        let t = generate_trace(&TraceConfig {
            seed: 9,
            multijobs: 3,
            ..Default::default()
        });
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&t, &cluster);
        (t, profiles, cluster)
    }

    #[test]
    fn all_online_systems_complete_the_stream() {
        let (t, profiles, cluster) = trace();
        let rungs = RungConfig::halving();
        for sys in ONLINE_SYSTEMS {
            let (r, m) = run_trace(&t, Some(&rungs), &profiles, &cluster,
                                   sys, SolverMode::Joint);
            assert_eq!(r.finish_times.len(), t.jobs.len(), "{sys}");
            assert_eq!(m.completed + m.early_stopped, t.jobs.len(), "{sys}");
            assert!(m.avg_jct_s > 0.0, "{sys}");
            assert!(m.p95_jct_s >= m.avg_jct_s * 0.5, "{sys}");
            assert!(m.gpu_utilization <= 1.0 + 1e-9, "{sys}");
        }
    }

    #[test]
    fn saturn_beats_fifo_on_avg_jct() {
        let (t, profiles, cluster) = trace();
        let (_, fifo) = run_trace(&t, None, &profiles, &cluster,
                                  "online-current-practice",
                                  SolverMode::Joint);
        let (_, sat) = run_trace(&t, None, &profiles, &cluster,
                                 "online-saturn", SolverMode::Joint);
        assert!(sat.avg_jct_s < fifo.avg_jct_s * 1.001,
                "online-saturn {:.0}s !< fifo {:.0}s",
                sat.avg_jct_s, fifo.avg_jct_s);
    }

    #[test]
    fn warm_probe_preserves_quality_and_prunes_nodes() {
        let (t, profiles, cluster) = trace();
        let p = warm_cold_probe(&t, &profiles, &cluster);
        assert!(p.warm.warm_used);
        assert!(!p.cold.warm_used);
        // both solves run to the same 1% MILP gap; list-scheduling can
        // amplify in-gap differences slightly, hence the loose band
        assert!(p.warm_makespan_s <= p.cold_makespan_s * 1.05 + 1.0,
                "warm {} vs cold {}", p.warm_makespan_s, p.cold_makespan_s);
        assert!(p.jobs_after > p.jobs_before);
    }

    #[test]
    fn drift_run_reports_observations_and_stress_counters() {
        use crate::perf::DriftConfig;
        let (t, profiles, cluster) = trace();
        let mut perf = PerfModel::with_drift(
            &profiles, DriftConfig::uniform(5, 0.2), true);
        let (r, m) = run_trace_perf(&t, Some(&RungConfig::halving()),
                                    &mut perf, &cluster, "online-saturn",
                                    SolverMode::Joint, None);
        assert_eq!(r.finish_times.len(), t.jobs.len());
        assert!(m.observations > 0, "no observations under drift");
        assert!(m.estimate_mae > 0.0);
        assert!(m.drift_resolves.is_some());
        let parsed = Json::parse(&m.to_json().to_string()).unwrap();
        assert!(parsed.get("lp_capped").unwrap().as_f64().is_some());
        assert!(parsed.get("milp_limit_reached").unwrap().as_f64()
                    .is_some());
        assert!(parsed.get("estimate_mae").unwrap().as_f64().unwrap()
                    > 0.0);
        assert!(parsed.get("drift_resolves").unwrap().as_f64().is_some());
    }

    #[test]
    fn objective_runs_complete_and_report_tardiness_metrics() {
        let t = generate_trace(&TraceConfig {
            seed: 9,
            multijobs: 3,
            deadline_slack_s: Some(1800.0),
            ..Default::default()
        });
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_trace(&t, &cluster);
        for objective in [
            Objective::Makespan,
            Objective::WeightedTardiness { deadline_weight: 1.0 },
            Objective::WeightedJct { alpha: 0.5 },
        ] {
            let mut perf = PerfModel::exact(&profiles);
            let (r, m) = run_trace_obj(&t, None, &mut perf, &cluster,
                                       "online-saturn", SolverMode::Joint,
                                       None, objective);
            assert_eq!(r.finish_times.len(), t.jobs.len(), "{}",
                       objective.name());
            assert!(m.total_tardiness_s >= 0.0);
            assert!(m.weighted_tardiness_s >= 0.0);
            let parsed = Json::parse(&m.to_json().to_string()).unwrap();
            assert!(parsed.get("total_tardiness_s").unwrap().as_f64()
                        .is_some());
            assert!(parsed.get("weighted_tardiness_s").unwrap().as_f64()
                        .is_some());
        }
    }

    #[test]
    fn incremental_knobs_run_completes_and_reports_new_metrics() {
        let (t, profiles, cluster) = trace();
        let rungs = RungConfig::halving();
        let mut perf = PerfModel::exact(&profiles);
        let knobs = OnlineKnobs { incremental: true,
                                  ..OnlineKnobs::default() };
        let (r, m) = run_trace_knobs(&t, Some(&rungs), &mut perf, &cluster,
                                     "online-saturn", SolverMode::Joint,
                                     None, &SimConfig::default(), knobs);
        assert_eq!(r.finish_times.len(), t.jobs.len());
        assert_eq!(m.delta_resolves.unwrap() + m.full_resolves.unwrap(),
                   m.solves.unwrap());
        assert!(m.solve_p99_s.unwrap() >= m.solve_p50_s.unwrap());
        assert_eq!(m.coalesced_events, 0, "no window configured");
        let parsed = Json::parse(&m.to_json().to_string()).unwrap();
        for key in ["delta_resolves", "full_resolves", "budget_exhausted",
                    "solve_p50_s", "solve_p99_s", "coalesced_events"] {
            assert!(parsed.get(key).unwrap().as_f64().is_some(), "{key}");
        }
    }

    #[test]
    fn default_knobs_reproduce_run_trace_sim_bitwise() {
        let (t, profiles, cluster) = trace();
        let mut perf_a = PerfModel::exact(&profiles);
        let (a, _) = run_trace_sim(&t, None, &mut perf_a, &cluster,
                                   "online-saturn", SolverMode::Joint,
                                   None, &SimConfig::default());
        let mut perf_b = PerfModel::exact(&profiles);
        let (b, _) = run_trace_knobs(&t, None, &mut perf_b, &cluster,
                                     "online-saturn", SolverMode::Joint,
                                     None, &SimConfig::default(),
                                     OnlineKnobs::default());
        assert_eq!(a.finish_times, b.finish_times);
        assert_eq!(a.jct_s, b.jct_s);
    }

    #[test]
    fn metrics_serialize_to_json() {
        let (t, profiles, cluster) = trace();
        let (_, m) = run_trace(&t, None, &profiles, &cluster,
                               "online-saturn", SolverMode::Joint);
        let s = m.to_json().to_string();
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(parsed.get("system").unwrap().as_str(),
                   Some("online-saturn"));
        assert!(parsed.get("avg_jct_s").unwrap().as_f64().unwrap() > 0.0);
        // the solver-stat plumbing: branch-and-bound warm-basis hit rate
        // must be present and non-zero for the saturn system
        assert!(parsed.get("warm_hit_rate").unwrap().as_f64().unwrap() > 0.0);
        assert!(parsed.get("lp_pivots").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn fault_free_fault_entry_reproduces_run_trace_bitwise() {
        let (t, profiles, cluster) = trace();
        let (_, base) = run_trace(&t, None, &profiles, &cluster,
                                  "online-saturn", SolverMode::Joint);
        let mut perf = PerfModel::exact(&profiles);
        let (r, m) = run_trace_faults(&t, None, &mut perf, &cluster,
                                      SolverMode::Joint,
                                      &SimConfig::default(), true);
        assert_eq!(m.makespan_s.to_bits(), base.makespan_s.to_bits());
        assert_eq!(m.avg_jct_s.to_bits(), base.avg_jct_s.to_bits());
        assert_eq!(m.failures, 0);
        assert_eq!(m.fault_preemptions, 0);
        assert_eq!(m.goodput.to_bits(), r.gpu_utilization.to_bits());
        assert_eq!(m.solver_fallbacks, Some(0));
    }

    #[test]
    fn faulted_run_surfaces_fault_metrics_in_json() {
        use crate::faults::FaultConfig;
        let (t, profiles, cluster) = trace();
        let cfg = SimConfig {
            faults: FaultConfig {
                seed: 11,
                crash_per_hour: 4.0,
                ..FaultConfig::none()
            },
            checkpoint_interval_s: 600.0,
            ..SimConfig::default()
        };
        let mut perf = PerfModel::exact(&profiles);
        let (r, m) = run_trace_faults(&t, None, &mut perf, &cluster,
                                      SolverMode::Joint, &cfg, true);
        assert_eq!(r.finish_times.len(), t.jobs.len());
        assert!(m.fault_preemptions > 0, "crash hazard never fired");
        assert!(m.lost_work_gpu_s > 0.0);
        assert!(m.goodput <= m.gpu_utilization + 1e-12);
        let parsed = Json::parse(&m.to_json().to_string()).unwrap();
        for key in ["failures", "fault_preemptions", "lost_work_gpu_s",
                    "mean_recovery_s", "goodput", "solver_fallbacks"] {
            assert!(parsed.get(key).unwrap().as_f64().is_some(), "{key}");
        }
    }
}
