//! Megatron-LM tensor parallelism (Shoeybi et al. 2019) — an OPTIONAL
//! fifth technique demonstrating the Library's extensibility (paper
//! Figure 1B). Not part of `default_library()`: Table 2 registers exactly
//! the paper's four techniques; `extended_library()` adds this one (used
//! by `examples/custom_parallelism.rs` and the sensitivity bench).
//!
//! Cost model: every matmul shards column/row-wise across `g` GPUs inside
//! one NVLink domain; two activation all-reduces per layer per pass.
//! Memory: weights/optimizer shard by `g`, activations replicated.

use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;
use crate::parallelism::api::{batch_efficiency, Parallelism, StepEstimate};

#[derive(Debug, Clone)]
pub struct MegatronTp {
    pub mfu: f64,
}

impl Default for MegatronTp {
    fn default() -> Self {
        MegatronTp { mfu: 0.42 }
    }
}

impl Parallelism for MegatronTp {
    fn name(&self) -> &str {
        "megatron-tp"
    }

    fn search(&self, model: &ModelSpec, cluster: &ClusterSpec, gpus: u32,
              batch: u32) -> Option<StepEstimate> {
        if gpus == 0 || gpus > cluster.gpus_per_node() {
            return None; // TP lives inside the NVLink domain
        }
        if model.hidden % gpus != 0 {
            return None; // head/ffn dims must split evenly
        }
        let mem = model.state_bytes() / gpus as f64
            + model.act_bytes_per_sample * batch as f64; // acts replicated
        if mem > cluster.gpu().usable_bytes() {
            return None;
        }
        // TP keeps the FULL batch on every shard: occupancy is set by the
        // global batch, one of TP's practical advantages at small batches.
        let eff = self.mfu * batch_efficiency(batch as f64);
        let compute = model.flops_per_step(batch)
            / (gpus as f64 * cluster.gpu().peak_flops * eff);
        let comm = if gpus == 1 {
            0.0
        } else {
            // 4 all-reduces/layer (2 fwd + 2 bwd) over layer activations
            let act = model.boundary_bytes_per_sample() * batch as f64;
            4.0 * model.layers as f64 * 2.0 * (gpus as f64 - 1.0)
                / gpus as f64 * act / cluster.intra_bw()
        };
        let step = compute + 0.5 * comm; // partial overlap
        Some(StepEstimate {
            step_time_s: step,
            mem_per_gpu: mem,
            mfu: eff * compute / step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stays_in_nvlink_domain() {
        let c = ClusterSpec::p4d(2);
        let m = ModelSpec::gpt2_xl();
        assert!(MegatronTp::default().search(&m, &c, 16, 4).is_none());
        assert!(MegatronTp::default().search(&m, &c, 8, 4).is_some());
    }

    #[test]
    fn activation_replication_limits_batch() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt2_xl();
        let tp = MegatronTp::default();
        // replicated pre-flash activations blow past usable memory at bs32
        assert!(tp.search(&m, &c, 8, 32).is_none());
        assert!(tp.search(&m, &c, 8, 4).is_some());
    }

    #[test]
    fn wins_at_tiny_batches_vs_fsdp() {
        // TP's occupancy uses the GLOBAL batch -> at batch 4 on 4 GPUs it
        // beats FSDP (whose per-GPU batch is 1)
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt2_xl();
        let tp = MegatronTp::default().search(&m, &c, 4, 4).unwrap();
        let fsdp = crate::parallelism::fsdp::Fsdp::default()
            .search(&m, &c, 4, 4)
            .unwrap();
        assert!(tp.step_time_s < fsdp.step_time_s,
                "tp {} !< fsdp {}", tp.step_time_s, fsdp.step_time_s);
    }

    #[test]
    fn hidden_divisibility() {
        let c = ClusterSpec::p4d(1);
        let mut m = ModelSpec::gpt2_xl();
        m.hidden = 1602; // not divisible by 4
        assert!(MegatronTp::default().search(&m, &c, 4, 16).is_none());
    }
}
