//! GPipe cost model (Huang et al. 2018): layer-partitioned pipeline
//! parallelism with micro-batching.
//!
//! The model splits into `g` contiguous stages; the mini-batch splits into
//! `m` micro-batches streamed through the pipeline. The classic bubble
//! fraction is (g-1)/(m+g-1):
//!
//!   step = compute(batch) / (g * peak * mfu) / (1 - bubble)
//!          + activation p2p traffic between stages
//!
//! Memory per GPU: state/g + m in-flight microbatch activations of one
//! stage. GPipe shines when a big model needs FEW GPUs (memory-bound, low
//! comm) — exactly the "5 GPUs GPipe / 3 GPUs FSDP" unintuitive splits the
//! paper highlights.

use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;
use crate::parallelism::api::{mem, Parallelism, StepEstimate};

#[derive(Debug, Clone)]
pub struct GPipe {
    pub mfu: f64,
    /// Micro-batches per mini-batch (chunks); the paper's deployments use
    /// a fixed chunk count tuned once per model.
    pub microbatches: u32,
}

impl Default for GPipe {
    fn default() -> Self {
        GPipe { mfu: 0.38, microbatches: 8 }
    }
}

impl Parallelism for GPipe {
    fn name(&self) -> &str {
        "gpipe"
    }

    fn search(&self, model: &ModelSpec, cluster: &ClusterSpec, gpus: u32,
              batch: u32) -> Option<StepEstimate> {
        if gpus == 0 || gpus > cluster.total_gpus() {
            return None;
        }
        if gpus > model.layers {
            return None; // cannot split finer than one layer per stage
        }
        let m = self.microbatches.min(batch).max(1);
        let micro = (batch as f64 / m as f64).ceil();
        // GPipe's default re-materialization: only microbatch BOUNDARY
        // activations are stashed (m of them); one microbatch's stage
        // activations recompute during backward (working set).
        let stash = m as f64 * micro * model.boundary_bytes_per_sample();
        let working = model.act_bytes_per_sample * micro / gpus as f64;
        let mem_per_gpu =
            mem::pipeline_stage_state(model, gpus) + stash + working;
        if mem_per_gpu > cluster.gpu().usable_bytes() {
            return None;
        }
        let bubble = (gpus as f64 - 1.0) / (m as f64 + gpus as f64 - 1.0);
        // remat re-runs the forward during backward: +fwd/(fwd+bwd) = +1/3;
        // each stage computes on ONE microbatch at a time -> occupancy is
        // set by the microbatch size, not the global batch.
        let remat = if gpus > 1 { 4.0 / 3.0 } else { 1.0 };
        let eff = self.mfu * crate::parallelism::api::batch_efficiency(micro);
        let compute = remat * model.flops_per_step(batch)
            / (gpus as f64 * cluster.gpu().peak_flops * eff);
        // p2p: boundary activations per microbatch, (g-1) hops, fwd+bwd
        let boundary = micro * model.boundary_bytes_per_sample();
        let p2p = if gpus == 1 {
            0.0
        } else {
            2.0 * (gpus as f64 - 1.0) * m as f64 * boundary
                / cluster.collective_bw(gpus)
        };
        let step = compute / (1.0 - bubble) + p2p;
        Some(StepEstimate {
            step_time_s: step,
            mem_per_gpu,
            mfu: eff * compute / step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bubble_fraction_sane() {
        // 4 stages, 8 microbatches: bubble = 3/11
        let g = 4.0f64;
        let m = 8.0;
        assert!(((g - 1.0) / (m + g - 1.0) - 3.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn fits_gpt2_with_few_gpus() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt2_xl();
        // 2 GPUs: 12 GB state per stage + activations -> feasible
        let e = GPipe::default().search(&m, &c, 2, 16).expect("feasible");
        assert!(e.mem_per_gpu < 40e9);
    }

    #[test]
    fn single_gpu_has_no_bubble_penalty_vs_ddp_compute() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::resnet200();
        let e = GPipe::default().search(&m, &c, 1, 64).unwrap();
        // g=1: no bubble, no remat, no p2p — pure (saturation-scaled) compute
        let eff = GPipe::default().mfu
            * crate::parallelism::api::batch_efficiency(8.0); // micro=64/8
        let compute = m.flops_per_step(64) / (c.gpu().peak_flops * eff);
        assert!((e.step_time_s - compute).abs() / compute < 1e-9);
    }

    #[test]
    fn diminishing_returns_from_bubble() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt2_xl();
        let p = GPipe::default();
        let t2 = p.search(&m, &c, 2, 32).unwrap().step_time_s;
        let t8 = p.search(&m, &c, 8, 32).unwrap().step_time_s;
        // 4x GPUs but far less than 4x faster (bubble grows)
        assert!(t8 > t2 / 4.0);
        assert!(t8 < t2); // still faster though
    }

    #[test]
    fn stage_count_bounded_by_layers() {
        let c = ClusterSpec::p4d(2);
        let mut m = ModelSpec::gpt2_xl();
        m.layers = 8;
        assert!(GPipe::default().search(&m, &c, 16, 32).is_none());
    }
}
