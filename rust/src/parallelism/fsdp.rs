//! FSDP / ZeRO-3 cost model (PyTorch FullyShardedDataParallel).
//!
//! Training state shards across GPUs; each step all-gathers weights twice
//! (fwd + bwd) and reduce-scatters gradients:
//!
//!   comm_bytes ~= 3 * 2B * params * (g-1)/g      (bf16 shards)
//!   step = compute(batch/g) + (1 - overlap) * comm_bytes / bus_bw
//!
//! Memory: state/g + gathered-layer working set + activations(batch/g).
//! FSDP unlocks single-node training of the paper's multi-billion-param
//! models at moderate communication cost.

use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;
use crate::parallelism::api::{mem, Parallelism, StepEstimate};

#[derive(Debug, Clone)]
pub struct Fsdp {
    pub mfu: f64,
    pub overlap: f64,
}

impl Default for Fsdp {
    fn default() -> Self {
        Fsdp { mfu: 0.40, overlap: 0.5 }
    }
}

impl Parallelism for Fsdp {
    fn name(&self) -> &str {
        "fsdp"
    }

    fn search(&self, model: &ModelSpec, cluster: &ClusterSpec, gpus: u32,
              batch: u32) -> Option<StepEstimate> {
        if gpus == 0 || gpus > cluster.total_gpus() || batch < gpus {
            return None;
        }
        let per_gpu_batch = batch as f64 / gpus as f64;
        // FSDP deployments for multi-billion-param fine-tuning pair with
        // activation checkpointing (FairScale/PyTorch default guidance).
        let mem_per_gpu = mem::sharded_state(model, gpus)
            + mem::checkpointed_act(model, per_gpu_batch);
        if mem_per_gpu > cluster.gpu().usable_bytes() {
            return None;
        }
        let eff = self.mfu * crate::parallelism::api::batch_efficiency(per_gpu_batch);
        // checkpointing re-runs forward during backward: +1/3 compute
        let compute = (4.0 / 3.0) * model.flops_per_step(batch)
            / (gpus as f64 * cluster.gpu().peak_flops * eff);
        let comm = if gpus == 1 {
            0.0
        } else {
            3.0 * 2.0 * model.params * (gpus as f64 - 1.0) / gpus as f64
                / cluster.collective_bw(gpus)
        };
        let step = compute + (1.0 - self.overlap) * comm;
        Some(StepEstimate {
            step_time_s: step,
            mem_per_gpu,
            mfu: eff * compute / step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlocks_gpt2_on_one_node() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt2_xl();
        let e = Fsdp::default().search(&m, &c, 8, 16).expect("feasible");
        assert!(e.mem_per_gpu < 40e9);
    }

    #[test]
    fn gptj_needs_many_gpus() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt_j(); // 96.8 GB state
        let f = Fsdp::default();
        assert!(f.search(&m, &c, 1, 16).is_none());
        assert!(f.search(&m, &c, 2, 16).is_none());
        assert!(f.search(&m, &c, 8, 16).is_some());
    }

    #[test]
    fn sharding_reduces_memory() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt2_xl();
        let f = Fsdp::default();
        let m4 = f.search(&m, &c, 4, 16).map(|e| e.mem_per_gpu);
        let m8 = f.search(&m, &c, 8, 16).unwrap().mem_per_gpu;
        if let Some(m4) = m4 {
            assert!(m8 < m4);
        }
    }

    #[test]
    fn comm_overhead_vs_ddp() {
        // where both are feasible, FSDP is slower than DDP (3x shard traffic)
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::resnet200();
        let fsdp = Fsdp::default().search(&m, &c, 8, 64).unwrap();
        let ddp = crate::parallelism::ddp::Ddp::default()
            .search(&m, &c, 8, 64)
            .unwrap();
        assert!(fsdp.step_time_s > ddp.step_time_s);
    }
}
