//! DDP (PyTorch DistributedDataParallel) cost model.
//!
//! Full replication: every GPU holds the complete training state, the
//! global batch splits across replicas, gradients all-reduce each step.
//!
//! step = compute(batch/g) + (1 - overlap) * ring_allreduce(grad bytes)
//! ring_allreduce(bytes) = 2 * (g-1)/g * bytes / bus_bw
//!
//! DDP is the throughput king for models that FIT (ResNet-200) and
//! infeasible for the large transformers — the asymmetry that makes the
//! paper's joint parallelism selection matter.

use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;
use crate::parallelism::api::{mem, Parallelism, StepEstimate};

#[derive(Debug, Clone)]
pub struct Ddp {
    /// Achieved MFU for dense compute under DDP.
    pub mfu: f64,
    /// Fraction of the all-reduce hidden behind backward compute.
    pub overlap: f64,
}

impl Default for Ddp {
    fn default() -> Self {
        Ddp { mfu: 0.45, overlap: 0.7 }
    }
}

impl Parallelism for Ddp {
    fn name(&self) -> &str {
        "ddp"
    }

    fn search(&self, model: &ModelSpec, cluster: &ClusterSpec, gpus: u32,
              batch: u32) -> Option<StepEstimate> {
        if gpus == 0 || gpus > cluster.total_gpus() || batch < gpus {
            return None;
        }
        let per_gpu_batch = batch as f64 / gpus as f64;
        let mem_per_gpu = mem::replicated_state(model)
            + model.act_bytes_per_sample * per_gpu_batch;
        if mem_per_gpu > cluster.gpu().usable_bytes() {
            return None; // the A100-40GB wall for GPT-2 XL and up
        }
        let eff = self.mfu * crate::parallelism::api::batch_efficiency(per_gpu_batch);
        let compute = model.flops_per_step(batch)
            / (gpus as f64 * cluster.gpu().peak_flops * eff);
        let comm = if gpus == 1 {
            0.0
        } else {
            let grad_bytes = 4.0 * model.params; // fp32 gradient buckets
            2.0 * (gpus as f64 - 1.0) / gpus as f64 * grad_bytes
                / cluster.collective_bw(gpus)
        };
        let step = compute + (1.0 - self.overlap) * comm;
        Some(StepEstimate {
            step_time_s: step,
            mem_per_gpu,
            mfu: eff * compute / step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infeasible_for_gpt2_xl() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt2_xl();
        // full replication of AdamW state (20B/param = 30 GB) plus two
        // samples of pre-flash activations exceeds the usable A100-40GB.
        assert!(m.state_bytes() + m.act_bytes(2)
                > c.gpu().usable_bytes());
        assert!(Ddp::default().search(&m, &c, 8, 16).is_none());
    }

    #[test]
    fn feasible_and_fast_for_resnet() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::resnet200();
        let e = Ddp::default().search(&m, &c, 8, 64).expect("fits");
        assert!(e.step_time_s > 0.0);
        assert!(e.mem_per_gpu < 40e9);
    }

    #[test]
    fn runtime_improves_with_gpus() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::resnet200();
        let d = Ddp::default();
        let t1 = d.search(&m, &c, 1, 64).unwrap().step_time_s;
        let t8 = d.search(&m, &c, 8, 64).unwrap().step_time_s;
        assert!(t8 < t1 / 4.0, "t1={t1} t8={t8}");
    }

    #[test]
    fn h100_class_unlocks_and_outruns_a100() {
        // per-class feasibility: the same (job, tech, gpus) point is
        // infeasible on the A100 class yet feasible on H100-80GB; where
        // both fit, the H100 class is strictly faster.
        let a = ClusterSpec::p4d(1);
        let h = ClusterSpec::p5(1);
        let d = Ddp::default();
        let m = ModelSpec::gpt2_xl();
        assert!(d.search(&m, &a, 8, 16).is_none());
        assert!(d.search(&m, &h, 8, 16).is_some());
        let r = ModelSpec::resnet200();
        let ta = d.search(&r, &a, 8, 64).unwrap().step_time_s;
        let th = d.search(&r, &h, 8, 64).unwrap().step_time_s;
        assert!(th < ta, "H100 step {th} !< A100 step {ta}");
    }

    #[test]
    fn batch_smaller_than_gpus_rejected() {
        let c = ClusterSpec::p4d(2);
        let m = ModelSpec::resnet200();
        assert!(Ddp::default().search(&m, &c, 16, 8).is_none());
    }

    #[test]
    fn cross_node_comm_penalty() {
        let c = ClusterSpec::p4d(2);
        let m = ModelSpec::resnet200();
        let d = Ddp::default();
        let t8 = d.search(&m, &c, 8, 128).unwrap().step_time_s;
        let t16 = d.search(&m, &c, 16, 128).unwrap().step_time_s;
        // 16 GPUs cross nodes: comm over EFA erodes the 2x compute win
        assert!(t16 > t8 * 0.5, "t8={t8} t16={t16}");
    }
}
