//! Model offloading cost model (FairScale OffloadModel / ZeRO-Offload
//! style): weights + optimizer state live in host DRAM, layers stream over
//! PCIe for fwd/bwd, the optimizer step runs on CPU.
//!
//!   step = compute(batch) / (g * peak * mfu_offload)
//!          + pcie_traffic / (g * pcie_bw)
//!   pcie_traffic ~= 2B*P (weights in, fwd) + 2B*P (weights in, bwd)
//!                 + 2B*P (grads out)             = 6B * params
//!
//! Always memory-feasible (GPU holds only a layer window + activations) and
//! nearly always the slowest option — the scheduler's technique of last
//! resort, which is exactly its role in the paper.

use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;
use crate::parallelism::api::{Parallelism, StepEstimate};

#[derive(Debug, Clone)]
pub struct Offload {
    pub mfu: f64,
    /// Fraction of PCIe traffic hidden behind compute (double buffering).
    pub overlap: f64,
}

impl Default for Offload {
    fn default() -> Self {
        Offload { mfu: 0.30, overlap: 0.4 }
    }
}

impl Parallelism for Offload {
    fn name(&self) -> &str {
        "offload"
    }

    fn search(&self, model: &ModelSpec, cluster: &ClusterSpec, gpus: u32,
              batch: u32) -> Option<StepEstimate> {
        if gpus == 0 || gpus > cluster.total_gpus() || batch < gpus {
            return None;
        }
        let per_gpu_batch = batch as f64 / gpus as f64;
        // GPU working set: a 2-layer weight window + activation
        // checkpoints (layer boundaries) + one layer's recompute acts —
        // offload engines always pair with activation checkpointing.
        let window = 2.0 * 2.0 * model.params / model.layers as f64;
        let ckpts = model.layers as f64 * model.boundary_bytes_per_sample()
            * per_gpu_batch;
        let working =
            model.act_bytes_per_sample * per_gpu_batch / model.layers as f64;
        let mem_per_gpu = window + ckpts + working;
        if mem_per_gpu > cluster.gpu().usable_bytes() {
            return None; // activations can still overflow at huge batches
        }
        // checkpointing re-runs forward during backward: +1/3 compute
        let eff = self.mfu * crate::parallelism::api::batch_efficiency(per_gpu_batch);
        let compute = (4.0 / 3.0) * model.flops_per_step(batch)
            / (gpus as f64 * cluster.gpu().peak_flops * eff);
        let pcie = 6.0 * model.params / (gpus as f64 * cluster.pcie_bw());
        // data-parallel grad sync when g > 1 (fp32, ring)
        let sync = if gpus == 1 {
            0.0
        } else {
            2.0 * (gpus as f64 - 1.0) / gpus as f64 * 4.0 * model.params
                / cluster.collective_bw(gpus)
        };
        // the node's copy engines floor the overlap: a gen5 host (H100
        // class) hides more of the stream than the technique's gen4
        // default no matter how the technique was tuned
        let overlap = self.overlap.max(cluster.pcie_overlap());
        let step = compute + (1.0 - overlap) * pcie + sync;
        Some(StepEstimate {
            step_time_s: step,
            mem_per_gpu,
            mfu: eff * compute / step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_feasible_for_gptj_single_gpu() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt_j();
        let e = Offload::default().search(&m, &c, 1, 16).expect("feasible");
        assert!(e.mem_per_gpu < 40e9);
    }

    #[test]
    fn slower_than_fsdp_when_both_fit() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt2_xl();
        let off = Offload::default().search(&m, &c, 8, 16).unwrap();
        let fsdp = crate::parallelism::fsdp::Fsdp::default()
            .search(&m, &c, 8, 16)
            .unwrap();
        assert!(off.step_time_s > fsdp.step_time_s);
    }

    #[test]
    fn pcie_dominates_for_big_models() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt_j();
        let e = Offload::default().search(&m, &c, 1, 16).unwrap();
        let pcie = 6.0 * m.params / c.pcie_bw() * (1.0 - 0.4);
        assert!(e.step_time_s > pcie * 0.9);
    }

    #[test]
    fn gen5_overlap_hides_more_pcie_on_h100() {
        let m = ModelSpec::gpt_j();
        let o = Offload::default();
        let p5 = ClusterSpec::p5(1);
        assert_eq!(p5.pcie_overlap(), 0.7);
        assert_eq!(ClusterSpec::p4d(1).pcie_overlap(), 0.4);
        // A/B: the same H100 node with its overlap dialed back to the
        // gen4 figure must be slower by EXACTLY the extra hidden share
        // of the stream — the term touches nothing else
        let mut gen4_node = crate::cluster::NodeSpec::p5_48xlarge();
        gen4_node.pcie_overlap = 0.4;
        let gen4 = ClusterSpec::single("h100-gen4", 1, gen4_node, 200e9);
        let fast = o.search(&m, &p5, 1, 16).unwrap().step_time_s;
        let slow = o.search(&m, &gen4, 1, 16).unwrap().step_time_s;
        let pcie = 6.0 * m.params / p5.pcie_bw();
        assert!(fast < slow);
        assert!((slow - fast - 0.3 * pcie).abs() < 1e-9 * slow.max(1.0));
    }

    #[test]
    fn multi_gpu_offload_scales() {
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::gpt_j();
        let o = Offload::default();
        let t1 = o.search(&m, &c, 1, 16).unwrap().step_time_s;
        let t8 = o.search(&m, &c, 8, 16).unwrap().step_time_s;
        assert!(t8 < t1);
    }
}
