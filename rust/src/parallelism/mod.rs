//! The Parallelism Library (paper §2, Figure 1B).
//!
//! Users register techniques behind the two-function `Parallelism` trait
//! (`search` = feasibility + cost estimate, `execute` = launch); Saturn's
//! Trial Runner then profiles every (model, technique, GPU count) and the
//! Solver picks per-job winners. Four built-ins mirror the paper's
//! registration set: DDP and FSDP (PyTorch Distributed), GPipe, and
//! FairScale-style model offloading.

pub mod api;
pub mod ddp;
pub mod fsdp;
pub mod gpipe;
pub mod megatron;
pub mod offload;

pub use api::{Library, Parallelism, StepEstimate};

/// The paper's default library: DDP, FSDP, GPipe, offloading.
pub fn default_library() -> Library {
    let mut lib = Library::new();
    lib.register(Box::new(ddp::Ddp::default()));
    lib.register(Box::new(fsdp::Fsdp::default()));
    lib.register(Box::new(gpipe::GPipe::default()));
    lib.register(Box::new(offload::Offload::default()));
    lib
}

/// Default library + Megatron tensor parallelism (extensibility demo /
/// ablation arm; Table 2 itself uses the paper's four techniques).
pub fn extended_library() -> Library {
    let mut lib = default_library();
    lib.register(Box::new(megatron::MegatronTp::default()));
    lib
}
