//! The two-function Parallelism interface + the Library registry
//! (paper Figure 1B: `search(model, gpus)` / `execute(model, gpus)`).

use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;

/// Result of `search`: the technique's cost/feasibility estimate for one
/// (model, batch, gpus) combination.
#[derive(Debug, Clone, PartialEq)]
pub struct StepEstimate {
    /// Wall-clock seconds for one optimizer step.
    pub step_time_s: f64,
    /// Peak per-GPU memory demand, bytes.
    pub mem_per_gpu: f64,
    /// Model FLOP utilization achieved (diagnostics / roofline reports).
    pub mfu: f64,
}

/// A registered parallelization technique (the paper's user-extensible
/// black box). `search` must be side-effect free; `execute` is invoked by
/// the execution engine (simulator or the PJRT-backed real executor) and
/// returns the realized step time.
pub trait Parallelism: Send + Sync {
    fn name(&self) -> &str;

    /// Feasibility + cost estimate; `None` when the technique cannot run
    /// this model on `gpus` GPUs (e.g. out of memory, or pipeline depth
    /// exceeding layers). `cluster` is always a single-class view
    /// ([`ClusterSpec::class_view`]): on heterogeneous fleets the Trial
    /// Runner profiles each GPU class separately, so the estimate is
    /// per (model, technique, gpus, class).
    fn search(&self, model: &ModelSpec, cluster: &ClusterSpec, gpus: u32,
              batch: u32) -> Option<StepEstimate>;

    /// Launch one training step under this technique. The default mirrors
    /// `search` (the simulator realizes estimates); the real executor
    /// overrides timing with measured PJRT step times.
    fn execute(&self, model: &ModelSpec, cluster: &ClusterSpec, gpus: u32,
               batch: u32) -> Option<StepEstimate> {
        self.search(model, cluster, gpus, batch)
    }
}

/// Registry of techniques, reusable across sessions/users (paper §2).
#[derive(Default)]
pub struct Library {
    techniques: Vec<Box<dyn Parallelism>>,
}

impl Library {
    pub fn new() -> Self {
        Library { techniques: Vec::new() }
    }

    /// `registerParallelism` in Figure 1B.
    pub fn register(&mut self, tech: Box<dyn Parallelism>) {
        assert!(
            self.techniques.iter().all(|t| t.name() != tech.name()),
            "technique '{}' already registered",
            tech.name()
        );
        self.techniques.push(tech);
    }

    pub fn len(&self) -> usize {
        self.techniques.len()
    }

    pub fn is_empty(&self) -> bool {
        self.techniques.is_empty()
    }

    pub fn get(&self, idx: usize) -> &dyn Parallelism {
        self.techniques[idx].as_ref()
    }

    pub fn by_name(&self, name: &str) -> Option<(usize, &dyn Parallelism)> {
        self.techniques
            .iter()
            .enumerate()
            .find(|(_, t)| t.name() == name)
            .map(|(i, t)| (i, t.as_ref()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.techniques.iter().map(|t| t.name()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = (usize, &dyn Parallelism)> {
        self.techniques.iter().enumerate().map(|(i, t)| (i, t.as_ref()))
    }
}

/// Strong-scaling saturation: achievable MXU/SM occupancy falls off as the
/// per-GPU (or per-microbatch) sample count shrinks — the effect that makes
/// "throw 8 GPUs at every job" wasteful and joint allocation worth doing.
/// Calibrated as a saturating curve with half-occupancy at 4 samples
/// (typical for A100-class transformers; see DESIGN.md §6).
pub fn batch_efficiency(samples_per_unit: f64) -> f64 {
    let s = samples_per_unit.max(0.0);
    s / (s + 4.0)
}

/// Shared memory-model helpers used by the built-in techniques.
pub mod mem {
    use crate::models::ModelSpec;

    /// Full replicated training state (bytes/GPU) under data parallelism.
    pub fn replicated_state(model: &ModelSpec) -> f64 {
        model.state_bytes()
    }

    /// ZeRO-3/FSDP: state sharded across `g` GPUs + one layer's gathered
    /// weights as working set.
    pub fn sharded_state(model: &ModelSpec, g: u32) -> f64 {
        model.state_bytes() / g as f64
            + 2.0 * model.params / model.layers as f64 // gathered layer (bf16)
    }

    /// Activation footprint WITH checkpointing: per-layer boundaries are
    /// stashed, one layer's activations recompute during backward.
    pub fn checkpointed_act(model: &ModelSpec, samples: f64) -> f64 {
        samples
            * (model.layers as f64 * model.boundary_bytes_per_sample()
                + model.act_bytes_per_sample / model.layers as f64)
    }

    /// Pipeline: contiguous stage of `layers/g` layers.
    pub fn pipeline_stage_state(model: &ModelSpec, g: u32) -> f64 {
        model.state_bytes() / g as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::models::ModelSpec;

    struct Fake(&'static str);

    impl Parallelism for Fake {
        fn name(&self) -> &str {
            self.0
        }

        fn search(&self, _: &ModelSpec, _: &ClusterSpec, gpus: u32, _: u32)
            -> Option<StepEstimate> {
            Some(StepEstimate { step_time_s: 1.0 / gpus as f64,
                                mem_per_gpu: 1.0, mfu: 0.5 })
        }
    }

    #[test]
    fn register_and_lookup() {
        let mut lib = Library::new();
        lib.register(Box::new(Fake("a")));
        lib.register(Box::new(Fake("b")));
        assert_eq!(lib.len(), 2);
        assert_eq!(lib.by_name("b").unwrap().0, 1);
        assert_eq!(lib.names(), vec!["a", "b"]);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn duplicate_names_rejected() {
        let mut lib = Library::new();
        lib.register(Box::new(Fake("a")));
        lib.register(Box::new(Fake("a")));
    }

    #[test]
    fn execute_defaults_to_search() {
        let f = Fake("x");
        let c = ClusterSpec::p4d(1);
        let m = ModelSpec::resnet200();
        assert_eq!(f.execute(&m, &c, 4, 16), f.search(&m, &c, 4, 16));
    }
}
