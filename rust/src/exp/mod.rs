//! Experiment harness: regenerates every numeric artifact of the paper
//! (Table 2 and the §3 speedup claims) plus the ablations DESIGN.md §5
//! indexes. Shared by `saturn table2`, `benches/bench_table2.rs`, and the
//! integration tests.

use crate::baselines::{CurrentPractice, Optimus, OptimusDynamic, RandomPolicy};
use crate::cluster::ClusterSpec;
use crate::parallelism::default_library;
use crate::saturn::SaturnPolicy;
use crate::sim::engine::{simulate, Policy, SimConfig, SimResult};
use crate::trials::{profile_analytic, ProfileTable};
use crate::workload::{imagenet_workload, wikitext_workload, Job};

pub const SYSTEMS: [&str; 5] =
    ["current-practice", "random", "optimus", "optimus-dynamic", "saturn"];

/// One Table 2 cell: a (workload, nodes, system) simulation.
#[derive(Debug, Clone)]
pub struct Cell {
    pub system: &'static str,
    pub nodes: u32,
    pub makespan_h: f64,
    pub result: SimResult,
}

pub fn make_policy(system: &str, seed: u64) -> Box<dyn Policy> {
    match system {
        "current-practice" => Box::new(CurrentPractice),
        "random" => Box::new(RandomPolicy::new(seed)),
        "optimus" => Box::new(Optimus),
        "optimus-dynamic" => Box::new(OptimusDynamic::default()),
        "saturn" => Box::new(SaturnPolicy::paper_default()),
        other => panic!("unknown system '{other}'"),
    }
}

pub fn workload_by_name(name: &str) -> Vec<Job> {
    match name {
        "wikitext" => wikitext_workload(),
        "imagenet" => imagenet_workload(),
        other => panic!("unknown workload '{other}' (wikitext|imagenet)"),
    }
}

/// Run one cell of Table 2.
pub fn run_cell(workload: &str, nodes: u32, system: &str, seed: u64) -> Cell {
    let jobs = workload_by_name(workload);
    let cluster = ClusterSpec::p4d(nodes);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, &cluster);
    run_cell_with(&jobs, &profiles, &cluster, system, seed)
}

pub fn run_cell_with(jobs: &[Job], profiles: &ProfileTable,
                     cluster: &ClusterSpec, system: &str, seed: u64) -> Cell {
    let mut policy = make_policy(system, seed);
    let result = simulate(jobs, profiles, cluster, policy.as_mut(),
                          &SimConfig::default());
    Cell {
        system: SYSTEMS.iter().find(|s| **s == system).copied()
            .unwrap_or("custom"),
        nodes: cluster.total_nodes(),
        makespan_h: result.makespan_s / 3600.0,
        result,
    }
}

/// A full Table 2 row: all five systems on {1, 2} nodes for one workload.
pub fn run_row(workload: &str, seed: u64) -> Vec<(Cell, Cell)> {
    SYSTEMS
        .iter()
        .map(|sys| (run_cell(workload, 1, sys, seed),
                    run_cell(workload, 2, sys, seed)))
        .collect()
}

/// Paper's Table 2 values (hours), for side-by-side reporting.
pub fn paper_table2(workload: &str) -> [(f64, f64); 5] {
    match workload {
        "wikitext" => [(28.39, 14.57), (41.45, 21.76), (34.9, 16.62),
                       (24.87, 13.62), (17.24, 8.23)],
        "imagenet" => [(19.05, 10.15), (28.34, 14.44), (19.44, 10.19),
                       (17.31, 8.32), (11.31, 5.16)],
        other => panic!("unknown workload '{other}'"),
    }
}

/// Render a Table 2 row in the paper's format.
pub fn format_row(workload: &str, cells: &[(Cell, Cell)]) -> String {
    let paper = paper_table2(workload);
    let mut out = String::new();
    out.push_str(&format!(
        "== Table 2: {workload} — makespan hours as (1-node/2-node) ==\n"));
    out.push_str(&format!(
        "{:<18} {:>16} {:>16} {:>10}\n", "system", "measured", "paper", "ratio"));
    for (i, (c1, c2)) in cells.iter().enumerate() {
        let (p1, p2) = paper[i];
        out.push_str(&format!(
            "{:<18} {:>7.2}/{:<8.2} {:>7.2}/{:<8.2} {:>4.2}/{:<4.2}\n",
            c1.system, c1.makespan_h, c2.makespan_h, p1, p2,
            c1.makespan_h / p1, c2.makespan_h / p2));
    }
    // §3 headline: speedup & reduction vs current practice
    let cp = &cells[0];
    let sat = &cells[4];
    for (tag, a, b) in [("1-node", cp.0.makespan_h, sat.0.makespan_h),
                        ("2-node", cp.1.makespan_h, sat.1.makespan_h)] {
        out.push_str(&format!(
            "saturn vs current-practice ({tag}): {:.2}x speedup, {:.0}% reduction\n",
            a / b, 100.0 * (1.0 - b / a)));
    }
    out
}

/// Render an online-bench row (streaming scenario; see `online::run_trace`)
/// in the same spirit as `format_row`. Shared by the `saturn online` CLI,
/// `benches/bench_online.rs`, and `examples/online_stream.rs`.
pub fn format_online_row(metrics: &[crate::online::OnlineMetrics]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<24} {:>10} {:>10} {:>10} {:>11} {:>8} {:>7} {:>7} {:>9} \
         {:>8} {:>8} {:>8}\n",
        "system", "avgJCT(h)", "p95JCT(h)", "wJCT(h)", "makespan(h)",
        "util(%)", "kills", "miss", "wTard(h)", "solves", "p50(ms)",
        "p99(ms)"));
    for m in metrics {
        let solves = match (m.solves, m.warm_solves) {
            (Some(s), Some(w)) => format!("{s}({w}w)"),
            _ => "-".to_string(),
        };
        out.push_str(&format!(
            "{:<24} {:>10.2} {:>10.2} {:>10.2} {:>11.2} {:>8.0} {:>7} \
             {:>7} {:>9.3} {:>8} {:>8.2} {:>8.2}\n",
            m.system, m.avg_jct_s / 3600.0, m.p95_jct_s / 3600.0,
            m.weighted_jct_s / 3600.0, m.makespan_s / 3600.0,
            m.gpu_utilization * 100.0, m.early_stopped, m.deadline_misses,
            m.weighted_tardiness_s / 3600.0, solves,
            m.decision_p50_s * 1e3, m.decision_p99_s * 1e3));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_runs_and_reports_hours() {
        let c = run_cell("wikitext", 1, "current-practice", 0);
        assert!(c.makespan_h > 0.0);
        assert_eq!(c.nodes, 1);
    }

    #[test]
    fn paper_values_sane() {
        let p = paper_table2("wikitext");
        assert!((p[0].0 - 28.39).abs() < 1e-9);
        let p = paper_table2("imagenet");
        assert!((p[4].1 - 5.16).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "unknown workload")]
    fn unknown_workload_rejected() {
        workload_by_name("cifar");
    }
}
