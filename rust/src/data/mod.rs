//! Synthetic data generators (dataset substitution, DESIGN.md
//! §Hardware-Adaptation): a Zipfian token stream standing in for
//! WikiText-2 and labeled image-tensor batches standing in for ImageNet.
//! Content never affects scheduling decisions; the token stream feeds the
//! REAL training loop in `examples/e2e_train.rs`.

use crate::util::rng::Rng;

/// Zipfian LM corpus with local n-gram structure so next-token losses are
/// learnable (pure iid Zipf would bottom out at the unigram entropy).
pub struct TokenStream {
    rng: Rng,
    vocab: u32,
    /// Markov kick: with probability `p_repeat`, emit f(prev) instead of a
    /// fresh Zipf draw -> gives the model predictable transitions.
    p_repeat: f64,
    prev: u32,
}

impl TokenStream {
    pub fn new(seed: u64, vocab: u32) -> Self {
        TokenStream { rng: Rng::new(seed), vocab, p_repeat: 0.5, prev: 0 }
    }

    pub fn next_token(&mut self) -> u32 {
        let t = if self.rng.bool(self.p_repeat) {
            // deterministic successor: strong learnable signal
            (self.prev.wrapping_mul(31).wrapping_add(7)) % self.vocab
        } else {
            self.rng.zipf(self.vocab as usize, 1.1) as u32
        };
        self.prev = t;
        t
    }

    /// A `(batch, seq)` token matrix flattened row-major (i32 for PJRT).
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        (0..batch * seq).map(|_| self.next_token() as i32).collect()
    }
}

/// Synthetic "image" batch: normal pixels + balanced labels.
pub struct ImageStream {
    rng: Rng,
    classes: u32,
}

impl ImageStream {
    pub fn new(seed: u64, classes: u32) -> Self {
        ImageStream { rng: Rng::new(seed), classes }
    }

    pub fn batch(&mut self, batch: usize, pixels: usize) -> (Vec<f32>, Vec<i32>) {
        let data = (0..batch * pixels)
            .map(|_| self.rng.normal() as f32)
            .collect();
        let labels = (0..batch)
            .map(|_| self.rng.usize(self.classes as usize) as i32)
            .collect();
        (data, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let mut s = TokenStream::new(1, 512);
        let b = s.batch(4, 64);
        assert_eq!(b.len(), 256);
        assert!(b.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn stream_deterministic_per_seed() {
        let a = TokenStream::new(9, 512).batch(2, 32);
        let b = TokenStream::new(9, 512).batch(2, 32);
        let c = TokenStream::new(10, 512).batch(2, 32);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn tokens_have_learnable_structure() {
        // successor correlation: P(next == f(prev)) should be ~p_repeat,
        // far above chance
        let mut s = TokenStream::new(2, 512);
        let n = 20_000;
        let mut hits = 0;
        let mut prev = s.next_token();
        for _ in 0..n {
            let t = s.next_token();
            if t == (prev.wrapping_mul(31).wrapping_add(7)) % 512 {
                hits += 1;
            }
            prev = t;
        }
        assert!(hits as f64 / n as f64 > 0.3, "structure too weak");
    }

    #[test]
    fn image_batch_shapes() {
        let mut s = ImageStream::new(3, 1000);
        let (x, y) = s.batch(8, 3 * 32 * 32);
        assert_eq!(x.len(), 8 * 3072);
        assert_eq!(y.len(), 8);
        assert!(y.iter().all(|&c| (0..1000).contains(&c)));
    }
}
