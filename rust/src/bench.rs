//! Criterion-style benchmark harness (substrate: no `criterion` in the
//! offline set). Used by every target in `rust/benches/` via
//! `harness = false`.
//!
//! Measures wall time over warmup + sampled iterations and prints a
//! fixed-width report; `Bencher::run_fn` also returns the stats so bench
//! binaries can assert regressions.

use std::time::Instant;

use crate::util::stats::{percentile, Welford};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, samples: 10 }
    }
}

impl Bencher {
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bencher { warmup, samples }
    }

    /// Honor `SATURN_BENCH_FAST=1` (CI): single sample, no warmup.
    pub fn from_env() -> Self {
        if std::env::var("SATURN_BENCH_FAST").as_deref() == Ok("1") {
            Bencher::new(0, 1)
        } else {
            Bencher::default()
        }
    }

    pub fn run_fn<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            f();
        }
        let mut w = Welford::new();
        let mut xs = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let t0 = Instant::now();
            f();
            let dt = t0.elapsed().as_secs_f64();
            w.add(dt);
            xs.push(dt);
        }
        BenchStats {
            name: name.to_string(),
            samples: xs.len(),
            mean_s: w.mean(),
            std_s: w.std(),
            p50_s: percentile(&xs, 0.5),
            p99_s: percentile(&xs, 0.99),
            min_s: w.min(),
        }
    }

    pub fn report(&self, name: &str, f: impl FnMut()) -> BenchStats {
        let s = self.run_fn(name, f);
        print_stats(&s);
        s
    }
}

pub fn print_header(title: &str) {
    println!("\n### {title}");
    println!("{:<44} {:>10} {:>10} {:>10} {:>6}", "benchmark", "mean",
             "p50", "p99", "n");
}

pub fn print_stats(s: &BenchStats) {
    println!("{:<44} {:>10} {:>10} {:>10} {:>6}", s.name, fmt_s(s.mean_s),
             fmt_s(s.p50_s), fmt_s(s.p99_s), s.samples);
}

pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::new(1, 5);
        let s = b.run_fn("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert_eq!(s.samples, 5);
        assert!(s.mean_s > 0.0);
        assert!(s.p99_s >= s.p50_s);
        assert!(s.min_s <= s.mean_s + 1e-12);
    }

    #[test]
    fn formatting_units() {
        assert!(fmt_s(2.5e-9).contains("ns"));
        assert!(fmt_s(2.5e-5).contains("µs"));
        assert!(fmt_s(2.5e-2).contains("ms"));
        assert!(fmt_s(2.5).contains(" s"));
    }
}
