//! Artifact manifest: the machine-readable contract between `aot.py` and
//! the Rust runtime (`artifacts/manifest.json`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One lowered HLO artifact (train / eval / init for a model x batch).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub model: String,
    pub batch: Option<u32>,
    pub seq: u32,
    pub vocab: u32,
    pub padded_params: usize,
    pub param_count: usize,
    pub flops_per_step: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let arr = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::new();
        for a in arr {
            let get_str = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))
            };
            let get_num = |k: &str| -> Result<f64> {
                a.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))
            };
            artifacts.push(ArtifactSpec {
                name: get_str("name")?,
                file: dir.join(get_str("file")?),
                kind: get_str("kind")?,
                model: get_str("model")?,
                batch: a.get("batch").and_then(|v| v.as_f64()).map(|b| b as u32),
                seq: get_num("seq")? as u32,
                vocab: get_num("vocab")? as u32,
                padded_params: get_num("padded_params")? as usize,
                param_count: get_num("param_count")? as usize,
                flops_per_step: a
                    .get("flops_per_step")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Default location: `$SATURN_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Manifest> {
        let dir = std::env::var("SATURN_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    pub fn find(&self, kind: &str, model: &str, batch: Option<u32>)
        -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| {
            a.kind == kind && a.model == model
                && (batch.is_none() || a.batch == batch)
        })
    }

    pub fn train(&self, model: &str, batch: u32) -> Result<&ArtifactSpec> {
        self.find("train", model, Some(batch)).ok_or_else(|| {
            anyhow!("no train artifact for model={model} batch={batch}; \
                     available: {:?}",
                    self.artifacts.iter().map(|a| &a.name).collect::<Vec<_>>())
        })
    }

    pub fn init(&self, model: &str) -> Result<&ArtifactSpec> {
        self.find("init", model, None)
            .ok_or_else(|| anyhow!("no init artifact for model={model}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the package root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// `None` until `make artifacts` has produced the manifest — tests
    /// skip (with a note) rather than fail on artifact-less build farms.
    fn load_or_skip() -> Option<Manifest> {
        match Manifest::load(&artifacts_dir()) {
            Ok(m) => Some(m),
            Err(e) => {
                eprintln!("skipping artifact test (make artifacts first): {e:#}");
                None
            }
        }
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = load_or_skip() else { return };
        assert!(m.artifacts.len() >= 7);
        let t = m.train("tiny", 8).unwrap();
        assert_eq!(t.seq, 64);
        assert_eq!(t.padded_params % 2048, 0);
        assert!(t.file.exists());
        assert!(m.init("tiny").is_ok());
        assert!(m.train("tiny", 99).is_err());
    }

    #[test]
    fn find_filters_by_kind() {
        let Some(m) = load_or_skip() else { return };
        assert!(m.find("eval", "tiny", None).is_some());
        assert!(m.find("nope", "tiny", None).is_none());
    }
}
