//! PJRT client wrapper: HLO text -> compiled executable, executed with
//! `xla::Literal` inputs. Compilation is cached per artifact (one compiled
//! executable per model variant, as the architecture prescribes).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::runtime::artifacts::ArtifactSpec;

/// Process-wide PJRT engine. Thread-safe: executions serialize per
/// executable via PJRT itself; the compile cache is mutex-guarded.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn load(&self, path: &Path)
        -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&key)
            .with_context(|| format!("parsing HLO text {key}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compiling {key}"))?,
        );
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    pub fn load_artifact(&self, spec: &ArtifactSpec)
        -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        self.load(&spec.file)
    }

    /// Execute and fetch the (tuple) result as host literals.
    /// All artifacts are lowered with `return_tuple=True`, so the single
    /// output buffer decomposes into the function's results.
    pub fn run(&self, exe: &xla::PjRtLoadedExecutable, inputs: &[xla::Literal])
        -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(inputs).context("execute")?;
        let mut lit = out[0][0].to_literal_sync().context("fetch result")?;
        Ok(lit.decompose_tuple().context("decompose tuple")?)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;
    use std::path::PathBuf;

    /// `None` when the PJRT backend (or `make artifacts`) is unavailable —
    /// e.g. under the vendored `xla` stub — so tests skip instead of fail.
    fn setup() -> Option<(Engine, Manifest)> {
        let engine = match Engine::cpu() {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skipping PJRT test: {e:#}");
                return None;
            }
        };
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match Manifest::load(&dir) {
            Ok(m) => Some((engine, m)),
            Err(e) => {
                eprintln!("skipping PJRT test (make artifacts first): {e:#}");
                None
            }
        }
    }

    #[test]
    fn compiles_and_caches() {
        let Some((engine, m)) = setup() else { return };
        let spec = m.init("tiny").unwrap();
        let a = engine.load_artifact(spec).unwrap();
        let b = engine.load_artifact(spec).unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        assert_eq!(engine.compiled_count(), 1);
    }

    #[test]
    fn init_produces_param_vector() {
        let Some((engine, m)) = setup() else { return };
        let spec = m.init("tiny").unwrap();
        let exe = engine.load_artifact(spec).unwrap();
        let out = engine
            .run(&exe, &[xla::Literal::scalar(0i32)])
            .unwrap();
        assert_eq!(out.len(), 1);
        let flat = out[0].to_vec::<f32>().unwrap();
        assert_eq!(flat.len(), spec.padded_params);
        // ln gammas are 1.0 somewhere; padded tail is zero
        assert!(flat.iter().any(|&x| (x - 1.0).abs() < 1e-6));
        assert_eq!(flat[spec.padded_params - 1], 0.0);
    }
}
