//! Trainer: a real training session over one AOT artifact — the execution
//! backend behind `examples/e2e_train.rs` and the empirical Trial Runner.
//!
//! Owns the flat parameter/optimizer-state literals, feeds token batches,
//! and tracks the loss curve. The learning rate is a runtime input, so one
//! compiled executable serves every LR in a model-selection grid.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::data::TokenStream;
use crate::runtime::artifacts::{ArtifactSpec, Manifest};
use crate::runtime::client::Engine;

pub struct Trainer {
    engine: Arc<Engine>,
    train_exe: Arc<xla::PjRtLoadedExecutable>,
    spec: ArtifactSpec,
    // training state (host literals between steps)
    params: xla::Literal,
    m: xla::Literal,
    v: xla::Literal,
    pub step: u64,
    pub losses: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: u64,
    pub first_loss: f32,
    pub last_loss: f32,
    pub mean_step_ms: f64,
    pub tokens_per_s: f64,
    pub mfu_estimate: f64,
}

impl Trainer {
    /// Build a session: compile init+train artifacts, run init(seed).
    pub fn new(engine: Arc<Engine>, manifest: &Manifest, model: &str,
               batch: u32, seed: i32) -> Result<Trainer> {
        let init_spec = manifest.init(model)?;
        let train_spec = manifest.train(model, batch)?.clone();
        let init_exe = engine.load_artifact(init_spec)?;
        let train_exe = engine.load_artifact(&train_spec)?;

        let out = engine
            .run(&init_exe, &[xla::Literal::scalar(seed)])
            .context("running init")?;
        let params = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("init returned nothing"))?;
        let p = train_spec.padded_params;
        let zeros = vec![0f32; p];
        Ok(Trainer {
            engine,
            train_exe,
            spec: train_spec,
            params,
            m: xla::Literal::vec1(&zeros),
            v: xla::Literal::vec1(&zeros),
            step: 0,
            losses: Vec::new(),
        })
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// One optimizer step on a `(batch, seq)` i32 token matrix.
    pub fn step_tokens(&mut self, lr: f32, tokens: &[i32]) -> Result<f32> {
        let b = self.spec.batch.unwrap_or(0) as usize;
        let s = self.spec.seq as usize;
        if tokens.len() != b * s {
            return Err(anyhow!("expected {}x{}={} tokens, got {}", b, s,
                               b * s, tokens.len()));
        }
        let tok = xla::Literal::vec1(tokens).reshape(&[b as i64, s as i64])?;
        let step_l = xla::Literal::scalar((self.step + 1) as f32);
        let lr_l = xla::Literal::scalar(lr);
        // placeholder swap so we can move state into execute without clone
        let params = std::mem::replace(&mut self.params, xla::Literal::scalar(0f32));
        let m = std::mem::replace(&mut self.m, xla::Literal::scalar(0f32));
        let v = std::mem::replace(&mut self.v, xla::Literal::scalar(0f32));
        let outs = self
            .engine
            .run(&self.train_exe, &[params, m, v, step_l, lr_l, tok])
            .context("train step")?;
        let mut it = outs.into_iter();
        self.params = it.next().ok_or_else(|| anyhow!("missing params out"))?;
        self.m = it.next().ok_or_else(|| anyhow!("missing m out"))?;
        self.v = it.next().ok_or_else(|| anyhow!("missing v out"))?;
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("missing loss out"))?
            .get_first_element::<f32>()?;
        self.step += 1;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Convenience: stream synthetic tokens for `steps` steps.
    pub fn train_synthetic(&mut self, lr: f32, steps: u64, data_seed: u64)
        -> Result<TrainReport> {
        let b = self.spec.batch.unwrap_or(1) as usize;
        let s = self.spec.seq as usize;
        let mut stream = TokenStream::new(data_seed, self.spec.vocab);
        let t0 = Instant::now();
        let first_step = self.step;
        let mut first_loss = f32::NAN;
        let mut last_loss = f32::NAN;
        for i in 0..steps {
            let batch = stream.batch(b, s);
            let loss = self.step_tokens(lr, &batch)?;
            if i == 0 {
                first_loss = loss;
            }
            last_loss = loss;
        }
        let wall = t0.elapsed().as_secs_f64();
        let did = (self.step - first_step) as f64;
        let tokens = did * (b * s) as f64;
        let flops = self.spec.flops_per_step * did;
        Ok(TrainReport {
            steps: self.step - first_step,
            first_loss,
            last_loss,
            mean_step_ms: wall / did * 1e3,
            tokens_per_s: tokens / wall,
            mfu_estimate: flops / wall, // FLOP/s achieved (roofline vs CPU)
        })
    }

    /// The Trial Runner's probe: time `n` steps (paper: "one or two
    /// mini-batches"), excluding compilation (already cached).
    pub fn time_step(&mut self, lr: f32, n: u64, data_seed: u64) -> Result<f64> {
        let b = self.spec.batch.unwrap_or(1) as usize;
        let s = self.spec.seq as usize;
        let mut stream = TokenStream::new(data_seed, self.spec.vocab);
        // one warmup step (buffer setup), then timed probes
        let batch = stream.batch(b, s);
        self.step_tokens(lr, &batch)?;
        let t0 = Instant::now();
        for _ in 0..n {
            let batch = stream.batch(b, s);
            self.step_tokens(lr, &batch)?;
        }
        Ok(t0.elapsed().as_secs_f64() / n as f64)
    }

    // -- checkpoint support (see runtime::checkpoint) ----------------------

    pub fn params_vec(&self) -> Result<Vec<f32>> {
        Ok(self.params.to_vec::<f32>()?)
    }

    pub fn m_vec(&self) -> Result<Vec<f32>> {
        Ok(self.m.to_vec::<f32>()?)
    }

    pub fn v_vec(&self) -> Result<Vec<f32>> {
        Ok(self.v.to_vec::<f32>()?)
    }

    pub(crate) fn set_state(&mut self, params: &[f32], m: &[f32], v: &[f32],
                            step: u64, losses: Vec<f32>) {
        self.params = xla::Literal::vec1(params);
        self.m = xla::Literal::vec1(m);
        self.v = xla::Literal::vec1(v);
        self.step = step;
        self.losses = losses;
    }

    /// Current loss (mean of last k) for convergence checks.
    pub fn recent_loss(&self, k: usize) -> f32 {
        let n = self.losses.len();
        if n == 0 {
            return f32::NAN;
        }
        let k = k.min(n);
        self.losses[n - k..].iter().sum::<f32>() / k as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// `None` when the PJRT backend (or `make artifacts`) is unavailable —
    /// e.g. under the vendored `xla` stub — so tests skip instead of fail.
    fn setup() -> Option<(Arc<Engine>, Manifest)> {
        let engine = match Engine::cpu() {
            Ok(e) => Arc::new(e),
            Err(e) => {
                eprintln!("skipping PJRT test: {e:#}");
                return None;
            }
        };
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match Manifest::load(&dir) {
            Ok(m) => Some((engine, m)),
            Err(e) => {
                eprintln!("skipping PJRT test (make artifacts first): {e:#}");
                None
            }
        }
    }

    #[test]
    fn trains_tiny_and_loss_decreases() {
        let Some((engine, manifest)) = setup() else { return };
        let mut t = Trainer::new(engine, &manifest, "tiny", 8, 0).unwrap();
        let report = t.train_synthetic(3e-3, 12, 42).unwrap();
        assert_eq!(report.steps, 12);
        assert!(report.first_loss.is_finite());
        assert!(report.last_loss < report.first_loss,
                "loss did not decrease: {} -> {}",
                report.first_loss, report.last_loss);
        // initial loss ~ ln(512) = 6.24
        assert!((report.first_loss - 6.24).abs() < 0.5);
    }

    #[test]
    fn deterministic_given_seeds() {
        let Some((engine, manifest)) = setup() else { return };
        let mut a = Trainer::new(engine.clone(), &manifest, "tiny", 8, 7).unwrap();
        let mut b = Trainer::new(engine, &manifest, "tiny", 8, 7).unwrap();
        let ra = a.train_synthetic(1e-3, 3, 9).unwrap();
        let rb = b.train_synthetic(1e-3, 3, 9).unwrap();
        assert_eq!(ra.last_loss, rb.last_loss);
    }

    #[test]
    fn lr_zero_changes_nothing_in_loss_trajectory_shape() {
        let Some((engine, manifest)) = setup() else { return };
        let mut t = Trainer::new(engine, &manifest, "tiny", 8, 1).unwrap();
        let l0 = t.step_tokens(0.0, &vec![1i32; 8 * 64]).unwrap();
        let l1 = t.step_tokens(0.0, &vec![1i32; 8 * 64]).unwrap();
        // lr=0 with weight decay folded through lr -> params frozen
        assert!((l0 - l1).abs() < 1e-5, "{l0} vs {l1}");
    }

    #[test]
    fn probe_timing_positive() {
        let Some((engine, manifest)) = setup() else { return };
        let mut t = Trainer::new(engine, &manifest, "tiny", 8, 2).unwrap();
        let s = t.time_step(1e-3, 2, 3).unwrap();
        assert!(s > 0.0 && s < 60.0);
    }

    #[test]
    fn wrong_token_count_rejected() {
        let Some((engine, manifest)) = setup() else { return };
        let mut t = Trainer::new(engine, &manifest, "tiny", 8, 3).unwrap();
        assert!(t.step_tokens(1e-3, &[0i32; 7]).is_err());
    }
}
