//! Checkpoint/restore for real training sessions.
//!
//! The simulator charges an abstract checkpoint penalty when introspection
//! migrates a job (paper §2); this module is the REAL counterpart used by
//! the coordinator's executor lanes: a `Trainer`'s full state (flat
//! params, AdamW moments, step counter, loss history) round-trips through
//! a self-describing binary file, so a job can be stopped on one lane and
//! resumed on another — or in another process entirely.
//!
//! Format (little-endian):
//!   magic "STRNCKPT" | version u32 | step u64 | param_count u64 |
//!   params f32[P] | m f32[P] | v f32[P] | n_losses u64 | losses f32[n]

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

const MAGIC: &[u8; 8] = b"STRNCKPT";
const VERSION: u32 = 1;

/// In-memory checkpoint of a training session.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub losses: Vec<f32>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf = Vec::with_capacity(24 + 12 * self.params.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        for arr in [&self.params, &self.m, &self.v] {
            for x in arr.iter() {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        buf.extend_from_slice(&(self.losses.len() as u64).to_le_bytes());
        for x in &self.losses {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        // atomic-ish: write sidecar then rename
        let tmp = path.with_extension("tmp");
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {tmp:?}"))?;
        f.write_all(&buf)?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {path:?}"))?;
        let mut data = Vec::new();
        f.read_to_end(&mut data)?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > data.len() {
                bail!("truncated checkpoint at byte {pos:?}");
            }
            let s = &data[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 8)? != MAGIC {
            bail!("not a saturn checkpoint (bad magic)");
        }
        let version = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
        let p = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
        let mut read_arr = |pos: &mut usize, n: usize| -> Result<Vec<f32>> {
            let raw = take(pos, 4 * n)?;
            Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        };
        let params = read_arr(&mut pos, p)?;
        let m = read_arr(&mut pos, p)?;
        let v = read_arr(&mut pos, p)?;
        let nl = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
        let losses = read_arr(&mut pos, nl)?;
        if pos != data.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint { step, params, m, v, losses })
    }
}

impl crate::runtime::trainer::Trainer {
    /// Snapshot the full session state.
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        Ok(Checkpoint {
            step: self.step,
            params: self.params_vec()?,
            m: self.m_vec()?,
            v: self.v_vec()?,
            losses: self.losses.clone(),
        })
    }

    /// Restore a snapshot into this session (artifact shapes must match).
    pub fn restore(&mut self, ckpt: &Checkpoint) -> Result<()> {
        let p = self.spec().padded_params;
        if ckpt.params.len() != p {
            return Err(anyhow!(
                "checkpoint has {} params, artifact expects {p}",
                ckpt.params.len()));
        }
        self.set_state(&ckpt.params, &ckpt.m, &ckpt.v, ckpt.step,
                       ckpt.losses.clone());
        Ok(())
    }

    pub fn save_to(&self, path: &Path) -> Result<()> {
        self.checkpoint()?.save(path)
    }

    pub fn load_from(&mut self, path: &Path) -> Result<()> {
        self.restore(&Checkpoint::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Engine, Manifest, Trainer};
    use std::path::PathBuf;
    use std::sync::Arc;

    /// `None` when the PJRT backend (or `make artifacts`) is unavailable —
    /// e.g. under the vendored `xla` stub — so tests skip instead of fail.
    fn setup() -> Option<(Arc<Engine>, Manifest)> {
        let engine = match Engine::cpu() {
            Ok(e) => Arc::new(e),
            Err(e) => {
                eprintln!("skipping PJRT test: {e:#}");
                return None;
            }
        };
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match Manifest::load(&dir) {
            Ok(m) => Some((engine, m)),
            Err(e) => {
                eprintln!("skipping PJRT test (make artifacts first): {e:#}");
                None
            }
        }
    }

    #[test]
    fn roundtrip_preserves_training_trajectory() {
        let Some((engine, manifest)) = setup() else { return };
        let tokens: Vec<i32> = (0..8 * 64).map(|i| (i * 7 % 512) as i32).collect();

        // session A: 4 steps, checkpoint, 3 more steps
        let mut a = Trainer::new(engine.clone(), &manifest, "tiny", 8, 3).unwrap();
        for _ in 0..4 {
            a.step_tokens(1e-3, &tokens).unwrap();
        }
        let ckpt = a.checkpoint().unwrap();
        let mut want = Vec::new();
        for _ in 0..3 {
            want.push(a.step_tokens(1e-3, &tokens).unwrap());
        }

        // session B: restored from the checkpoint on a FRESH trainer
        let mut b = Trainer::new(engine, &manifest, "tiny", 8, 999).unwrap();
        b.restore(&ckpt).unwrap();
        assert_eq!(b.step, 4);
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(b.step_tokens(1e-3, &tokens).unwrap());
        }
        assert_eq!(got, want, "restored session diverged");
    }

    #[test]
    fn file_roundtrip() {
        let ckpt = Checkpoint {
            step: 42,
            params: (0..2048).map(|i| i as f32 * 0.5).collect(),
            m: vec![0.25; 2048],
            v: vec![0.125; 2048],
            losses: vec![6.2, 5.1, 4.0],
        };
        let path = std::env::temp_dir().join("saturn_ckpt_test.bin");
        ckpt.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt, loaded);
    }

    #[test]
    fn corrupt_files_rejected() {
        let path = std::env::temp_dir().join("saturn_ckpt_bad.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let path2 = std::env::temp_dir().join("saturn_ckpt_trunc.bin");
        let ckpt = Checkpoint { step: 1, params: vec![1.0; 16], m: vec![0.0; 16],
                                v: vec![0.0; 16], losses: vec![] };
        ckpt.save(&path2).unwrap();
        let full = std::fs::read(&path2).unwrap();
        std::fs::write(&path2, &full[..full.len() - 4]).unwrap();
        assert!(Checkpoint::load(&path2).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some((engine, manifest)) = setup() else { return };
        let mut t = Trainer::new(engine, &manifest, "tiny", 8, 0).unwrap();
        let ckpt = Checkpoint { step: 1, params: vec![0.0; 10], m: vec![0.0; 10],
                                v: vec![0.0; 10], losses: vec![] };
        assert!(t.restore(&ckpt).is_err());
    }
}
