//! PJRT execution runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the PJRT CPU client, and
//! drives training entirely from Rust. Python never runs here.

pub mod artifacts;
pub mod checkpoint;
pub mod client;
pub mod trainer;

pub use artifacts::{ArtifactSpec, Manifest};
pub use checkpoint::Checkpoint;
pub use client::Engine;
pub use trainer::{TrainReport, Trainer};
