//! Two-phase primal simplex LP solver (substrate: the paper uses Gurobi).
//!
//! Solves   min c'x   s.t.  Ax {<=,>=,=} b,  x >= 0
//! via the standard dense tableau with Bland's anti-cycling rule. Problem
//! sizes in Saturn's joint MILP are modest (hundreds of columns), so a
//! dense tableau is simple and fast enough; `solver/milp.rs` adds
//! branch-and-bound on top.
//!
//! Numerical conventions: all comparisons use `EPS = 1e-9`; callers should
//! scale coefficients to O(1)-O(1e3) (the Saturn solver normalizes runtimes
//! to slot units before formulating).

pub const EPS: f64 = 1e-9;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// One linear constraint: `coeffs . x  cmp  rhs` (sparse coefficient list).
#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// LP in "min" orientation. Variables are indexed 0..n and implicitly >= 0.
#[derive(Debug, Clone, Default)]
pub struct Lp {
    pub n: usize,
    pub objective: Vec<f64>, // length n, minimize
    pub constraints: Vec<Constraint>,
}

impl Lp {
    pub fn new(n: usize) -> Self {
        Lp { n, objective: vec![0.0; n], constraints: Vec::new() }
    }

    pub fn set_obj(&mut self, var: usize, coeff: f64) {
        self.objective[var] = coeff;
    }

    pub fn add(&mut self, coeffs: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) {
        debug_assert!(coeffs.iter().all(|&(j, _)| j < self.n));
        self.constraints.push(Constraint { coeffs, cmp, rhs });
    }

    /// Convenience: upper bound `x_j <= ub`.
    pub fn bound_le(&mut self, var: usize, ub: f64) {
        self.add(vec![(var, 1.0)], Cmp::Le, ub);
    }

    /// Convenience: lower bound `x_j >= lb`.
    pub fn bound_ge(&mut self, var: usize, lb: f64) {
        self.add(vec![(var, 1.0)], Cmp::Ge, lb);
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    Unbounded,
}

impl LpResult {
    pub fn optimal(&self) -> Option<(&[f64], f64)> {
        match self {
            LpResult::Optimal { x, objective } => Some((x, *objective)),
            _ => None,
        }
    }
}

/// Solve with the two-phase dense tableau simplex.
pub fn solve(lp: &Lp) -> LpResult {
    Tableau::build(lp).solve()
}

struct Tableau {
    /// rows m x cols (n + slacks + artificials + 1 rhs)
    a: Vec<Vec<f64>>,
    m: usize,
    cols: usize, // total structural+slack+artificial columns (excl. rhs)
    n: usize,    // original variables
    basis: Vec<usize>,
    artificials: Vec<usize>,
    obj: Vec<f64>, // original objective padded to `cols`
}

impl Tableau {
    fn build(lp: &Lp) -> Tableau {
        let m = lp.constraints.len();
        // Count slack columns (one per inequality) and artificials.
        let mut n_slack = 0;
        for c in &lp.constraints {
            if c.cmp != Cmp::Eq {
                n_slack += 1;
            }
        }
        // worst case: one artificial per row
        let cols = lp.n + n_slack + m;
        let mut a = vec![vec![0.0; cols + 1]; m];
        let mut basis = vec![usize::MAX; m];
        let mut artificials = Vec::new();
        let mut slack_idx = lp.n;
        let mut art_idx = lp.n + n_slack;

        for (i, c) in lp.constraints.iter().enumerate() {
            let mut rhs = c.rhs;
            let mut sign = 1.0;
            if rhs < 0.0 {
                // normalize rhs >= 0 by flipping the row
                rhs = -rhs;
                sign = -1.0;
            }
            for &(j, v) in &c.coeffs {
                a[i][j] += sign * v;
            }
            a[i][cols] = rhs;
            let cmp = match (c.cmp, sign < 0.0) {
                (Cmp::Le, false) | (Cmp::Ge, true) => Cmp::Le,
                (Cmp::Ge, false) | (Cmp::Le, true) => Cmp::Ge,
                (Cmp::Eq, _) => Cmp::Eq,
            };
            match cmp {
                Cmp::Le => {
                    a[i][slack_idx] = 1.0;
                    basis[i] = slack_idx;
                    slack_idx += 1;
                }
                Cmp::Ge => {
                    a[i][slack_idx] = -1.0; // surplus
                    slack_idx += 1;
                    a[i][art_idx] = 1.0;
                    basis[i] = art_idx;
                    artificials.push(art_idx);
                    art_idx += 1;
                }
                Cmp::Eq => {
                    a[i][art_idx] = 1.0;
                    basis[i] = art_idx;
                    artificials.push(art_idx);
                    art_idx += 1;
                }
            }
        }

        let mut obj = vec![0.0; cols];
        obj[..lp.n].copy_from_slice(&lp.objective);
        Tableau { a, m, cols, n: lp.n, basis, artificials, obj }
    }

    fn solve(mut self) -> LpResult {
        // Phase 1: minimize sum of artificials.
        if !self.artificials.is_empty() {
            let mut phase1 = vec![0.0; self.cols];
            for &j in &self.artificials {
                phase1[j] = 1.0;
            }
            match self.run_simplex(&phase1) {
                SimplexOutcome::Optimal(obj) => {
                    if obj > 1e-6 {
                        return LpResult::Infeasible;
                    }
                }
                SimplexOutcome::Unbounded => return LpResult::Infeasible,
            }
            // Drive remaining artificials out of the basis if possible.
            for i in 0..self.m {
                if self.artificials.contains(&self.basis[i]) {
                    let pivot_col = (0..self.n + self.cols - self.n)
                        .take(self.cols)
                        .find(|&j| {
                            !self.artificials.contains(&j)
                                && self.a[i][j].abs() > EPS
                        });
                    if let Some(j) = pivot_col {
                        self.pivot(i, j);
                    }
                    // else: redundant row; artificial stays basic at 0.
                }
            }
            // Freeze artificial columns at zero for phase 2.
            for &j in &self.artificials.clone() {
                for row in self.a.iter_mut() {
                    row[j] = 0.0;
                }
            }
        }

        // Phase 2: original objective.
        let obj = self.obj.clone();
        match self.run_simplex(&obj) {
            SimplexOutcome::Optimal(objective) => {
                let mut x = vec![0.0; self.n];
                for i in 0..self.m {
                    let b = self.basis[i];
                    if b < self.n {
                        x[b] = self.a[i][self.cols];
                    }
                }
                LpResult::Optimal { x, objective }
            }
            SimplexOutcome::Unbounded => LpResult::Unbounded,
        }
    }

    /// Reduced-cost simplex loop on objective `c`; returns optimal value.
    fn run_simplex(&mut self, c: &[f64]) -> SimplexOutcome {
        let max_iters = 200 * (self.m + self.cols);
        for iter in 0..max_iters {
            // reduced costs: z_j = c_j - c_B' B^-1 A_j (computed row-wise)
            let mut reduced = c.to_vec();
            for i in 0..self.m {
                let cb = c[self.basis[i]];
                if cb.abs() > EPS {
                    for j in 0..self.cols {
                        reduced[j] -= cb * self.a[i][j];
                    }
                }
            }
            // entering column: Dantzig normally, Bland past a burn-in to
            // guarantee termination under degeneracy.
            let entering = if iter < max_iters / 2 {
                let mut best = None;
                let mut best_val = -EPS;
                for (j, &r) in reduced.iter().enumerate() {
                    if r < best_val {
                        best_val = r;
                        best = Some(j);
                    }
                }
                best
            } else {
                reduced.iter().position(|&r| r < -EPS)
            };
            let Some(e) = entering else {
                // optimal; objective = c_B' b
                let mut obj = 0.0;
                for i in 0..self.m {
                    obj += c[self.basis[i]] * self.a[i][self.cols];
                }
                return SimplexOutcome::Optimal(obj);
            };
            // ratio test (Bland tie-break on basis index)
            let mut leave: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            for i in 0..self.m {
                if self.a[i][e] > EPS {
                    let ratio = self.a[i][self.cols] / self.a[i][e];
                    if ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS
                            && leave.map_or(true, |l| self.basis[i] < self.basis[l]))
                    {
                        best_ratio = ratio;
                        leave = Some(i);
                    }
                }
            }
            let Some(l) = leave else {
                return SimplexOutcome::Unbounded;
            };
            self.pivot(l, e);
        }
        // Iteration cap: treat as optimal-at-current-point; callers in this
        // repo only hit this on pathological random inputs.
        let mut obj = 0.0;
        for i in 0..self.m {
            obj += c[self.basis[i]] * self.a[i][self.cols];
        }
        SimplexOutcome::Optimal(obj)
    }

    fn pivot(&mut self, row: usize, col: usize) {
        let pv = self.a[row][col];
        debug_assert!(pv.abs() > EPS);
        let inv = 1.0 / pv;
        for v in self.a[row].iter_mut() {
            *v *= inv;
        }
        let pivot_row = self.a[row].clone();
        for (i, r) in self.a.iter_mut().enumerate() {
            if i != row && r[col].abs() > EPS {
                let factor = r[col];
                for (v, pv) in r.iter_mut().zip(&pivot_row) {
                    *v -= factor * pv;
                }
            }
        }
        self.basis[row] = col;
    }
}

enum SimplexOutcome {
    Optimal(f64),
    Unbounded,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} != {b}");
    }

    #[test]
    fn simple_maximization_as_min() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 (classic Dantzig ex.)
        // optimum (2,6) value 36 -> min form objective -36
        let mut lp = Lp::new(2);
        lp.set_obj(0, -3.0);
        lp.set_obj(1, -5.0);
        lp.add(vec![(0, 1.0)], Cmp::Le, 4.0);
        lp.add(vec![(1, 2.0)], Cmp::Le, 12.0);
        lp.add(vec![(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let res: LpResult = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, -36.0);
        assert_close(x[0], 2.0);
        assert_close(x[1], 6.0);
    }

    #[test]
    fn ge_and_eq_constraints() {
        // min x + 2y s.t. x + y = 10, x >= 3  -> x=10? No: y free to 0:
        // x+y=10, minimize x+2y -> prefer all x: x=10, y=0 (x>=3 ok), obj 10
        let mut lp = Lp::new(2);
        lp.set_obj(0, 1.0);
        lp.set_obj(1, 2.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Eq, 10.0);
        lp.bound_ge(0, 3.0);
        let res = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, 10.0);
        assert_close(x[0], 10.0);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new(1);
        lp.bound_ge(0, 5.0);
        lp.bound_le(0, 3.0);
        assert_eq!(solve(&lp), LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new(1);
        lp.set_obj(0, -1.0); // min -x, x >= 0 unbounded below
        assert_eq!(solve(&lp), LpResult::Unbounded);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // multiple redundant constraints through the same vertex
        let mut lp = Lp::new(2);
        lp.set_obj(0, -1.0);
        lp.set_obj(1, -1.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 1.0);
        lp.add(vec![(0, 2.0), (1, 2.0)], Cmp::Le, 2.0);
        lp.add(vec![(0, 1.0)], Cmp::Le, 1.0);
        lp.add(vec![(1, 1.0)], Cmp::Le, 1.0);
        let (_, obj) = solve(&lp).optimal().expect("optimal");
        assert_close(obj, -1.0);
    }

    #[test]
    fn negative_rhs_normalization() {
        // x - y <= -2  (i.e. y >= x + 2), min y -> x=0, y=2
        let mut lp = Lp::new(2);
        lp.set_obj(1, 1.0);
        lp.add(vec![(0, 1.0), (1, -1.0)], Cmp::Le, -2.0);
        let res = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, 2.0);
        assert_close(x[1], 2.0);
    }

    #[test]
    fn transportation_problem() {
        // 2 plants (cap 20, 30) -> 2 cities (demand 25, 25); costs
        // [[1,3],[2,1]]; optimum: p0->c0 20, p1->c0 5, p1->c1 25 = 20+10+25=55
        let mut lp = Lp::new(4); // x00 x01 x10 x11
        for (j, c) in [1.0, 3.0, 2.0, 1.0].iter().enumerate() {
            lp.set_obj(j, *c);
        }
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 20.0);
        lp.add(vec![(2, 1.0), (3, 1.0)], Cmp::Le, 30.0);
        lp.add(vec![(0, 1.0), (2, 1.0)], Cmp::Eq, 25.0);
        lp.add(vec![(1, 1.0), (3, 1.0)], Cmp::Eq, 25.0);
        let (_, obj) = solve(&lp).optimal().expect("optimal");
        assert_close(obj, 55.0);
    }
}

