//! Bounded-variable revised simplex LP solver (substrate: the paper uses
//! Gurobi).
//!
//! Solves   min c'x   s.t.  Ax {<=,>=,=} b,  l <= x <= u
//! with per-variable bounds held OUT of the constraint matrix: rows are
//! converted to equalities with one slack column each, and the simplex
//! works on the basis inverse (`B^-1`) over sparse columns instead of a
//! dense tableau. That keeps the row count at m (constraints only) —
//! the seed solver carried every bound as an extra row, which tripled
//! the tableau for Saturn's 0/1 plan-selection MILPs.
//!
//! Two entry styles:
//!  * [`solve`] / [`solve_with_info`] — one-shot cold solve of an [`Lp`]
//!    (two-phase: artificial phase 1 only for rows whose slack start is
//!    infeasible, then primal phase 2).
//!  * [`Simplex`] — a reusable factorization of the constraint matrix.
//!    `solve_cold` takes a bounds vector, so branch-and-bound re-solves
//!    the SAME matrix under different bounds without rebuilding or
//!    cloning anything; `solve_warm` re-solves after a bound change from
//!    a parent [`Basis`] via the dual simplex, typically in a handful of
//!    pivots (`solver::milp` warm-starts every child node this way).
//!
//! Basis maintenance is product-form (Forrest–Tomlin style): every pivot
//! records one sparse-support **eta vector** instead of eliminating a
//! dense row of `B^-1`, and the eta file is collapsed into a fresh dense
//! factorization only periodically — when the file reaches
//! [`REFACTOR_ETAS`] entries (spike count) or a pivot magnitude exceeds
//! [`ETA_DRIFT`] (numeric-drift trigger). FTRAN/BTRAN apply the file on
//! top of the last refactored inverse, and dual-simplex basic values are
//! updated incrementally per pivot (refactorization recomputes them from
//! scratch, bounding drift). [`LpInfo`] reports `eta_updates` and
//! `refactorizations` so callers can attribute time between the cheap
//! and the expensive path.
//!
//! Numerical conventions: all comparisons use `EPS = 1e-9`; callers
//! should scale coefficients to O(1)-O(1e3) (the Saturn solver
//! normalizes runtimes to slot units before formulating). The seed
//! dense-tableau implementation survives as `solver::dense` — the
//! property suite (`tests/prop_solver.rs`) holds the two to the same
//! objectives on random LPs.

pub const EPS: f64 = 1e-9;

/// Refactorize when the eta file reaches this many product-form updates.
pub const REFACTOR_ETAS: usize = 64;

/// Refactorize immediately when a pivot's `|1/w_r|` exceeds this — a
/// near-singular pivot is the classic source of factor drift.
pub const ETA_DRIFT: f64 = 1e6;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// One linear constraint: `coeffs . x  cmp  rhs` (sparse coefficient list).
#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// LP in "min" orientation. Variables are indexed 0..n with first-class
/// bounds `lower <= x <= upper` (default `[0, +inf)`).
#[derive(Debug, Clone, Default)]
pub struct Lp {
    pub n: usize,
    pub objective: Vec<f64>, // length n, minimize
    pub constraints: Vec<Constraint>,
    /// Per-variable lower bounds (length n, default 0).
    pub lower: Vec<f64>,
    /// Per-variable upper bounds (length n, default +inf).
    pub upper: Vec<f64>,
}

impl Lp {
    pub fn new(n: usize) -> Self {
        Lp {
            n,
            objective: vec![0.0; n],
            constraints: Vec::new(),
            lower: vec![0.0; n],
            upper: vec![f64::INFINITY; n],
        }
    }

    pub fn set_obj(&mut self, var: usize, coeff: f64) {
        self.objective[var] = coeff;
    }

    pub fn add(&mut self, coeffs: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) {
        debug_assert!(coeffs.iter().all(|&(j, _)| j < self.n));
        self.constraints.push(Constraint { coeffs, cmp, rhs });
    }

    /// Tighten the upper bound `x_j <= ub` (a variable bound, not a row).
    pub fn bound_le(&mut self, var: usize, ub: f64) {
        self.upper[var] = self.upper[var].min(ub);
    }

    /// Tighten the lower bound `x_j >= lb` (a variable bound, not a row).
    pub fn bound_ge(&mut self, var: usize, lb: f64) {
        self.lower[var] = self.lower[var].max(lb);
    }

    /// Set both bounds of a variable outright.
    pub fn set_bounds(&mut self, var: usize, lb: f64, ub: f64) {
        self.lower[var] = lb;
        self.upper[var] = ub;
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    Unbounded,
}

impl LpResult {
    pub fn optimal(&self) -> Option<(&[f64], f64)> {
        match self {
            LpResult::Optimal { x, objective } => Some((x, *objective)),
            _ => None,
        }
    }
}

/// A simplex basis: which column is basic in each row, and which bound
/// every nonbasic column sits at. Returned by [`Simplex::solve_cold`] and
/// accepted by [`Simplex::solve_warm`] — the warm-start currency of the
/// MILP's branch-and-bound.
#[derive(Debug, Clone, PartialEq)]
pub struct Basis {
    /// Length m: column index (structural `0..n` or slack `n..n+m`)
    /// basic in each row.
    pub basic: Vec<usize>,
    /// Length n+m: nonbasic columns at their upper (vs lower) bound.
    pub at_upper: Vec<bool>,
}

impl Basis {
    /// Carry this basis onto a RESIZED problem — the persistence API an
    /// incremental master maintains across row/column edits (arrivals
    /// append rows/columns, departures delete them; see
    /// `saturn::incremental`).
    ///
    /// `row_from[r]` names the OLD row each new row `r` descends from
    /// (`None` = brand-new row); `col_to[j]` names the NEW structural
    /// index of each old structural column `j` (`None` = deleted).
    /// `old_n`/`new_n` are the structural counts. Rules, per new row:
    ///
    ///  * a brand-new row starts with its own slack basic (dual-feasible
    ///    start for the dual-simplex repair pass);
    ///  * a surviving row keeps its old basic column, translated —
    ///    structural via `col_to`, slack via the surviving-row map; a
    ///    basic column that did not survive degrades to the row's own
    ///    slack.
    ///
    /// `at_upper` states are carried for every surviving column and
    /// default to the lower bound elsewhere. The result is a VALID
    /// shape for the new matrix but not necessarily a nonsingular or
    /// primal-feasible basis — [`Simplex::solve_warm`] already returns
    /// `None` on singular refactorization, so callers fall back to a
    /// cold solve and correctness never depends on the mapping.
    pub fn remap(&self, row_from: &[Option<usize>], col_to: &[Option<usize>],
                 old_n: usize, new_n: usize) -> Basis {
        debug_assert_eq!(col_to.len(), old_n);
        debug_assert_eq!(self.at_upper.len(), old_n + self.basic.len());
        let old_m = self.basic.len();
        let new_m = row_from.len();
        // surviving old row -> new row
        let mut new_of_old_row = vec![None; old_m];
        for (nr, of) in row_from.iter().enumerate() {
            if let Some(or) = *of {
                if or < old_m {
                    new_of_old_row[or] = Some(nr);
                }
            }
        }
        let mut basic = Vec::with_capacity(new_m);
        for (nr, of) in row_from.iter().enumerate() {
            let own_slack = new_n + nr;
            let b = match *of {
                Some(or) if or < old_m => {
                    let ob = self.basic[or];
                    if ob < old_n {
                        col_to[ob].unwrap_or(own_slack)
                    } else {
                        match new_of_old_row[ob - old_n] {
                            Some(nr2) => new_n + nr2,
                            None => own_slack,
                        }
                    }
                }
                _ => own_slack,
            };
            basic.push(b);
        }
        let mut at_upper = vec![false; new_n + new_m];
        for (j, to) in col_to.iter().enumerate() {
            if let Some(nc) = *to {
                at_upper[nc] = self.at_upper[j];
            }
        }
        for (or, to) in new_of_old_row.iter().enumerate() {
            if let Some(nr) = *to {
                at_upper[new_n + nr] = self.at_upper[old_n + or];
            }
        }
        Basis { basic, at_upper }
    }
}

/// Per-solve diagnostics.
#[derive(Debug, Clone, Default)]
pub struct LpInfo {
    /// Basis changes performed (phase 1 + phase 2, or dual + cleanup).
    pub pivots: usize,
    /// The iteration cap fired before convergence: the reported point is
    /// feasible but possibly suboptimal. Also logged via `log::warn!`.
    pub capped: bool,
    /// Product-form eta updates recorded in place of dense basis work.
    pub eta_updates: usize,
    /// From-scratch basis factorizations: one per warm entry plus every
    /// spike-count / drift-triggered collapse of the eta file.
    pub refactorizations: usize,
}

/// One solve's complete outcome.
#[derive(Debug, Clone)]
pub struct Solved {
    pub result: LpResult,
    /// Final basis for warm restarts; `None` when the result is not
    /// optimal or a redundant row kept an artificial column basic.
    pub basis: Option<Basis>,
    pub info: LpInfo,
}

/// One-shot cold solve (compat entry point).
pub fn solve(lp: &Lp) -> LpResult {
    solve_with_info(lp).0
}

/// One-shot cold solve returning pivot count / cap diagnostics.
pub fn solve_with_info(lp: &Lp) -> (LpResult, LpInfo) {
    let sx = Simplex::new(lp);
    let s = sx.solve_cold(&lp.lower, &lp.upper);
    (s.result, s.info)
}

// ---------------------------------------------------------------------------
// Reusable factorization: constraint matrix in standard form
// ---------------------------------------------------------------------------

/// The constraint matrix of an [`Lp`] in standard form `Ax + Is = b`,
/// stored as sparse columns, reusable across many bound vectors. Column
/// layout: structural `0..n`, slack `n..n+m` (Le: `s in [0,inf)`,
/// Ge: `s in (-inf,0]`, Eq: `s = 0`).
#[derive(Debug, Clone)]
pub struct Simplex {
    n: usize,
    m: usize,
    total: usize,
    /// Sparse columns, length `total` (structural then slack).
    cols: Vec<Vec<(usize, f64)>>,
    rhs: Vec<f64>,
    /// Objective padded to `total` (slacks cost 0).
    c: Vec<f64>,
    slack_lb: Vec<f64>,
    slack_ub: Vec<f64>,
}

impl Simplex {
    pub fn new(lp: &Lp) -> Simplex {
        let n = lp.n;
        let m = lp.constraints.len();
        let total = n + m;
        let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); total];
        let mut rhs = Vec::with_capacity(m);
        let mut slack_lb = Vec::with_capacity(m);
        let mut slack_ub = Vec::with_capacity(m);
        let mut row_acc: Vec<f64> = vec![0.0; n];
        for (i, cstr) in lp.constraints.iter().enumerate() {
            // coalesce duplicate variable entries within the row
            for &(j, v) in &cstr.coeffs {
                row_acc[j] += v;
            }
            for &(j, _) in &cstr.coeffs {
                if row_acc[j] != 0.0 {
                    cols[j].push((i, row_acc[j]));
                    row_acc[j] = 0.0;
                }
            }
            cols[n + i].push((i, 1.0));
            rhs.push(cstr.rhs);
            let (lo, hi) = match cstr.cmp {
                Cmp::Le => (0.0, f64::INFINITY),
                Cmp::Ge => (f64::NEG_INFINITY, 0.0),
                Cmp::Eq => (0.0, 0.0),
            };
            slack_lb.push(lo);
            slack_ub.push(hi);
        }
        let mut c = vec![0.0; total];
        c[..n].copy_from_slice(&lp.objective);
        Simplex { n, m, total, cols, rhs, c, slack_lb, slack_ub }
    }

    pub fn num_vars(&self) -> usize {
        self.n
    }

    pub fn num_rows(&self) -> usize {
        self.m
    }

    /// Two-phase primal solve under the given structural bounds
    /// (lengths n). Artificial columns are introduced only for rows whose
    /// slack start violates its bound.
    pub fn solve_cold(&self, lower: &[f64], upper: &[f64]) -> Solved {
        let mut st = State::new(self, lower, upper);
        st.solve_cold()
    }

    /// Dual-simplex re-solve from `basis` after bound changes; `None`
    /// when the basis cannot be reused (singular refactorization, an
    /// unbounded-side nonbasic, or a dual iteration cap) — callers fall
    /// back to [`Simplex::solve_cold`].
    pub fn solve_warm(&self, lower: &[f64], upper: &[f64], basis: &Basis)
        -> Option<Solved> {
        if basis.basic.len() != self.m || basis.at_upper.len() != self.total {
            return None;
        }
        let mut st = State::new(self, lower, upper);
        st.solve_warm(basis)
    }

    /// Row duals `y = c_B' B^-1` at `basis` — the prices a
    /// column-generation master hands its pricing subproblem so it can
    /// score candidate columns by reduced cost `c_j - y'A_j`. `None`
    /// when the basis does not fit this matrix or is singular.
    pub fn duals_for(&self, basis: &Basis) -> Option<Vec<f64>> {
        if basis.basic.len() != self.m {
            return None;
        }
        let binv = invert_basis(self, &basis.basic)?;
        let m = self.m;
        let mut y = vec![0.0; m];
        for (i, &b) in basis.basic.iter().enumerate() {
            let cb = self.c[b];
            if cb != 0.0 {
                for (yr, &bv) in y.iter_mut().zip(&binv[i * m..(i + 1) * m])
                {
                    *yr += cb * bv;
                }
            }
        }
        Some(y)
    }
}

// ---------------------------------------------------------------------------
// Per-solve state
// ---------------------------------------------------------------------------

enum Phase {
    Optimal(f64),
    Unbounded,
}

struct State<'a> {
    sx: &'a Simplex,
    /// Effective bounds, length `total` + artificials.
    lb: Vec<f64>,
    ub: Vec<f64>,
    /// Artificial columns appended past `total`: (row, sign).
    art: Vec<(usize, f64)>,
    basic: Vec<usize>,
    in_basis: Vec<bool>,
    at_upper: Vec<bool>,
    /// Dense inverse of the basis AT THE LAST REFACTORIZATION, row-major
    /// m x m. The live inverse is `E_k .. E_1 * binv` via `etas`.
    binv: Vec<f64>,
    /// Product-form eta file since the last refactorization: each entry
    /// `(p, eta)` is an identity matrix with column `p` replaced by
    /// `eta` (length m), applied left-to-right in vector order.
    etas: Vec<(usize, Vec<f64>)>,
    xb: Vec<f64>,
    pivots: usize,
    capped: bool,
    eta_updates: usize,
    refactorizations: usize,
}

impl<'a> State<'a> {
    fn new(sx: &'a Simplex, lower: &[f64], upper: &[f64]) -> State<'a> {
        debug_assert_eq!(lower.len(), sx.n);
        debug_assert_eq!(upper.len(), sx.n);
        let mut lb = Vec::with_capacity(sx.total);
        let mut ub = Vec::with_capacity(sx.total);
        lb.extend_from_slice(lower);
        ub.extend_from_slice(upper);
        lb.extend_from_slice(&sx.slack_lb);
        ub.extend_from_slice(&sx.slack_ub);
        State {
            sx,
            lb,
            ub,
            art: Vec::new(),
            basic: vec![usize::MAX; sx.m],
            in_basis: vec![false; sx.total],
            at_upper: vec![false; sx.total],
            binv: vec![0.0; sx.m * sx.m],
            etas: Vec::new(),
            xb: vec![0.0; sx.m],
            pivots: 0,
            capped: false,
            eta_updates: 0,
            refactorizations: 0,
        }
    }

    fn ncols(&self) -> usize {
        self.sx.total + self.art.len()
    }

    fn col(&self, j: usize) -> &[(usize, f64)] {
        if j < self.sx.total {
            &self.sx.cols[j]
        } else {
            std::slice::from_ref(&self.art[j - self.sx.total])
        }
    }

    fn cost(&self, c: &[f64], j: usize) -> f64 {
        if j < c.len() {
            c[j]
        } else {
            0.0
        }
    }

    fn nb_val(&self, j: usize) -> f64 {
        if self.at_upper[j] {
            self.ub[j]
        } else {
            self.lb[j]
        }
    }

    fn max_iters(&self) -> usize {
        200 * (self.sx.m + self.ncols())
    }

    /// Apply the eta file forward: `w <- E_k .. E_1 w`.
    fn apply_etas(&self, w: &mut [f64]) {
        for (p, eta) in &self.etas {
            let wp = w[*p];
            if wp != 0.0 {
                for (wi, ei) in w.iter_mut().zip(eta.iter()) {
                    *wi += ei * wp;
                }
                // the p-th term above added eta_p*wp ON TOP of wp; the
                // product-form column REPLACES it: w_p = eta_p * wp
                w[*p] -= wp;
            }
        }
    }

    /// Fold the eta file into a row vector from the right:
    /// `u' <- u' E_k .. E_1` (each transpose touches one component).
    fn fold_etas_rev(&self, u: &mut [f64]) {
        for (p, eta) in self.etas.iter().rev() {
            let mut d = 0.0;
            for (ui, ei) in u.iter().zip(eta.iter()) {
                d += ui * ei;
            }
            u[*p] = d;
        }
    }

    /// w = B^-1 A_j (FTRAN through the eta file).
    fn ftran(&self, j: usize) -> Vec<f64> {
        let m = self.sx.m;
        let mut w = vec![0.0; m];
        for &(r, v) in self.col(j) {
            for i in 0..m {
                let b = self.binv[i * m + r];
                if b != 0.0 {
                    w[i] += b * v;
                }
            }
        }
        self.apply_etas(&mut w);
        w
    }

    /// rho = e_r' B^-1, row `r` of the live inverse (BTRAN of a unit
    /// vector — what the dual ratio test prices columns against).
    fn btran_row(&self, r: usize) -> Vec<f64> {
        let m = self.sx.m;
        if self.etas.is_empty() {
            return self.binv[r * m..(r + 1) * m].to_vec();
        }
        let mut u = vec![0.0; m];
        u[r] = 1.0;
        self.fold_etas_rev(&mut u);
        let mut rho = vec![0.0; m];
        for (i, &ui) in u.iter().enumerate() {
            if ui != 0.0 {
                for k in 0..m {
                    rho[k] += ui * self.binv[i * m + k];
                }
            }
        }
        rho
    }

    /// y = c_B' B^-1 (BTRAN through the eta file).
    fn duals(&self, c: &[f64]) -> Vec<f64> {
        let m = self.sx.m;
        let mut u: Vec<f64> =
            (0..m).map(|i| self.cost(c, self.basic[i])).collect();
        self.fold_etas_rev(&mut u);
        let mut y = vec![0.0; m];
        for (i, &ui) in u.iter().enumerate() {
            if ui != 0.0 {
                for r in 0..m {
                    y[r] += ui * self.binv[i * m + r];
                }
            }
        }
        y
    }

    fn reduced(&self, c: &[f64], y: &[f64], j: usize) -> f64 {
        let mut d = self.cost(c, j);
        for &(r, v) in self.col(j) {
            d -= y[r] * v;
        }
        d
    }

    /// xb = B^-1 (b - N x_N), from scratch.
    fn recompute_xb(&mut self) {
        let m = self.sx.m;
        let mut bt = self.sx.rhs.clone();
        for j in 0..self.ncols() {
            if self.is_basic(j) {
                continue;
            }
            let v = self.nb_val(j);
            if v != 0.0 {
                for &(r, a) in self.col(j) {
                    bt[r] -= a * v;
                }
            }
        }
        let mut xb = std::mem::take(&mut self.xb);
        for (i, x) in xb.iter_mut().enumerate() {
            let mut s = 0.0;
            for r in 0..m {
                s += self.binv[i * m + r] * bt[r];
            }
            *x = s;
        }
        self.apply_etas(&mut xb);
        self.xb = xb;
    }

    fn is_basic(&self, j: usize) -> bool {
        if j < self.sx.total {
            self.in_basis[j]
        } else {
            self.basic.contains(&j)
        }
    }

    fn set_basic(&mut self, row: usize, j: usize) {
        let old = self.basic[row];
        if old != usize::MAX && old < self.sx.total {
            self.in_basis[old] = false;
        }
        self.basic[row] = j;
        if j < self.sx.total {
            self.in_basis[j] = true;
        }
    }

    /// Replace the basic column of `row` with `enter`; `w = ftran(enter)`.
    /// Product-form update: record one eta vector (O(m)) instead of
    /// eliminating a dense row of `B^-1` (O(m^2)); collapse the file when
    /// it grows long or the pivot magnitude signals drift.
    fn pivot_update(&mut self, row: usize, w: &[f64], enter: usize) {
        let m = self.sx.m;
        let inv = 1.0 / w[row];
        let mut eta = vec![0.0; m];
        for (i, &wi) in w.iter().enumerate() {
            if i != row && wi != 0.0 {
                eta[i] = -wi * inv;
            }
        }
        eta[row] = inv;
        self.etas.push((row, eta));
        self.eta_updates += 1;
        self.set_basic(row, enter);
        self.pivots += 1;
        if self.etas.len() >= REFACTOR_ETAS || inv.abs() > ETA_DRIFT {
            self.refactor();
        }
    }

    /// Collapse the eta file: re-invert the CURRENT basis from scratch
    /// and recompute the basic values (bounding incremental drift). When
    /// the factorization is numerically singular the (still-valid) eta
    /// representation is kept and the next pivot retries.
    fn refactor(&mut self) {
        if let Some(binv) = self.invert_current() {
            self.binv = binv;
            self.etas.clear();
            self.refactorizations += 1;
            self.recompute_xb();
        }
    }

    /// Dense inverse of the CURRENT basis (artificial columns included,
    /// unlike the free-function [`invert_basis`]); `None` when singular.
    fn invert_current(&self) -> Option<Vec<f64>> {
        invert_columns(self.sx.m, &self.basic, |b| self.col(b))
    }

    fn objective_at(&self, c: &[f64]) -> f64 {
        let mut obj = 0.0;
        for i in 0..self.sx.m {
            obj += self.cost(c, self.basic[i]) * self.xb[i];
        }
        for j in 0..self.ncols() {
            if !self.is_basic(j) {
                let cj = self.cost(c, j);
                if cj != 0.0 {
                    obj += cj * self.nb_val(j);
                }
            }
        }
        obj
    }

    /// Primal bounded-variable simplex on objective `c` from the current
    /// (primal-feasible) basis. Dantzig pricing with a Bland fallback past
    /// a burn-in to guarantee termination under degeneracy.
    fn primal(&mut self, c: &[f64]) -> Phase {
        let m = self.sx.m;
        let max_iters = self.max_iters();
        for iter in 0..max_iters {
            let y = self.duals(c);
            let bland = iter >= max_iters / 2;
            let mut enter: Option<(usize, f64)> = None; // (col, dir)
            let mut best_score = -EPS;
            for j in 0..self.ncols() {
                if self.is_basic(j) || self.ub[j] - self.lb[j] <= EPS {
                    continue; // basic or fixed columns never enter
                }
                let d = self.reduced(c, &y, j);
                let dir = if self.at_upper[j] { -1.0 } else { 1.0 };
                let score = d * dir; // improving iff < -EPS
                if score < -EPS {
                    if bland {
                        enter = Some((j, dir));
                        break;
                    }
                    if score < best_score {
                        best_score = score;
                        enter = Some((j, dir));
                    }
                }
            }
            let Some((j, dir)) = enter else {
                return Phase::Optimal(self.objective_at(c));
            };
            let w = self.ftran(j);
            // ratio test: x_j moves by t*dir (t >= 0); x_B -= t*dir*w
            let mut t_best = self.ub[j] - self.lb[j]; // bound-flip limit
            let mut leave: Option<usize> = None;
            let mut leave_to_upper = false;
            for i in 0..m {
                let delta = -dir * w[i]; // d(x_Bi)/dt
                let bi = self.basic[i];
                let (t, to_upper) = if delta < -EPS
                    && self.lb[bi] > f64::NEG_INFINITY
                {
                    ((self.xb[i] - self.lb[bi]) / (-delta), false)
                } else if delta > EPS && self.ub[bi] < f64::INFINITY {
                    ((self.ub[bi] - self.xb[i]) / delta, true)
                } else {
                    continue;
                };
                // Bland-style tie-break on basis index against cycling
                let take = match leave {
                    None => t < t_best + EPS,
                    Some(l) => {
                        t < t_best - EPS
                            || (t < t_best + EPS && bi < self.basic[l])
                    }
                };
                if take {
                    t_best = t.min(t_best);
                    leave = Some(i);
                    leave_to_upper = to_upper;
                }
            }
            if t_best.is_infinite() {
                return Phase::Unbounded;
            }
            let t = t_best.max(0.0);
            match leave {
                None => {
                    // bound flip: no basis change
                    for i in 0..m {
                        self.xb[i] -= t * dir * w[i];
                    }
                    self.at_upper[j] = !self.at_upper[j];
                }
                Some(r) => {
                    let enter_val = self.nb_val(j) + dir * t;
                    let lv = self.basic[r];
                    for i in 0..m {
                        if i != r {
                            self.xb[i] -= t * dir * w[i];
                        }
                    }
                    self.xb[r] = enter_val;
                    self.at_upper[lv] = leave_to_upper;
                    self.pivot_update(r, &w, j);
                }
            }
        }
        // Iteration cap: feasible but possibly suboptimal point.
        self.capped = true;
        log::warn!(
            "simplex hit the iteration cap ({} iters, m={} cols={}); \
             reporting the current feasible point",
            self.max_iters(), self.sx.m, self.ncols());
        Phase::Optimal(self.objective_at(c))
    }

    fn extract_x(&self) -> Vec<f64> {
        let mut x = vec![0.0; self.sx.n];
        for j in 0..self.sx.n {
            x[j] = self.nb_val(j);
        }
        for (i, &b) in self.basic.iter().enumerate() {
            if b < self.sx.n {
                x[b] = self.xb[i];
            }
        }
        x
    }

    fn snapshot(&self) -> Option<Basis> {
        if self.basic.iter().any(|&b| b >= self.sx.total) {
            return None; // redundant row kept an artificial basic
        }
        Some(Basis {
            basic: self.basic.clone(),
            at_upper: self.at_upper[..self.sx.total].to_vec(),
        })
    }

    fn finish(&self, result: LpResult, basis: Option<Basis>) -> Solved {
        Solved {
            result,
            basis,
            info: LpInfo {
                pivots: self.pivots,
                capped: self.capped,
                eta_updates: self.eta_updates,
                refactorizations: self.refactorizations,
            },
        }
    }

    // -- cold solve: artificial phase 1 + primal phase 2 -----------------

    fn solve_cold(&mut self) -> Solved {
        let (n, m, total) = (self.sx.n, self.sx.m, self.sx.total);
        for j in 0..total {
            if self.lb[j] > self.ub[j] + 1e-9 {
                return self.finish(LpResult::Infeasible, None);
            }
        }
        // nonbasic start: every column at its finite bound
        for j in 0..total {
            debug_assert!(
                self.lb[j].is_finite() || self.ub[j].is_finite(),
                "free variables are unsupported"
            );
            self.at_upper[j] = self.lb[j] == f64::NEG_INFINITY;
        }
        // residuals with every column nonbasic
        let mut resid = self.sx.rhs.clone();
        for j in 0..total {
            let v = self.nb_val(j);
            if v != 0.0 {
                for &(r, a) in self.col(j) {
                    resid[r] -= a * v;
                }
            }
        }
        // per row: slack basic when its start value is feasible, else an
        // artificial carries the residual through phase 1
        for i in 0..m {
            // if the slack were basic its value would absorb the residual
            let s_val = resid[i] + self.nb_val(n + i);
            if self.sx.slack_lb[i] - 1e-9 <= s_val
                && s_val <= self.sx.slack_ub[i] + 1e-9
            {
                self.set_basic(i, n + i);
                self.binv[i * m + i] = 1.0;
                self.xb[i] = s_val;
            } else {
                let sign = if s_val >= 0.0 { 1.0 } else { -1.0 };
                self.art.push((i, sign));
                let aj = total + self.art.len() - 1;
                self.lb.push(0.0);
                self.ub.push(f64::INFINITY);
                self.at_upper.push(false);
                self.set_basic(i, aj);
                self.binv[i * m + i] = sign;
                self.xb[i] = s_val.abs();
            }
        }
        if !self.art.is_empty() {
            let mut c1 = vec![0.0; self.ncols()];
            for k in total..self.ncols() {
                c1[k] = 1.0;
            }
            match self.primal(&c1) {
                Phase::Unbounded => {
                    return self.finish(LpResult::Infeasible, None)
                }
                Phase::Optimal(obj) => {
                    if obj > 1e-6 {
                        return self.finish(LpResult::Infeasible, None);
                    }
                }
            }
            // freeze artificials at zero, then pivot basic ones out where
            // the row allows it (degenerate swaps at value 0)
            for k in total..self.ncols() {
                self.ub[k] = 0.0;
            }
            for i in 0..m {
                if self.basic[i] < total {
                    continue;
                }
                let rho = self.btran_row(i);
                let mut entering = None;
                for j in 0..total {
                    if self.in_basis[j] {
                        continue;
                    }
                    let mut a = 0.0;
                    for &(r, v) in self.col(j) {
                        a += rho[r] * v;
                    }
                    if a.abs() > 1e-7 {
                        entering = Some(j);
                        break;
                    }
                }
                if let Some(j) = entering {
                    let w = self.ftran(j);
                    self.pivot_update(i, &w, j);
                    self.recompute_xb();
                }
                // else: redundant row; the artificial stays basic at 0 and
                // the final basis is not snapshot-able.
            }
        }
        let c = self.sx.c.clone();
        match self.primal(&c) {
            Phase::Unbounded => self.finish(LpResult::Unbounded, None),
            Phase::Optimal(objective) => {
                let x = self.extract_x();
                let basis = self.snapshot();
                self.finish(LpResult::Optimal { x, objective }, basis)
            }
        }
    }

    // -- warm solve: install basis, dual simplex, primal cleanup ---------

    fn solve_warm(&mut self, basis: &Basis) -> Option<Solved> {
        let (m, total) = (self.sx.m, self.sx.total);
        for j in 0..total {
            if self.lb[j] > self.ub[j] + 1e-9 {
                return Some(self.finish(LpResult::Infeasible, None));
            }
        }
        for (i, &b) in basis.basic.iter().enumerate() {
            self.set_basic(i, b);
        }
        self.at_upper.copy_from_slice(&basis.at_upper);
        // one refactorization per warm entry (m excludes bound rows, so
        // this stays small); every subsequent pivot is an O(m) eta update
        self.binv = invert_basis(self.sx, &self.basic)?;
        self.refactorizations += 1;
        // a nonbasic column must rest on a finite bound; bound changes can
        // have removed the side it sat on
        for j in 0..total {
            if self.in_basis[j] {
                continue;
            }
            if self.at_upper[j] && self.ub[j] == f64::INFINITY {
                if self.lb[j] == f64::NEG_INFINITY {
                    return None;
                }
                self.at_upper[j] = false;
            } else if !self.at_upper[j] && self.lb[j] == f64::NEG_INFINITY {
                if self.ub[j] == f64::INFINITY {
                    return None;
                }
                self.at_upper[j] = true;
            }
        }
        self.recompute_xb();
        let c = self.sx.c.clone();
        let max_iters = self.max_iters();
        for _ in 0..max_iters {
            // leaving: the basic variable with the largest bound violation
            let mut leave: Option<(usize, bool)> = None; // (row, below_lb)
            let mut viol = 1e-7;
            for i in 0..m {
                let bi = self.basic[i];
                if self.xb[i] < self.lb[bi] - viol {
                    viol = self.lb[bi] - self.xb[i];
                    leave = Some((i, true));
                } else if self.xb[i] > self.ub[bi] + viol {
                    viol = self.xb[i] - self.ub[bi];
                    leave = Some((i, false));
                }
            }
            let Some((r, below)) = leave else {
                // primal feasible; the primal pass certifies optimality
                // (usually zero pivots) and handles any dual-status drift
                return match self.primal(&c) {
                    Phase::Unbounded => {
                        Some(self.finish(LpResult::Unbounded, None))
                    }
                    Phase::Optimal(objective) => {
                        let x = self.extract_x();
                        let basis = self.snapshot();
                        Some(self.finish(
                            LpResult::Optimal { x, objective }, basis))
                    }
                };
            };
            let y = self.duals(&c);
            let rho = self.btran_row(r);
            // entering: dual ratio test |d_j| / |alpha_j| over columns
            // that can push x_Br back toward the violated bound
            let mut enter: Option<usize> = None;
            let mut best = f64::INFINITY;
            for j in 0..total {
                if self.in_basis[j] || self.ub[j] - self.lb[j] <= EPS {
                    continue;
                }
                let mut a = 0.0;
                for &(rr, v) in self.col(j) {
                    a += rho[rr] * v;
                }
                let eligible = if below {
                    (!self.at_upper[j] && a < -EPS)
                        || (self.at_upper[j] && a > EPS)
                } else {
                    (!self.at_upper[j] && a > EPS)
                        || (self.at_upper[j] && a < -EPS)
                };
                if !eligible {
                    continue;
                }
                let d = self.reduced(&c, &y, j);
                let ratio = d.abs() / a.abs();
                // strictly-better only: j ascends, so the first index wins
                // among (near-)ties — deterministic without a tie-break
                if enter.is_none() || ratio < best - EPS {
                    best = ratio.min(best);
                    enter = Some(j);
                }
            }
            let Some(j) = enter else {
                // the violated row maxes out over the whole bound box:
                // genuinely infeasible (no dual feasibility needed)
                return Some(self.finish(LpResult::Infeasible, None));
            };
            let w = self.ftran(j);
            if w[r].abs() <= EPS {
                return None; // numerically unusable pivot; cold-solve
            }
            let lv = self.basic[r];
            // incremental basic-value update (replaces the per-pivot
            // from-scratch recompute): x_j moves by t, x_B -= t*w, and
            // x_Br lands exactly on the violated bound side
            let beta = if below { self.lb[lv] } else { self.ub[lv] };
            let t = (self.xb[r] - beta) / w[r];
            let enter_val = self.nb_val(j) + t;
            for i in 0..m {
                if i != r {
                    self.xb[i] -= t * w[i];
                }
            }
            self.xb[r] = enter_val;
            self.at_upper[lv] = !below; // leaves at the violated bound side
            self.pivot_update(r, &w, j);
        }
        None // dual iteration cap: let the caller cold-solve
    }
}

/// Dense inverse of the basis matrix via Gauss-Jordan with partial
/// pivoting; `None` when singular. Artificial-free bases only (the warm
/// entry point); mid-solve refactorization uses `State::invert_current`,
/// which resolves artificial columns too.
fn invert_basis(sx: &Simplex, basic: &[usize]) -> Option<Vec<f64>> {
    invert_columns(sx.m, basic, |b| sx.cols[b].as_slice())
}

/// Gauss-Jordan inversion core over caller-resolved sparse columns.
fn invert_columns<'c>(
    m: usize,
    basic: &[usize],
    col_of: impl Fn(usize) -> &'c [(usize, f64)],
) -> Option<Vec<f64>> {
    // augmented [B | I], row-major with width 2m
    let w = 2 * m;
    let mut a = vec![0.0; m * w];
    for (i, &b) in basic.iter().enumerate() {
        for &(r, v) in col_of(b) {
            a[r * w + i] = v;
        }
    }
    for i in 0..m {
        a[i * w + m + i] = 1.0;
    }
    for col in 0..m {
        let mut p = None;
        let mut best = 1e-10;
        for i in col..m {
            if a[i * w + col].abs() > best {
                best = a[i * w + col].abs();
                p = Some(i);
            }
        }
        let p = p?;
        if p != col {
            for k in 0..w {
                a.swap(col * w + k, p * w + k);
            }
        }
        let pv = a[col * w + col];
        for k in 0..w {
            a[col * w + k] /= pv;
        }
        for i in 0..m {
            if i != col && a[i * w + col] != 0.0 {
                let f = a[i * w + col];
                for k in 0..w {
                    a[i * w + k] -= f * a[col * w + k];
                }
            }
        }
    }
    let mut inv = vec![0.0; m * m];
    for i in 0..m {
        inv[i * m..(i + 1) * m].copy_from_slice(&a[i * w + m..i * w + 2 * m]);
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} != {b}");
    }

    #[test]
    fn simple_maximization_as_min() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 (classic Dantzig ex.)
        // optimum (2,6) value 36 -> min form objective -36
        let mut lp = Lp::new(2);
        lp.set_obj(0, -3.0);
        lp.set_obj(1, -5.0);
        lp.add(vec![(0, 1.0)], Cmp::Le, 4.0);
        lp.add(vec![(1, 2.0)], Cmp::Le, 12.0);
        lp.add(vec![(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let res: LpResult = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, -36.0);
        assert_close(x[0], 2.0);
        assert_close(x[1], 6.0);
    }

    #[test]
    fn ge_and_eq_constraints() {
        // min x + 2y s.t. x + y = 10, x >= 3 -> all x: x=10, y=0, obj 10
        let mut lp = Lp::new(2);
        lp.set_obj(0, 1.0);
        lp.set_obj(1, 2.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Eq, 10.0);
        lp.bound_ge(0, 3.0);
        let res = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, 10.0);
        assert_close(x[0], 10.0);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new(1);
        lp.bound_ge(0, 5.0);
        lp.bound_le(0, 3.0);
        assert_eq!(solve(&lp), LpResult::Infeasible);
    }

    #[test]
    fn infeasible_rows_detected() {
        // bound conflicts expressed as ROWS (not variable bounds) must
        // still be caught — this exercises phase 1
        let mut lp = Lp::new(1);
        lp.add(vec![(0, 1.0)], Cmp::Ge, 5.0);
        lp.add(vec![(0, 1.0)], Cmp::Le, 3.0);
        assert_eq!(solve(&lp), LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new(1);
        lp.set_obj(0, -1.0); // min -x, x >= 0 unbounded below
        assert_eq!(solve(&lp), LpResult::Unbounded);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // multiple redundant constraints through the same vertex
        let mut lp = Lp::new(2);
        lp.set_obj(0, -1.0);
        lp.set_obj(1, -1.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 1.0);
        lp.add(vec![(0, 2.0), (1, 2.0)], Cmp::Le, 2.0);
        lp.add(vec![(0, 1.0)], Cmp::Le, 1.0);
        lp.add(vec![(1, 1.0)], Cmp::Le, 1.0);
        let (_, obj) = solve(&lp).optimal().expect("optimal");
        assert_close(obj, -1.0);
    }

    #[test]
    fn negative_rhs_handled() {
        // x - y <= -2  (i.e. y >= x + 2), min y -> x=0, y=2
        let mut lp = Lp::new(2);
        lp.set_obj(1, 1.0);
        lp.add(vec![(0, 1.0), (1, -1.0)], Cmp::Le, -2.0);
        let res = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, 2.0);
        assert_close(x[1], 2.0);
    }

    #[test]
    fn transportation_problem() {
        // 2 plants (cap 20, 30) -> 2 cities (demand 25, 25); costs
        // [[1,3],[2,1]]; optimum 55
        let mut lp = Lp::new(4); // x00 x01 x10 x11
        for (j, c) in [1.0, 3.0, 2.0, 1.0].iter().enumerate() {
            lp.set_obj(j, *c);
        }
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 20.0);
        lp.add(vec![(2, 1.0), (3, 1.0)], Cmp::Le, 30.0);
        lp.add(vec![(0, 1.0), (2, 1.0)], Cmp::Eq, 25.0);
        lp.add(vec![(1, 1.0), (3, 1.0)], Cmp::Eq, 25.0);
        let (_, obj) = solve(&lp).optimal().expect("optimal");
        assert_close(obj, 55.0);
    }

    #[test]
    fn variable_bounds_respected_without_rows() {
        // min -x - y s.t. x + y <= 10, 1 <= x <= 3, y <= 4 — all bounds
        // first-class (row count must stay 1)
        let mut lp = Lp::new(2);
        lp.set_obj(0, -1.0);
        lp.set_obj(1, -1.0);
        lp.set_bounds(0, 1.0, 3.0);
        lp.bound_le(1, 4.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 10.0);
        assert_eq!(lp.constraints.len(), 1);
        let res = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, -7.0);
        assert_close(x[0], 3.0);
        assert_close(x[1], 4.0);
    }

    #[test]
    fn cold_solve_returns_reusable_basis() {
        let mut lp = Lp::new(2);
        lp.set_obj(0, -3.0);
        lp.set_obj(1, -5.0);
        lp.bound_le(0, 4.0);
        lp.bound_le(1, 6.0);
        lp.add(vec![(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let sx = Simplex::new(&lp);
        let s = sx.solve_cold(&lp.lower, &lp.upper);
        let (_, obj) = s.result.optimal().expect("optimal");
        assert_close(obj, -36.0);
        let basis = s.basis.expect("basis available");
        // warm re-solve with identical bounds reproduces the optimum in
        // zero (or near-zero) extra pivots
        let warm = sx
            .solve_warm(&lp.lower, &lp.upper, &basis)
            .expect("basis reusable");
        let (_, wobj) = warm.result.optimal().expect("optimal");
        assert_close(wobj, obj);
        assert!(warm.info.pivots <= 1, "warm pivots {}", warm.info.pivots);
    }

    #[test]
    fn warm_resolve_after_bound_change_matches_cold() {
        // knapsack relaxation, then branch-style bound tightenings
        let mut lp = Lp::new(3);
        for (j, v) in [10.0, 13.0, 7.0].iter().enumerate() {
            lp.set_obj(j, -v);
            lp.bound_le(j, 1.0);
        }
        lp.add(vec![(0, 3.0), (1, 4.0), (2, 2.0)], Cmp::Le, 6.0);
        let sx = Simplex::new(&lp);
        let root = sx.solve_cold(&lp.lower, &lp.upper);
        let basis = root.basis.expect("root basis");
        for (var, lo, hi) in
            [(0, 0.0, 0.0), (0, 1.0, 1.0), (1, 0.0, 0.0), (2, 1.0, 1.0)]
        {
            let mut lower = lp.lower.clone();
            let mut upper = lp.upper.clone();
            lower[var] = lo;
            upper[var] = hi;
            let cold = sx.solve_cold(&lower, &upper);
            let warm = sx
                .solve_warm(&lower, &upper, &basis)
                .expect("warm resolve usable");
            match (&cold.result, &warm.result) {
                (
                    LpResult::Optimal { objective: a, .. },
                    LpResult::Optimal { objective: b, .. },
                ) => assert_close(*a, *b),
                (a, b) => assert_eq!(a, b),
            }
        }
    }

    #[test]
    fn warm_resolve_detects_infeasible_child() {
        let mut lp = Lp::new(2);
        lp.set_obj(0, 1.0);
        lp.bound_le(0, 5.0);
        lp.bound_le(1, 5.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 4.0);
        let sx = Simplex::new(&lp);
        let root = sx.solve_cold(&lp.lower, &lp.upper);
        let basis = root.basis.expect("root basis");
        // force x0 >= 3 and x1 >= 3: violates x0 + x1 <= 4
        let lower = vec![3.0, 3.0];
        let upper = vec![5.0, 5.0];
        let warm = sx.solve_warm(&lower, &upper, &basis).expect("usable");
        assert_eq!(warm.result, LpResult::Infeasible);
        let cold = sx.solve_cold(&lower, &upper);
        assert_eq!(cold.result, LpResult::Infeasible);
    }

    #[test]
    fn pivot_counts_are_reported() {
        let mut lp = Lp::new(2);
        lp.set_obj(0, -1.0);
        lp.set_obj(1, -2.0);
        lp.bound_le(0, 1.0);
        lp.bound_le(1, 1.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 1.5);
        let (res, info) = solve_with_info(&lp);
        assert!(res.optimal().is_some());
        assert!(!info.capped);
        // bounded 2-var LP: a few pivots/flips at most
        assert!(info.pivots <= 6, "pivots {}", info.pivots);
    }

    #[test]
    fn eta_updates_track_pivots_on_cold_solves() {
        // every basis change records exactly one product-form eta; a
        // short cold solve never reaches the refactorization threshold
        let mut lp = Lp::new(4);
        for (j, c) in [1.0, 3.0, 2.0, 1.0].iter().enumerate() {
            lp.set_obj(j, *c);
        }
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 20.0);
        lp.add(vec![(2, 1.0), (3, 1.0)], Cmp::Le, 30.0);
        lp.add(vec![(0, 1.0), (2, 1.0)], Cmp::Eq, 25.0);
        lp.add(vec![(1, 1.0), (3, 1.0)], Cmp::Eq, 25.0);
        let (res, info) = solve_with_info(&lp);
        assert!(res.optimal().is_some());
        assert!(info.pivots > 0);
        assert_eq!(info.eta_updates, info.pivots);
        assert!(info.pivots < REFACTOR_ETAS);
        assert_eq!(info.refactorizations, 0);
    }

    #[test]
    fn drift_trigger_refactors_mid_solve() {
        // a 1e-7 pivot element records an eta spike of 1e7 > ETA_DRIFT,
        // which must collapse the file into a fresh factorization even
        // though the spike COUNT is nowhere near REFACTOR_ETAS
        let mut lp = Lp::new(1);
        lp.set_obj(0, -1.0);
        lp.bound_le(0, 1e9);
        lp.add(vec![(0, 1e-7)], Cmp::Le, 10.0);
        let (res, info) = solve_with_info(&lp);
        let (x, obj) = res.optimal().expect("solvable");
        assert!((x[0] - 1e8).abs() < 1.0, "x0 {}", x[0]);
        assert!((obj + 1e8).abs() < 1.0, "obj {obj}");
        assert!(info.pivots < REFACTOR_ETAS);
        assert!(info.refactorizations >= 1,
                "tiny pivot never tripped the drift refactorization");
    }

    #[test]
    fn warm_solve_counts_one_refactorization() {
        let mut lp = Lp::new(3);
        for (j, v) in [10.0, 13.0, 7.0].iter().enumerate() {
            lp.set_obj(j, -v);
            lp.bound_le(j, 1.0);
        }
        lp.add(vec![(0, 3.0), (1, 4.0), (2, 2.0)], Cmp::Le, 6.0);
        let sx = Simplex::new(&lp);
        let root = sx.solve_cold(&lp.lower, &lp.upper);
        assert_eq!(root.info.refactorizations, 0);
        let basis = root.basis.expect("root basis");
        let mut upper = lp.upper.clone();
        upper[1] = 0.0;
        let warm = sx.solve_warm(&lp.lower, &upper, &basis).expect("usable");
        // the warm entry refactors once; pivots ride the eta file
        assert_eq!(warm.info.refactorizations, 1);
        assert_eq!(warm.info.eta_updates, warm.info.pivots);
        assert!(warm.result.optimal().is_some());
    }

    #[test]
    fn identity_remap_round_trips_through_solve_warm() {
        let mut lp = Lp::new(2);
        lp.set_obj(0, -3.0);
        lp.set_obj(1, -5.0);
        lp.bound_le(0, 4.0);
        lp.bound_le(1, 6.0);
        lp.add(vec![(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let sx = Simplex::new(&lp);
        let cold = sx.solve_cold(&lp.lower, &lp.upper);
        let basis = cold.basis.expect("basis");
        let row_from: Vec<Option<usize>> = (0..1).map(Some).collect();
        let col_to: Vec<Option<usize>> = (0..2).map(Some).collect();
        let mapped = basis.remap(&row_from, &col_to, 2, 2);
        assert_eq!(mapped, basis);
        let warm = sx
            .solve_warm(&lp.lower, &lp.upper, &mapped)
            .expect("identity remap reusable");
        let (_, wobj) = warm.result.optimal().expect("optimal");
        let (_, cobj) = cold.result.optimal().expect("optimal");
        assert_close(wobj, cobj);
    }

    #[test]
    fn row_and_column_append_remap_warm_solve_matches_cold() {
        // solve a 2-var/1-row problem, then grow it by one column and
        // one row (the arrival shape: new job = new column + new assign
        // row) and warm-start the bigger problem from the mapped basis
        let mut small = Lp::new(2);
        small.set_obj(0, -3.0);
        small.set_obj(1, -5.0);
        small.bound_le(0, 4.0);
        small.bound_le(1, 6.0);
        small.add(vec![(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let sxs = Simplex::new(&small);
        let root = sxs.solve_cold(&small.lower, &small.upper);
        let basis = root.basis.expect("basis");

        let mut big = Lp::new(3);
        big.set_obj(0, -3.0);
        big.set_obj(1, -5.0);
        big.set_obj(2, -4.0);
        big.bound_le(0, 4.0);
        big.bound_le(1, 6.0);
        big.bound_le(2, 3.0);
        big.add(vec![(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        big.add(vec![(2, 2.0)], Cmp::Le, 4.0);
        let sxb = Simplex::new(&big);

        let row_from = vec![Some(0), None];
        let col_to = vec![Some(0), Some(1)];
        let mapped = basis.remap(&row_from, &col_to, 2, 3);
        assert_eq!(mapped.basic.len(), 2);
        assert_eq!(mapped.at_upper.len(), 5);
        // the fresh row starts on its own slack
        assert_eq!(mapped.basic[1], 3 + 1);
        let warm = sxb
            .solve_warm(&big.lower, &big.upper, &mapped)
            .expect("mapped basis reusable");
        let cold = sxb.solve_cold(&big.lower, &big.upper);
        let (_, wobj) = warm.result.optimal().expect("optimal");
        let (_, cobj) = cold.result.optimal().expect("optimal");
        assert_close(wobj, cobj);
    }

    #[test]
    fn deletion_remap_degrades_to_own_slack_and_stays_usable() {
        // departure shape: drop a column and its row; whatever was
        // basic there must fall back to the surviving rows' own slacks
        let mut big = Lp::new(3);
        for (j, v) in [3.0, 5.0, 4.0].iter().enumerate() {
            big.set_obj(j, -v);
            big.bound_le(j, 4.0);
        }
        big.add(vec![(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        big.add(vec![(2, 2.0)], Cmp::Le, 4.0);
        let sxb = Simplex::new(&big);
        let root = sxb.solve_cold(&big.lower, &big.upper);
        let basis = root.basis.expect("basis");

        let mut small = Lp::new(2);
        small.set_obj(0, -3.0);
        small.set_obj(1, -5.0);
        small.bound_le(0, 4.0);
        small.bound_le(1, 4.0);
        small.add(vec![(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let sxs = Simplex::new(&small);

        // keep row 0 / cols 0-1; drop col 2 and row 1
        let mapped = basis.remap(&[Some(0)], &[Some(0), Some(1), None], 3, 2);
        assert_eq!(mapped.basic.len(), 1);
        assert_eq!(mapped.at_upper.len(), 3);
        // every basic entry indexes into the new problem
        assert!(mapped.basic.iter().all(|&b| b < 3));
        let cold = sxs.solve_cold(&small.lower, &small.upper);
        let (_, cobj) = cold.result.optimal().expect("optimal");
        // a mapped basis is allowed to be rejected (cold fallback), but
        // when accepted it must reach the same optimum
        if let Some(warm) = sxs.solve_warm(&small.lower, &small.upper, &mapped)
        {
            let (_, wobj) = warm.result.optimal().expect("optimal");
            assert_close(wobj, cobj);
        }
    }
}
