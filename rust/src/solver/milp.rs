//! Branch-and-bound MILP solver on top of `solver::lp` (Gurobi stand-in).
//!
//! Depth-first with best-bound node ordering, incumbent pruning with a
//! relative gap tolerance, most-fractional branching, and an optional
//! rounding heuristic to seed the incumbent. Saturn's joint scheduling
//! instances (<= ~1500 binaries) solve in well under a second; node and
//! time limits make behaviour predictable beyond that.

use std::collections::BinaryHeap;
use std::time::Instant;

use crate::solver::lp::{solve as lp_solve, Cmp, Lp, LpResult};

#[derive(Debug, Clone)]
pub struct MilpOptions {
    /// Relative optimality gap at which search stops.
    pub gap: f64,
    pub max_nodes: usize,
    pub time_limit_s: f64,
    /// Candidate solution seeding the incumbent (Gurobi's MIP start).
    /// Validated against the constraints before use; an infeasible warm
    /// start is silently ignored. Online re-solves pass the previous
    /// plan here so branch-and-bound prunes against it from node one.
    pub warm_start: Option<Vec<f64>>,
}

impl Default for MilpOptions {
    fn default() -> Self {
        MilpOptions {
            gap: 1e-6,
            max_nodes: 200_000,
            time_limit_s: 30.0,
            warm_start: None,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum MilpResult {
    /// Best integer-feasible solution found; `proved_optimal` is false if a
    /// node/time limit stopped the search first.
    Solved { x: Vec<f64>, objective: f64, proved_optimal: bool, nodes: usize },
    Infeasible,
    Unbounded,
}

impl MilpResult {
    pub fn solution(&self) -> Option<(&[f64], f64)> {
        match self {
            MilpResult::Solved { x, objective, .. } => Some((x, *objective)),
            _ => None,
        }
    }
}

struct Node {
    bound: f64,
    extra: Vec<(usize, Cmp, f64)>, // branching bounds (var, cmp, rhs)
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; we want the LOWEST bound first.
        other.bound.partial_cmp(&self.bound).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Minimize `lp` with the variables in `integer_vars` restricted to Z.
pub fn solve(lp: &Lp, integer_vars: &[usize], opts: &MilpOptions) -> MilpResult {
    let start = Instant::now();
    let root = relax_with(lp, &[]);
    let root_bound = match root {
        LpResult::Infeasible => return MilpResult::Infeasible,
        LpResult::Unbounded => return MilpResult::Unbounded,
        LpResult::Optimal { objective, .. } => objective,
    };

    let mut heap = BinaryHeap::new();
    heap.push(Node { bound: root_bound, extra: Vec::new() });

    let mut incumbent: Option<(Vec<f64>, f64)> =
        opts.warm_start.as_ref().and_then(|ws| {
            let x = round_ints(ws.clone(), integer_vars);
            warm_objective(lp, &x).map(|obj| (x, obj))
        });
    let mut nodes = 0usize;
    let mut exhausted = true;

    while let Some(node) = heap.pop() {
        if nodes >= opts.max_nodes || start.elapsed().as_secs_f64() > opts.time_limit_s {
            exhausted = false;
            break;
        }
        nodes += 1;

        // bound pruning
        if let Some((_, best)) = &incumbent {
            if node.bound >= best - opts.gap * best.abs().max(1.0) {
                continue;
            }
        }

        let relaxed = relax_with(lp, &node.extra);
        let (x, obj) = match relaxed {
            LpResult::Optimal { x, objective } => (x, objective),
            _ => continue, // infeasible subtree (unbounded cannot appear
                           // after adding bounds if root was bounded)
        };
        if let Some((_, best)) = &incumbent {
            if obj >= best - opts.gap * best.abs().max(1.0) {
                continue;
            }
        }

        // find most fractional integer var
        let mut branch_var = None;
        let mut best_frac = 0.0;
        for &j in integer_vars {
            let f = (x[j] - x[j].round()).abs();
            if f > 1e-6 {
                let dist = (x[j].fract() - 0.5).abs();
                let score = 0.5 - dist; // closest to .5 wins
                if branch_var.is_none() || score > best_frac {
                    best_frac = score;
                    branch_var = Some(j);
                }
            }
        }

        match branch_var {
            None => {
                // integer feasible
                let better = incumbent
                    .as_ref()
                    .map(|(_, best)| obj < *best)
                    .unwrap_or(true);
                if better {
                    incumbent = Some((round_ints(x, integer_vars), obj));
                }
            }
            Some(j) => {
                let floor = x[j].floor();
                let mut left = node.extra.clone();
                left.push((j, Cmp::Le, floor));
                let mut right = node.extra;
                right.push((j, Cmp::Ge, floor + 1.0));
                heap.push(Node { bound: obj, extra: left });
                heap.push(Node { bound: obj, extra: right });
            }
        }
    }

    match incumbent {
        Some((x, objective)) => MilpResult::Solved {
            x,
            objective,
            proved_optimal: exhausted,
            nodes,
        },
        None => {
            if exhausted {
                MilpResult::Infeasible
            } else {
                // limits hit before any integer solution was found
                MilpResult::Infeasible
            }
        }
    }
}

/// Objective value of `x` if it satisfies every constraint of `lp` (the
/// integer restriction is the caller's concern — `x` arrives pre-rounded);
/// `None` when infeasible. Used to vet warm starts.
fn warm_objective(lp: &Lp, x: &[f64]) -> Option<f64> {
    if x.len() != lp.n {
        return None;
    }
    let tol = 1e-6;
    if x.iter().any(|&v| v < -tol) {
        return None;
    }
    for c in &lp.constraints {
        let lhs: f64 = c.coeffs.iter().map(|&(j, a)| a * x[j]).sum();
        let slack = tol * (1.0 + c.rhs.abs() + lhs.abs());
        let ok = match c.cmp {
            Cmp::Le => lhs <= c.rhs + slack,
            Cmp::Ge => lhs >= c.rhs - slack,
            Cmp::Eq => (lhs - c.rhs).abs() <= slack,
        };
        if !ok {
            return None;
        }
    }
    Some(x.iter().zip(&lp.objective).map(|(xi, ci)| xi * ci).sum())
}

fn relax_with(lp: &Lp, extra: &[(usize, Cmp, f64)]) -> LpResult {
    if extra.is_empty() {
        return lp_solve(lp);
    }
    let mut relaxed = lp.clone();
    for &(j, cmp, rhs) in extra {
        relaxed.add(vec![(j, 1.0)], cmp, rhs);
    }
    lp_solve(&relaxed)
}

fn round_ints(mut x: Vec<f64>, ints: &[usize]) -> Vec<f64> {
    for &j in ints {
        x[j] = x[j].round();
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-5, "{a} != {b}");
    }

    #[test]
    fn knapsack_small() {
        // max 10x0 + 13x1 + 7x2, weights 3,4,2 <= 6, x binary
        // best: x0+x2? 17, x1+x2: 20 (w=6). optimum 20.
        let mut lp = Lp::new(3);
        for (j, v) in [10.0, 13.0, 7.0].iter().enumerate() {
            lp.set_obj(j, -v);
            lp.bound_le(j, 1.0);
        }
        lp.add(vec![(0, 3.0), (1, 4.0), (2, 2.0)], Cmp::Le, 6.0);
        let res = solve(&lp, &[0, 1, 2], &MilpOptions::default());
        let (x, obj) = res.solution().expect("solved");
        assert_close(obj, -20.0);
        assert_close(x[1], 1.0);
        assert_close(x[2], 1.0);
    }

    #[test]
    fn integrality_matters() {
        // LP relaxation of: max x, 2x <= 3, x integer -> LP 1.5, MILP 1
        let mut lp = Lp::new(1);
        lp.set_obj(0, -1.0);
        lp.add(vec![(0, 2.0)], Cmp::Le, 3.0);
        let (x, obj) = solve(&lp, &[0], &MilpOptions::default())
            .solution()
            .map(|(x, o)| (x.to_vec(), o))
            .expect("solved");
        assert_close(obj, -1.0);
        assert_close(x[0], 1.0);
    }

    #[test]
    fn infeasible_integer() {
        // 0.4 <= x <= 0.6, x integer: LP feasible, MILP infeasible
        let mut lp = Lp::new(1);
        lp.bound_ge(0, 0.4);
        lp.bound_le(0, 0.6);
        assert_eq!(solve(&lp, &[0], &MilpOptions::default()), MilpResult::Infeasible);
    }

    #[test]
    fn mixed_integer_and_continuous() {
        // min y s.t. y >= 1.3 x, x >= 2 (x int), y continuous -> x=2, y=2.6
        let mut lp = Lp::new(2);
        lp.set_obj(1, 1.0);
        lp.add(vec![(1, 1.0), (0, -1.3)], Cmp::Ge, 0.0);
        lp.bound_ge(0, 2.0);
        let (x, obj) = solve(&lp, &[0], &MilpOptions::default())
            .solution()
            .map(|(x, o)| (x.to_vec(), o))
            .expect("solved");
        assert_close(obj, 2.6);
        assert_close(x[0], 2.0);
    }

    #[test]
    fn matches_bruteforce_on_random_knapsacks() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for _case in 0..25 {
            let n = 8;
            let values: Vec<f64> = (0..n).map(|_| rng.range(1, 30) as f64).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.range(1, 12) as f64).collect();
            let cap = rng.range(10, 40) as f64;

            // brute force over 2^n
            let mut best = 0.0f64;
            for mask in 0..(1u32 << n) {
                let (mut v, mut w) = (0.0, 0.0);
                for j in 0..n {
                    if mask & (1 << j) != 0 {
                        v += values[j];
                        w += weights[j];
                    }
                }
                if w <= cap {
                    best = best.max(v);
                }
            }

            let mut lp = Lp::new(n);
            for j in 0..n {
                lp.set_obj(j, -values[j]);
                lp.bound_le(j, 1.0);
            }
            lp.add(weights.iter().cloned().enumerate().collect(), Cmp::Le, cap);
            let ints: Vec<usize> = (0..n).collect();
            let (_, obj) = solve(&lp, &ints, &MilpOptions::default())
                .solution()
                .expect("solved");
            assert!((-obj - best).abs() < 1e-5, "milp {} vs brute {best}", -obj);
        }
    }

    fn knapsack_lp() -> Lp {
        // max 10x0 + 13x1 + 7x2, weights 3,4,2 <= 6, x binary; optimum 20
        let mut lp = Lp::new(3);
        for (j, v) in [10.0, 13.0, 7.0].iter().enumerate() {
            lp.set_obj(j, -v);
            lp.bound_le(j, 1.0);
        }
        lp.add(vec![(0, 3.0), (1, 4.0), (2, 2.0)], Cmp::Le, 6.0);
        lp
    }

    #[test]
    fn warm_start_preserves_optimum_and_prunes() {
        let lp = knapsack_lp();
        let ints = [0usize, 1, 2];
        let cold = solve(&lp, &ints, &MilpOptions::default());
        let MilpResult::Solved { objective: cold_obj, nodes: cold_nodes, .. } =
            cold
        else {
            panic!("cold solve failed");
        };
        let opts = MilpOptions {
            warm_start: Some(vec![0.0, 1.0, 1.0]), // the optimum itself
            ..Default::default()
        };
        let warm = solve(&lp, &ints, &opts);
        let MilpResult::Solved { objective, nodes, proved_optimal, .. } = warm
        else {
            panic!("warm solve failed");
        };
        assert_close(objective, cold_obj);
        assert!(proved_optimal);
        assert!(nodes <= cold_nodes,
                "warm explored {nodes} nodes vs cold {cold_nodes}");
    }

    #[test]
    fn suboptimal_warm_start_still_finds_optimum() {
        let lp = knapsack_lp();
        let opts = MilpOptions {
            warm_start: Some(vec![1.0, 0.0, 1.0]), // feasible, value 17
            ..Default::default()
        };
        let res = solve(&lp, &[0, 1, 2], &opts);
        let (x, obj) = res.solution().expect("solved");
        assert_close(obj, -20.0);
        assert_close(x[1], 1.0);
        assert_close(x[2], 1.0);
    }

    #[test]
    fn infeasible_warm_start_is_ignored() {
        let lp = knapsack_lp();
        let opts = MilpOptions {
            warm_start: Some(vec![1.0, 1.0, 1.0]), // weight 9 > 6
            ..Default::default()
        };
        let res = solve(&lp, &[0, 1, 2], &opts);
        let (_, obj) = res.solution().expect("solved");
        assert_close(obj, -20.0);
    }

    #[test]
    fn respects_node_limit() {
        let mut lp = Lp::new(6);
        for j in 0..6 {
            lp.set_obj(j, -((j + 1) as f64));
            lp.bound_le(j, 1.0);
        }
        lp.add((0..6).map(|j| (j, 1.7)).collect(), Cmp::Le, 5.0);
        let opts = MilpOptions { max_nodes: 2, ..Default::default() };
        // Must terminate quickly regardless of outcome.
        let _ = solve(&lp, &(0..6).collect::<Vec<_>>(), &opts);
    }
}
