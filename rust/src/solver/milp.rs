//! Branch-and-bound MILP solver on top of `solver::lp` (Gurobi stand-in).
//!
//! Rebuilt around the bounded-variable revised simplex:
//!
//!  * **Bound branching, zero cloning.** A node is just the list of
//!    `(var, lb, ub)` overrides along its path; the constraint matrix is
//!    factorized once ([`Simplex`]) and every node re-solves it under its
//!    own bounds. The seed cloned the whole LP and appended bound *rows*
//!    per node.
//!  * **Warm-basis child solves.** Each node re-solves from its parent's
//!    final [`Basis`] via the dual simplex — a single bound changed, so a
//!    handful of pivots suffice. `MilpStats` reports the hit rate.
//!  * **Pseudo-cost branching + best-bound node order.** Per-variable
//!    up/down degradation estimates pick the branch variable; the
//!    frontier is explored lowest-bound-first and the final
//!    incumbent/bound gap is reported.
//!  * **Deterministic sibling parallelism.** The frontier is processed
//!    in fixed-size batches; batch LPs can be evaluated on
//!    `util::threadpool::scope_map` worker threads, but batch
//!    composition and the merge order never depend on `threads`, so the
//!    incumbent (and node count) are identical for every thread count.
//!
//! `MilpEngine::DenseReference` preserves the seed algorithm (dense
//! tableau, bounds-as-rows, cold solve per node) as an oracle and perf
//! baseline; `tests/prop_solver.rs` holds the engines to identical
//! objectives.

use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::Instant;

use crate::obs::trace::Tracer;
use crate::solver::dense;
use crate::solver::lp::{Basis, Lp, LpResult, Simplex, Solved};
use crate::util::json::Json;
use crate::util::threadpool::scope_map;

/// Nodes per frontier batch. Fixed (NOT derived from `threads`) so that
/// search order, node counts and the incumbent are thread-count
/// independent.
const BATCH: usize = 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MilpEngine {
    /// Revised simplex + warm-basis dual re-solves (default).
    Revised,
    /// The seed path: dense tableau rebuilt from scratch per node with
    /// branching bounds materialized as rows. Kept as oracle/baseline.
    DenseReference,
}

#[derive(Debug, Clone)]
pub struct MilpOptions {
    /// Relative optimality gap at which search stops.
    pub gap: f64,
    pub max_nodes: usize,
    pub time_limit_s: f64,
    /// Candidate solution seeding the incumbent (Gurobi's MIP start).
    /// Validated against constraints and bounds before use; an infeasible
    /// warm start is silently ignored. Online re-solves pass the previous
    /// plan here so branch-and-bound prunes against it from node one.
    pub warm_start: Option<Vec<f64>>,
    /// Worker threads for sibling-subtree LP evaluation (1 = serial).
    /// Any value returns bit-identical results; >1 only changes wall
    /// time.
    pub threads: usize,
    pub engine: MilpEngine,
    /// Root-node strong branching: score the top-k pseudo-cost
    /// candidates by their ACTUAL dual-simplex child bounds before the
    /// first branch, and seed the pseudo-costs with the observed
    /// degradations. 0 (the default) disables — the root then branches
    /// on the product rule's 1.0 defaults, i.e. most-fractional.
    /// Revised engine only; the seed reference ignores it.
    pub strong_branch_k: usize,
    /// Anytime wall-clock budget, milliseconds: when set, the search
    /// stops at `min(deadline_ms/1e3, time_limit_s)` and returns the
    /// best incumbent with its bound (`MilpStats::budget_hit` records
    /// that the EXPLICIT budget — not the default safety limit — is
    /// what fired). `None` (the default) keeps the historical limits.
    pub deadline_ms: Option<f64>,
    /// Anytime node budget: caps branch-and-bound nodes at
    /// `min(node_budget, max_nodes)`. Deterministic (unlike the wall
    /// deadline), so tests pin budget semantics with it. `None` off.
    pub node_budget: Option<usize>,
    /// Flight-recorder handle (`obs::trace`). Off by default; when
    /// enabled the revised engine emits `solver/lp_root` and
    /// `solver/bnb` spans. Never affects the search itself.
    pub trace: Tracer,
}

impl Default for MilpOptions {
    fn default() -> Self {
        MilpOptions {
            gap: 1e-6,
            max_nodes: 200_000,
            time_limit_s: 30.0,
            warm_start: None,
            threads: 1,
            engine: MilpEngine::Revised,
            strong_branch_k: 0,
            deadline_ms: None,
            node_budget: None,
            trace: Tracer::default(),
        }
    }
}

/// Search diagnostics (all engines).
#[derive(Debug, Clone, Default)]
pub struct MilpStats {
    /// Branch-and-bound nodes whose relaxation was solved.
    pub nodes: usize,
    /// Simplex pivots across every node LP.
    pub lp_pivots: usize,
    /// Node LPs re-solved from the parent basis via dual simplex.
    pub warm_hits: usize,
    /// Node LPs that fell back to a cold two-phase solve.
    pub warm_misses: usize,
    /// Node LPs that hit the simplex iteration cap (their objectives are
    /// NOT trusted as bounds — see `solve_revised`).
    pub capped_lps: usize,
    /// Product-form eta updates across every node LP (revised engine).
    pub eta_updates: usize,
    /// From-scratch basis refactorizations across every node LP.
    pub refactorizations: usize,
    /// Best lower bound on the optimum at termination.
    pub best_bound: f64,
    /// Relative incumbent/bound gap at termination (0 when proved).
    pub gap: f64,
    /// An EXPLICIT anytime budget (`MilpOptions::deadline_ms` /
    /// `node_budget`) stopped the search — distinct from the default
    /// `max_nodes`/`time_limit_s` safety valves, so callers can count
    /// budget-truncated solves separately.
    pub budget_hit: bool,
}

impl MilpStats {
    /// Fraction of node LPs served from a parent basis.
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_hits + self.warm_misses;
        if total == 0 {
            0.0
        } else {
            self.warm_hits as f64 / total as f64
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum MilpResult {
    /// Best integer-feasible solution found; `proved_optimal` is false if
    /// a node/time limit stopped the search first (compare `objective`
    /// against `best_bound` for the residual gap).
    Solved {
        x: Vec<f64>,
        objective: f64,
        proved_optimal: bool,
        nodes: usize,
        best_bound: f64,
    },
    /// The search tree was exhausted without an integer-feasible point:
    /// PROVED infeasible.
    Infeasible,
    Unbounded,
    /// A node/time limit fired before any incumbent was found. NOT a
    /// feasibility verdict — the seed conflated this with `Infeasible`,
    /// which made online re-solves treat timeouts as dead instances.
    LimitReached { best_bound: f64, nodes: usize },
}

impl MilpResult {
    pub fn solution(&self) -> Option<(&[f64], f64)> {
        match self {
            MilpResult::Solved { x, objective, .. } => Some((x, *objective)),
            _ => None,
        }
    }
}

/// Minimize `lp` with the variables in `integer_vars` restricted to Z.
pub fn solve(lp: &Lp, integer_vars: &[usize], opts: &MilpOptions) -> MilpResult {
    solve_with_stats(lp, integer_vars, opts).0
}

/// As [`solve`], also returning pivot/warm-start/bound diagnostics.
pub fn solve_with_stats(
    lp: &Lp,
    integer_vars: &[usize],
    opts: &MilpOptions,
) -> (MilpResult, MilpStats) {
    match opts.engine {
        MilpEngine::Revised => solve_revised(lp, integer_vars, opts),
        MilpEngine::DenseReference => solve_reference(lp, integer_vars, opts),
    }
}

// ---------------------------------------------------------------------------
// Revised engine
// ---------------------------------------------------------------------------

struct Node {
    bound: f64,
    seq: usize,
    /// Accumulated (var, lb, ub) overrides along the path from the root.
    over: Vec<(usize, f64, f64)>,
    /// Parent's final basis for the dual-simplex warm start.
    basis: Option<Arc<Basis>>,
    parent_obj: f64,
    /// (var, parent fractional part, up-branch) that created this node —
    /// feeds the pseudo-cost update once the node's LP is solved.
    branched: Option<(usize, f64, bool)>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound && self.seq == other.seq
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; we want the LOWEST bound first, and
        // FIFO (lowest seq) among ties for determinism.
        match other.bound.partial_cmp(&self.bound) {
            Some(std::cmp::Ordering::Equal) | None => other.seq.cmp(&self.seq),
            Some(o) => o,
        }
    }
}

/// Per-variable pseudo-costs: average objective degradation per unit of
/// fractionality, learned from solved child nodes.
struct PseudoCosts {
    up_sum: Vec<f64>,
    up_n: Vec<usize>,
    dn_sum: Vec<f64>,
    dn_n: Vec<usize>,
}

impl PseudoCosts {
    fn new(n: usize) -> Self {
        PseudoCosts {
            up_sum: vec![0.0; n],
            up_n: vec![0; n],
            dn_sum: vec![0.0; n],
            dn_n: vec![0; n],
        }
    }

    fn record(&mut self, j: usize, frac: f64, up: bool, degradation: f64) {
        let d = degradation.max(0.0);
        if up {
            self.up_sum[j] += d / (1.0 - frac).max(1e-6);
            self.up_n[j] += 1;
        } else {
            self.dn_sum[j] += d / frac.max(1e-6);
            self.dn_n[j] += 1;
        }
    }

    /// Product score (Achterberg's rule); unvisited directions default to
    /// 1.0, which degrades to most-fractional branching.
    fn score(&self, j: usize, frac: f64) -> f64 {
        let dn = if self.dn_n[j] > 0 {
            self.dn_sum[j] / self.dn_n[j] as f64
        } else {
            1.0
        };
        let up = if self.up_n[j] > 0 {
            self.up_sum[j] / self.up_n[j] as f64
        } else {
            1.0
        };
        (dn * frac).max(1e-6) * (up * (1.0 - frac)).max(1e-6)
    }
}

fn solve_revised(
    lp: &Lp,
    integer_vars: &[usize],
    opts: &MilpOptions,
) -> (MilpResult, MilpStats) {
    let start = Instant::now();
    let mut stats = MilpStats::default();
    let traced = opts.trace.is_enabled();
    if traced {
        opts.trace.begin(
            "solver",
            "lp_root",
            Json::obj(vec![
                ("rows", Json::num(lp.constraints.len() as f64)),
                ("vars", Json::num(lp.n as f64)),
            ]),
        );
    }
    let sx = Simplex::new(lp);
    let root = sx.solve_cold(&lp.lower, &lp.upper);
    stats.lp_pivots += root.info.pivots;
    stats.eta_updates += root.info.eta_updates;
    stats.refactorizations += root.info.refactorizations;
    if traced {
        opts.trace.end(
            "solver",
            "lp_root",
            Json::obj(vec![(
                "pivots",
                Json::num(root.info.pivots as f64),
            )]),
        );
    }
    let root_obj = match &root.result {
        LpResult::Infeasible => {
            stats.best_bound = f64::INFINITY;
            return (MilpResult::Infeasible, stats);
        }
        LpResult::Unbounded => {
            stats.best_bound = f64::NEG_INFINITY;
            return (MilpResult::Unbounded, stats);
        }
        LpResult::Optimal { objective, .. } => *objective,
    };

    let mut incumbent: Option<(Vec<f64>, f64)> =
        opts.warm_start.as_ref().and_then(|ws| {
            let x = round_ints(ws.clone(), integer_vars);
            feasible_objective(lp, &x).map(|obj| (x, obj))
        });
    let mut pc = PseudoCosts::new(lp.n);
    let mut heap = BinaryHeap::new();
    let mut seq = 0usize;
    // the root re-solves warm from its own basis (a no-op dual pass),
    // keeping the node loop uniform
    heap.push(Node {
        bound: root_obj,
        seq,
        over: Vec::new(),
        basis: root.basis.map(Arc::new),
        parent_obj: root_obj,
        branched: None,
    });

    if traced {
        opts.trace.begin("solver", "bnb", Json::obj(vec![]));
    }
    let (node_cap, time_cap) = effective_caps(opts);
    loop {
        if stats.nodes >= node_cap
            || start.elapsed().as_secs_f64() > time_cap
        {
            stats.budget_hit =
                budget_fired(opts, stats.nodes, start.elapsed().as_secs_f64());
            break;
        }
        // assemble a fixed-size batch of still-interesting nodes
        let mut batch: Vec<Node> = Vec::new();
        while batch.len() < BATCH {
            let Some(node) = heap.pop() else { break };
            if let Some((_, best)) = &incumbent {
                if node.bound >= best - opts.gap * best.abs().max(1.0) {
                    continue;
                }
            }
            batch.push(node);
        }
        if batch.is_empty() {
            break;
        }
        // per-node effective bounds
        let jobs: Vec<(Vec<f64>, Vec<f64>, Option<Arc<Basis>>)> = batch
            .iter()
            .map(|n| {
                let mut lower = lp.lower.clone();
                let mut upper = lp.upper.clone();
                for &(j, lo, hi) in &n.over {
                    lower[j] = lo;
                    upper[j] = hi;
                }
                (lower, upper, n.basis.clone())
            })
            .collect();
        // sibling-subtree LP evaluation — possibly on worker threads; the
        // output order matches `batch` either way
        let solved: Vec<(Solved, bool)> =
            scope_map(opts.threads, jobs, |(lower, upper, basis)| {
                match basis
                    .as_deref()
                    .and_then(|b| sx.solve_warm(&lower, &upper, b))
                {
                    Some(s) => (s, true),
                    None => (sx.solve_cold(&lower, &upper), false),
                }
            });
        // deterministic sequential merge, in batch order
        for (node, (s, was_warm)) in batch.into_iter().zip(solved) {
            stats.nodes += 1;
            stats.lp_pivots += s.info.pivots;
            stats.eta_updates += s.info.eta_updates;
            stats.refactorizations += s.info.refactorizations;
            if was_warm {
                stats.warm_hits += 1;
            } else {
                stats.warm_misses += 1;
            }
            let LpResult::Optimal { x, objective } = s.result else {
                continue; // infeasible subtree (unbounded cannot appear
                          // after tightening bounds if the root was bounded)
            };
            // A capped node LP is feasible but possibly SUBOPTIMAL: its
            // objective is an upper estimate, not a valid lower bound.
            // Fall back to the inherited parent bound for every fathoming
            // decision so the true optimum can never be pruned away.
            let capped = s.info.capped;
            if capped {
                stats.capped_lps += 1;
            }
            let node_bound = if capped { node.bound } else { objective };
            if let Some((j, frac, up)) = node.branched {
                if !capped {
                    pc.record(j, frac, up, objective - node.parent_obj);
                }
            }
            if let Some((_, best)) = &incumbent {
                if node_bound >= best - opts.gap * best.abs().max(1.0) {
                    continue;
                }
            }
            // pseudo-cost branching over fractional integer vars
            let mut branch: Option<(usize, f64, f64)> = None; // (j, score, frac)
            for &j in integer_vars {
                let f = x[j] - x[j].floor();
                if f > 1e-6 && f < 1.0 - 1e-6 {
                    let score = pc.score(j, f);
                    let take = match branch {
                        Some((_, s, _)) => score > s + 1e-12,
                        None => true,
                    };
                    if take {
                        branch = Some((j, score, f));
                    }
                }
            }
            // root-node strong branching: at the tree's single
            // all-defaults decision, replace the pseudo-cost pick with
            // the candidate whose actual child bounds degrade most
            let branch = match branch {
                Some(pick)
                    if node.over.is_empty()
                        && opts.strong_branch_k > 0
                        && !capped =>
                {
                    Some(strong_branch_root(&sx, lp, &x, integer_vars,
                                            s.basis.as_ref(), objective,
                                            opts.strong_branch_k, &mut pc,
                                            &mut stats)
                        .unwrap_or(pick))
                }
                other => other,
            };
            match branch {
                None => {
                    let better = match &incumbent {
                        Some((_, best)) => objective < *best,
                        None => true,
                    };
                    if better {
                        incumbent =
                            Some((round_ints(x, integer_vars), objective));
                    }
                }
                Some((j, _, frac)) => {
                    let floor = x[j].floor();
                    let basis = s.basis.map(Arc::new);
                    let (cur_lo, cur_hi) = node
                        .over
                        .iter()
                        .rev()
                        .find(|&&(v, _, _)| v == j)
                        .map(|&(_, lo, hi)| (lo, hi))
                        .unwrap_or((lp.lower[j], lp.upper[j]));
                    if floor >= cur_lo - 1e-9 {
                        let mut over = node.over.clone();
                        over.push((j, cur_lo, floor));
                        seq += 1;
                        heap.push(Node {
                            bound: node_bound,
                            seq,
                            over,
                            basis: basis.clone(),
                            parent_obj: objective,
                            branched: Some((j, frac, false)),
                        });
                    }
                    if floor + 1.0 <= cur_hi + 1e-9 {
                        let mut over = node.over.clone();
                        over.push((j, floor + 1.0, cur_hi));
                        seq += 1;
                        heap.push(Node {
                            bound: node_bound,
                            seq,
                            over,
                            basis,
                            parent_obj: objective,
                            branched: Some((j, frac, true)),
                        });
                    }
                }
            }
        }
    }

    if traced {
        opts.trace.end(
            "solver",
            "bnb",
            Json::obj(vec![
                ("nodes", Json::num(stats.nodes as f64)),
                ("warm_hits", Json::num(stats.warm_hits as f64)),
            ]),
        );
    }
    let proved = heap.is_empty();
    let frontier = heap.peek().map(|n| n.bound);
    let nodes = stats.nodes;
    match incumbent {
        Some((x, objective)) => {
            let best_bound = frontier.unwrap_or(objective).min(objective);
            stats.best_bound = best_bound;
            stats.gap =
                (objective - best_bound).abs() / objective.abs().max(1.0);
            (
                MilpResult::Solved {
                    x,
                    objective,
                    proved_optimal: proved,
                    nodes,
                    best_bound,
                },
                stats,
            )
        }
        None if proved => {
            stats.best_bound = f64::INFINITY;
            (MilpResult::Infeasible, stats)
        }
        None => {
            let best_bound = frontier.unwrap_or(root_obj);
            stats.best_bound = best_bound;
            stats.gap = f64::INFINITY;
            (MilpResult::LimitReached { best_bound, nodes }, stats)
        }
    }
}

/// Root-node strong branching (`MilpOptions::strong_branch_k`): rank
/// the fractional candidates by pseudo-cost product score (all-default
/// at the root, so effectively most-fractional), take the top k, and
/// for each solve BOTH child LPs from the root basis via the dual
/// simplex to observe the true bound degradations. The winner by
/// product rule is branched on, and every observed degradation seeds
/// the pseudo-costs so the rest of the tree branches on real data
/// instead of 1.0 defaults. Deterministic: candidates are ranked
/// (score desc, var asc) and evaluated in that order. Returns `None`
/// only if no candidate yielded a usable score (caller falls back to
/// the pseudo-cost pick).
#[allow(clippy::too_many_arguments)]
fn strong_branch_root(
    sx: &Simplex,
    lp: &Lp,
    x: &[f64],
    integer_vars: &[usize],
    basis: Option<&Basis>,
    parent_obj: f64,
    k: usize,
    pc: &mut PseudoCosts,
    stats: &mut MilpStats,
) -> Option<(usize, f64, f64)> {
    let mut cands: Vec<(usize, f64, f64)> = integer_vars
        .iter()
        .filter_map(|&j| {
            let f = x[j] - x[j].floor();
            if f > 1e-6 && f < 1.0 - 1e-6 {
                Some((j, pc.score(j, f), f))
            } else {
                None
            }
        })
        .collect();
    cands.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    cands.truncate(k);
    let mut best: Option<(usize, f64, f64)> = None;
    for &(j, _, frac) in &cands {
        let floor = x[j].floor();
        let mut deg = [0.0f64; 2];
        for (slot, up) in [(0usize, false), (1usize, true)] {
            let mut lower = lp.lower.clone();
            let mut upper = lp.upper.clone();
            if up {
                lower[j] = floor + 1.0;
            } else {
                upper[j] = floor;
            }
            if lower[j] > upper[j] {
                deg[slot] = 1e18; // empty child: branching here prunes
                continue;
            }
            let solved = match basis
                .and_then(|b| sx.solve_warm(&lower, &upper, b))
            {
                Some(s) => {
                    stats.warm_hits += 1;
                    s
                }
                None => {
                    stats.warm_misses += 1;
                    sx.solve_cold(&lower, &upper)
                }
            };
            stats.lp_pivots += solved.info.pivots;
            stats.eta_updates += solved.info.eta_updates;
            stats.refactorizations += solved.info.refactorizations;
            match solved.result {
                LpResult::Optimal { objective, .. } => {
                    if solved.info.capped {
                        // capped probe: objective untrusted, skip
                        stats.capped_lps += 1;
                    } else {
                        deg[slot] = (objective - parent_obj).max(0.0);
                        pc.record(j, frac, up, objective - parent_obj);
                    }
                }
                LpResult::Infeasible => deg[slot] = 1e18,
                LpResult::Unbounded => {}
            }
        }
        let score = (deg[0] * frac).max(1e-6)
            * (deg[1] * (1.0 - frac)).max(1e-6);
        let take = match best {
            Some((_, s, _)) => score > s + 1e-12,
            None => true,
        };
        if take {
            best = Some((j, score, frac));
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Seed reference engine
// ---------------------------------------------------------------------------

struct RefNode {
    bound: f64,
    seq: usize,
    over: Vec<(usize, f64, f64)>,
}

impl PartialEq for RefNode {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound && self.seq == other.seq
    }
}
impl Eq for RefNode {}
impl PartialOrd for RefNode {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RefNode {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        match other.bound.partial_cmp(&self.bound) {
            Some(std::cmp::Ordering::Equal) | None => other.seq.cmp(&self.seq),
            Some(o) => o,
        }
    }
}

/// The seed algorithm, preserved: most-fractional branching, one dense
/// tableau rebuilt from scratch per node (bounds land as rows), no warm
/// starts. Slow by design — it is the "before" in `bench_solver_scale`.
fn solve_reference(
    lp: &Lp,
    integer_vars: &[usize],
    opts: &MilpOptions,
) -> (MilpResult, MilpStats) {
    let start = Instant::now();
    let mut stats = MilpStats::default();
    let relax = |over: &[(usize, f64, f64)], stats: &mut MilpStats| {
        let (res, info) = if over.is_empty() {
            dense::solve_with_info(lp)
        } else {
            let mut relaxed = lp.clone();
            for &(j, lo, hi) in over {
                relaxed.lower[j] = lo;
                relaxed.upper[j] = hi;
            }
            dense::solve_with_info(&relaxed)
        };
        stats.lp_pivots += info.pivots;
        stats.warm_misses += 1;
        if info.capped {
            stats.capped_lps += 1;
        }
        (res, info.capped)
    };

    let root_bound = match relax(&[], &mut stats).0 {
        LpResult::Infeasible => {
            stats.best_bound = f64::INFINITY;
            return (MilpResult::Infeasible, stats);
        }
        LpResult::Unbounded => {
            stats.best_bound = f64::NEG_INFINITY;
            return (MilpResult::Unbounded, stats);
        }
        LpResult::Optimal { objective, .. } => objective,
    };

    let mut heap = BinaryHeap::new();
    let mut seq = 0usize;
    heap.push(RefNode { bound: root_bound, seq, over: Vec::new() });
    let mut incumbent: Option<(Vec<f64>, f64)> =
        opts.warm_start.as_ref().and_then(|ws| {
            let x = round_ints(ws.clone(), integer_vars);
            feasible_objective(lp, &x).map(|obj| (x, obj))
        });

    let (node_cap, time_cap) = effective_caps(opts);
    while let Some(node) = heap.pop() {
        if stats.nodes >= node_cap
            || start.elapsed().as_secs_f64() > time_cap
        {
            stats.budget_hit =
                budget_fired(opts, stats.nodes, start.elapsed().as_secs_f64());
            // push it back so the frontier bound survives for reporting
            heap.push(node);
            break;
        }
        if let Some((_, best)) = &incumbent {
            if node.bound >= best - opts.gap * best.abs().max(1.0) {
                continue;
            }
        }
        stats.nodes += 1;
        let (res, capped) = relax(&node.over, &mut stats);
        let LpResult::Optimal { x, objective } = res else {
            continue;
        };
        // capped LP objectives are not valid bounds (see solve_revised)
        let node_bound = if capped { node.bound } else { objective };
        if let Some((_, best)) = &incumbent {
            if node_bound >= best - opts.gap * best.abs().max(1.0) {
                continue;
            }
        }
        // most fractional integer var (the seed rule)
        let mut branch_var = None;
        let mut best_frac = 0.0;
        for &j in integer_vars {
            let f = (x[j] - x[j].round()).abs();
            if f > 1e-6 {
                let dist = (x[j].fract() - 0.5).abs();
                let score = 0.5 - dist; // closest to .5 wins
                if branch_var.is_none() || score > best_frac {
                    best_frac = score;
                    branch_var = Some(j);
                }
            }
        }
        match branch_var {
            None => {
                let better = incumbent
                    .as_ref()
                    .map(|(_, best)| objective < *best)
                    .unwrap_or(true);
                if better {
                    incumbent = Some((round_ints(x, integer_vars), objective));
                }
            }
            Some(j) => {
                let floor = x[j].floor();
                let (cur_lo, cur_hi) = node
                    .over
                    .iter()
                    .rev()
                    .find(|&&(v, _, _)| v == j)
                    .map(|&(_, lo, hi)| (lo, hi))
                    .unwrap_or((lp.lower[j], lp.upper[j]));
                if floor >= cur_lo - 1e-9 {
                    let mut over = node.over.clone();
                    over.push((j, cur_lo, floor));
                    seq += 1;
                    heap.push(RefNode { bound: node_bound, seq, over });
                }
                if floor + 1.0 <= cur_hi + 1e-9 {
                    let mut over = node.over.clone();
                    over.push((j, floor + 1.0, cur_hi));
                    seq += 1;
                    heap.push(RefNode { bound: node_bound, seq, over });
                }
            }
        }
    }

    let proved = heap.is_empty();
    let frontier = heap.peek().map(|n| n.bound);
    let nodes = stats.nodes;
    match incumbent {
        Some((x, objective)) => {
            let best_bound = frontier.unwrap_or(objective).min(objective);
            stats.best_bound = best_bound;
            stats.gap =
                (objective - best_bound).abs() / objective.abs().max(1.0);
            (
                MilpResult::Solved {
                    x,
                    objective,
                    proved_optimal: proved,
                    nodes,
                    best_bound,
                },
                stats,
            )
        }
        None if proved => {
            stats.best_bound = f64::INFINITY;
            (MilpResult::Infeasible, stats)
        }
        None => {
            let best_bound = frontier.unwrap_or(root_bound);
            stats.best_bound = best_bound;
            stats.gap = f64::INFINITY;
            (MilpResult::LimitReached { best_bound, nodes }, stats)
        }
    }
}

// ---------------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------------

/// Node/time caps with the anytime budgets folded in: the search stops
/// at whichever of (budget, default limit) is tighter.
fn effective_caps(opts: &MilpOptions) -> (usize, f64) {
    let node_cap = opts.max_nodes.min(opts.node_budget.unwrap_or(usize::MAX));
    let time_cap = opts
        .time_limit_s
        .min(opts.deadline_ms.map(|d| d / 1e3).unwrap_or(f64::INFINITY));
    (node_cap, time_cap)
}

/// Whether the stop that just fired is attributable to an EXPLICIT
/// anytime budget (vs the default `max_nodes`/`time_limit_s` valves).
fn budget_fired(opts: &MilpOptions, nodes: usize, elapsed_s: f64) -> bool {
    opts.node_budget.map(|b| nodes >= b).unwrap_or(false)
        || opts.deadline_ms.map(|d| elapsed_s > d / 1e3).unwrap_or(false)
}

/// Objective value of `x` if it satisfies every constraint AND bound of
/// `lp` (the integer restriction is the caller's concern — `x` arrives
/// pre-rounded); `None` when infeasible. Used to vet warm starts.
fn feasible_objective(lp: &Lp, x: &[f64]) -> Option<f64> {
    if x.len() != lp.n {
        return None;
    }
    let tol = 1e-6;
    for j in 0..lp.n {
        if x[j] < lp.lower[j] - tol || x[j] > lp.upper[j] + tol {
            return None;
        }
    }
    for c in &lp.constraints {
        let lhs: f64 = c.coeffs.iter().map(|&(j, a)| a * x[j]).sum();
        let slack = tol * (1.0 + c.rhs.abs() + lhs.abs());
        let ok = match c.cmp {
            crate::solver::lp::Cmp::Le => lhs <= c.rhs + slack,
            crate::solver::lp::Cmp::Ge => lhs >= c.rhs - slack,
            crate::solver::lp::Cmp::Eq => (lhs - c.rhs).abs() <= slack,
        };
        if !ok {
            return None;
        }
    }
    Some(x.iter().zip(&lp.objective).map(|(xi, ci)| xi * ci).sum())
}

fn round_ints(mut x: Vec<f64>, ints: &[usize]) -> Vec<f64> {
    for &j in ints {
        x[j] = x[j].round();
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::lp::Cmp;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-5, "{a} != {b}");
    }

    #[test]
    fn knapsack_small() {
        // max 10x0 + 13x1 + 7x2, weights 3,4,2 <= 6, x binary
        // best: x0+x2? 17, x1+x2: 20 (w=6). optimum 20.
        let mut lp = Lp::new(3);
        for (j, v) in [10.0, 13.0, 7.0].iter().enumerate() {
            lp.set_obj(j, -v);
            lp.bound_le(j, 1.0);
        }
        lp.add(vec![(0, 3.0), (1, 4.0), (2, 2.0)], Cmp::Le, 6.0);
        let res = solve(&lp, &[0, 1, 2], &MilpOptions::default());
        let (x, obj) = res.solution().expect("solved");
        assert_close(obj, -20.0);
        assert_close(x[1], 1.0);
        assert_close(x[2], 1.0);
    }

    #[test]
    fn integrality_matters() {
        // LP relaxation of: max x, 2x <= 3, x integer -> LP 1.5, MILP 1
        let mut lp = Lp::new(1);
        lp.set_obj(0, -1.0);
        lp.add(vec![(0, 2.0)], Cmp::Le, 3.0);
        let (x, obj) = solve(&lp, &[0], &MilpOptions::default())
            .solution()
            .map(|(x, o)| (x.to_vec(), o))
            .expect("solved");
        assert_close(obj, -1.0);
        assert_close(x[0], 1.0);
    }

    #[test]
    fn infeasible_integer() {
        // 0.4 <= x <= 0.6, x integer: LP feasible, MILP infeasible
        let mut lp = Lp::new(1);
        lp.bound_ge(0, 0.4);
        lp.bound_le(0, 0.6);
        assert_eq!(
            solve(&lp, &[0], &MilpOptions::default()),
            MilpResult::Infeasible
        );
    }

    #[test]
    fn mixed_integer_and_continuous() {
        // min y s.t. y >= 1.3 x, x >= 2 (x int), y continuous -> x=2, y=2.6
        let mut lp = Lp::new(2);
        lp.set_obj(1, 1.0);
        lp.add(vec![(1, 1.0), (0, -1.3)], Cmp::Ge, 0.0);
        lp.bound_ge(0, 2.0);
        let (x, obj) = solve(&lp, &[0], &MilpOptions::default())
            .solution()
            .map(|(x, o)| (x.to_vec(), o))
            .expect("solved");
        assert_close(obj, 2.6);
        assert_close(x[0], 2.0);
    }

    #[test]
    fn matches_bruteforce_on_random_knapsacks() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for _case in 0..25 {
            let n = 8;
            let values: Vec<f64> =
                (0..n).map(|_| rng.range(1, 30) as f64).collect();
            let weights: Vec<f64> =
                (0..n).map(|_| rng.range(1, 12) as f64).collect();
            let cap = rng.range(10, 40) as f64;

            // brute force over 2^n
            let mut best = 0.0f64;
            for mask in 0..(1u32 << n) {
                let (mut v, mut w) = (0.0, 0.0);
                for j in 0..n {
                    if mask & (1 << j) != 0 {
                        v += values[j];
                        w += weights[j];
                    }
                }
                if w <= cap {
                    best = best.max(v);
                }
            }

            let mut lp = Lp::new(n);
            for j in 0..n {
                lp.set_obj(j, -values[j]);
                lp.bound_le(j, 1.0);
            }
            lp.add(weights.iter().cloned().enumerate().collect(), Cmp::Le, cap);
            let ints: Vec<usize> = (0..n).collect();
            let (_, obj) = solve(&lp, &ints, &MilpOptions::default())
                .solution()
                .expect("solved");
            assert!((-obj - best).abs() < 1e-5, "milp {} vs brute {best}", -obj);
        }
    }

    fn knapsack_lp() -> Lp {
        // max 10x0 + 13x1 + 7x2, weights 3,4,2 <= 6, x binary; optimum 20
        let mut lp = Lp::new(3);
        for (j, v) in [10.0, 13.0, 7.0].iter().enumerate() {
            lp.set_obj(j, -v);
            lp.bound_le(j, 1.0);
        }
        lp.add(vec![(0, 3.0), (1, 4.0), (2, 2.0)], Cmp::Le, 6.0);
        lp
    }

    #[test]
    fn warm_start_preserves_optimum_and_prunes() {
        let lp = knapsack_lp();
        let ints = [0usize, 1, 2];
        let cold = solve(&lp, &ints, &MilpOptions::default());
        let MilpResult::Solved { objective: cold_obj, nodes: cold_nodes, .. } =
            cold
        else {
            panic!("cold solve failed");
        };
        let opts = MilpOptions {
            warm_start: Some(vec![0.0, 1.0, 1.0]), // the optimum itself
            ..Default::default()
        };
        let warm = solve(&lp, &ints, &opts);
        let MilpResult::Solved { objective, nodes, proved_optimal, .. } = warm
        else {
            panic!("warm solve failed");
        };
        assert_close(objective, cold_obj);
        assert!(proved_optimal);
        assert!(nodes <= cold_nodes,
                "warm explored {nodes} nodes vs cold {cold_nodes}");
    }

    #[test]
    fn suboptimal_warm_start_still_finds_optimum() {
        let lp = knapsack_lp();
        let opts = MilpOptions {
            warm_start: Some(vec![1.0, 0.0, 1.0]), // feasible, value 17
            ..Default::default()
        };
        let res = solve(&lp, &[0, 1, 2], &opts);
        let (x, obj) = res.solution().expect("solved");
        assert_close(obj, -20.0);
        assert_close(x[1], 1.0);
        assert_close(x[2], 1.0);
    }

    #[test]
    fn infeasible_warm_start_is_ignored() {
        let lp = knapsack_lp();
        let opts = MilpOptions {
            warm_start: Some(vec![1.0, 1.0, 1.0]), // weight 9 > 6
            ..Default::default()
        };
        let res = solve(&lp, &[0, 1, 2], &opts);
        let (_, obj) = res.solution().expect("solved");
        assert_close(obj, -20.0);
    }

    #[test]
    fn respects_node_limit() {
        let mut lp = Lp::new(6);
        for j in 0..6 {
            lp.set_obj(j, -((j + 1) as f64));
            lp.bound_le(j, 1.0);
        }
        lp.add((0..6).map(|j| (j, 1.7)).collect(), Cmp::Le, 5.0);
        let opts = MilpOptions { max_nodes: 2, ..Default::default() };
        // Must terminate quickly regardless of outcome.
        let _ = solve(&lp, &(0..6).collect::<Vec<_>>(), &opts);
    }

    #[test]
    fn limit_reached_is_distinct_from_infeasible() {
        // limits hit before any incumbent -> LimitReached, NOT Infeasible
        let lp = knapsack_lp();
        let opts = MilpOptions { max_nodes: 0, ..Default::default() };
        match solve(&lp, &[0, 1, 2], &opts) {
            MilpResult::LimitReached { best_bound, nodes } => {
                assert_eq!(nodes, 0);
                assert!(best_bound <= -20.0 + 1e-6,
                        "bound {best_bound} above the optimum");
            }
            other => panic!("expected LimitReached, got {other:?}"),
        }
        // a PROVED infeasible instance still reports Infeasible
        let mut bad = Lp::new(1);
        bad.bound_ge(0, 0.4);
        bad.bound_le(0, 0.6);
        assert_eq!(
            solve(&bad, &[0], &MilpOptions::default()),
            MilpResult::Infeasible
        );
    }

    #[test]
    fn engines_agree_on_random_knapsacks() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(321);
        for _case in 0..10 {
            let n = 6;
            let mut lp = Lp::new(n);
            for j in 0..n {
                lp.set_obj(j, -(rng.range(1, 20) as f64));
                lp.bound_le(j, 1.0);
            }
            let weights: Vec<(usize, f64)> =
                (0..n).map(|j| (j, rng.range(1, 9) as f64)).collect();
            lp.add(weights, Cmp::Le, rng.range(6, 25) as f64);
            let ints: Vec<usize> = (0..n).collect();
            let revised = solve(&lp, &ints, &MilpOptions::default());
            let reference = solve(&lp, &ints, &MilpOptions {
                engine: MilpEngine::DenseReference,
                ..Default::default()
            });
            let (_, a) = revised.solution().expect("revised solved");
            let (_, b) = reference.solution().expect("reference solved");
            assert_close(a, b);
        }
    }

    #[test]
    fn thread_count_does_not_change_the_answer() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(555);
        let n = 10;
        let mut lp = Lp::new(n);
        for j in 0..n {
            lp.set_obj(j, -(rng.range(1, 25) as f64));
            lp.bound_le(j, 1.0);
        }
        lp.add((0..n).map(|j| (j, rng.range(1, 9) as f64)).collect(),
               Cmp::Le, 18.0);
        let ints: Vec<usize> = (0..n).collect();
        let base = solve_with_stats(&lp, &ints, &MilpOptions::default());
        for threads in [2usize, 4] {
            let par = solve_with_stats(&lp, &ints, &MilpOptions {
                threads,
                ..Default::default()
            });
            assert_eq!(base.0, par.0, "threads={threads}");
            assert_eq!(base.1.nodes, par.1.nodes, "threads={threads}");
        }
    }

    #[test]
    fn strong_branching_preserves_the_optimum() {
        let lp = knapsack_lp();
        let ints = [0usize, 1, 2];
        let base = solve(&lp, &ints, &MilpOptions::default());
        let strong = solve(&lp, &ints, &MilpOptions {
            strong_branch_k: 3,
            ..Default::default()
        });
        let (_, a) = base.solution().expect("base solved");
        let (_, b) = strong.solution().expect("strong solved");
        assert_close(a, b);
    }

    #[test]
    fn strong_branching_is_deterministic() {
        let lp = knapsack_lp();
        let ints = [0usize, 1, 2];
        let opts =
            MilpOptions { strong_branch_k: 2, ..Default::default() };
        let a = solve_with_stats(&lp, &ints, &opts);
        let b = solve_with_stats(&lp, &ints, &opts);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1.nodes, b.1.nodes);
        assert_eq!(a.1.lp_pivots, b.1.lp_pivots);
    }

    #[test]
    fn warm_basis_hits_are_reported() {
        // any instance that branches must re-solve children from the
        // parent basis (plus the uniform warm root re-solve)
        let lp = knapsack_lp();
        let (res, stats) =
            solve_with_stats(&lp, &[0, 1, 2], &MilpOptions::default());
        assert!(res.solution().is_some());
        assert!(stats.warm_hits > 0, "no warm-basis node solves");
        assert!(stats.warm_hit_rate() > 0.0);
        assert!(stats.lp_pivots > 0);
    }

    #[test]
    fn node_budget_truncates_and_reports_budget_hit() {
        let lp = knapsack_lp();
        let ints = [0usize, 1, 2];
        let (res, stats) = solve_with_stats(&lp, &ints, &MilpOptions {
            node_budget: Some(0),
            ..Default::default()
        });
        assert!(stats.budget_hit, "explicit node budget did not register");
        match res {
            MilpResult::LimitReached { nodes, .. } => assert_eq!(nodes, 0),
            other => panic!("expected LimitReached, got {other:?}"),
        }
        // the default limits alone never set the budget flag
        let (_, s2) = solve_with_stats(&lp, &ints, &MilpOptions {
            max_nodes: 0,
            ..Default::default()
        });
        assert!(!s2.budget_hit, "default max_nodes flagged as budget");
    }

    #[test]
    fn exhausted_budget_keeps_the_warm_incumbent() {
        // anytime contract: with a vetted warm start, a zero budget
        // still returns that incumbent (never worse than the seed)
        let lp = knapsack_lp();
        let (res, stats) = solve_with_stats(&lp, &[0, 1, 2], &MilpOptions {
            node_budget: Some(0),
            warm_start: Some(vec![1.0, 0.0, 1.0]), // feasible, value 17
            ..Default::default()
        });
        assert!(stats.budget_hit);
        let MilpResult::Solved { objective, proved_optimal, best_bound, .. } =
            res
        else {
            panic!("warm incumbent lost under a zero budget");
        };
        assert!(!proved_optimal);
        assert_close(objective, -17.0);
        assert!(best_bound <= objective + 1e-9);
    }

    #[test]
    fn generous_budget_changes_nothing() {
        let lp = knapsack_lp();
        let ints = [0usize, 1, 2];
        let base = solve_with_stats(&lp, &ints, &MilpOptions::default());
        let budgeted = solve_with_stats(&lp, &ints, &MilpOptions {
            node_budget: Some(1_000_000),
            deadline_ms: Some(3600.0 * 1e3),
            ..Default::default()
        });
        assert_eq!(base.0, budgeted.0);
        assert_eq!(base.1.nodes, budgeted.1.nodes);
        assert!(!budgeted.1.budget_hit);
    }

    #[test]
    fn reference_engine_honors_the_node_budget() {
        let lp = knapsack_lp();
        let (res, stats) = solve_with_stats(&lp, &[0, 1, 2], &MilpOptions {
            engine: MilpEngine::DenseReference,
            node_budget: Some(0),
            ..Default::default()
        });
        assert!(stats.budget_hit);
        assert!(matches!(res, MilpResult::LimitReached { .. }));
    }

    #[test]
    fn best_bound_closes_when_proved() {
        let lp = knapsack_lp();
        let (res, stats) =
            solve_with_stats(&lp, &[0, 1, 2], &MilpOptions::default());
        let MilpResult::Solved { objective, proved_optimal, best_bound, .. } =
            res
        else {
            panic!("expected solved");
        };
        assert!(proved_optimal);
        assert!(best_bound <= objective + 1e-9);
        assert!(stats.gap < 0.01, "gap {}", stats.gap);
    }
}
