//! Optimization substrates: LP simplex + MILP branch-and-bound.
//!
//! The paper formulates joint (parallelism, allocation, schedule)
//! selection as an MILP and solves it with Gurobi; this module is the
//! open replacement. `lp` is the production bounded-variable revised
//! simplex (sparse columns, basis warm starts, dual-simplex re-solves);
//! `dense` keeps the seed two-phase dense tableau as a reference oracle
//! and perf baseline; `milp` runs warm-started branch-and-bound on top.
//! `saturn::solver` builds the actual formulation.

pub mod dense;
pub mod lp;
pub mod milp;
