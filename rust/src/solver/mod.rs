//! Optimization substrates: LP simplex + MILP branch-and-bound.
//!
//! The paper formulates joint (parallelism, allocation, schedule) selection
//! as an MILP and solves it with Gurobi; this module is the open
//! replacement. `saturn::solver` builds the actual formulation.

pub mod lp;
pub mod milp;
