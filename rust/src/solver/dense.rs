//! Two-phase dense-tableau primal simplex — the seed implementation,
//! kept as the reference oracle for `solver::lp`'s revised simplex.
//!
//! `tests/prop_solver.rs` holds the two solvers to identical objectives
//! on random LPs, `benches/bench_solver_scale.rs` times the seed MILP
//! path (`MilpEngine::DenseReference`) against the rebuilt one, and the
//! unit tests here pin the historical behaviour. First-class variable
//! bounds on [`Lp`] are materialized as constraint rows before building
//! the tableau — exactly the formulation the seed forced on every
//! caller, which is what makes this the honest "before" baseline.

use crate::solver::lp::{Cmp, Lp, LpResult, EPS};

/// Diagnostics for one dense solve.
#[derive(Debug, Clone, Default)]
pub struct DenseInfo {
    /// Total simplex pivots across both phases.
    pub pivots: usize,
    /// The iteration cap fired before convergence: the reported point is
    /// the current basic solution, not a certified optimum. Also logged
    /// via `log::warn!`.
    pub capped: bool,
}

/// Solve with the two-phase dense tableau simplex.
pub fn solve(lp: &Lp) -> LpResult {
    solve_with_info(lp).0
}

/// Solve, reporting pivot count and whether the iteration cap fired.
pub fn solve_with_info(lp: &Lp) -> (LpResult, DenseInfo) {
    // The dense tableau knows only `x >= 0` plus rows: materialize the
    // first-class bounds (the seed carried them as rows all along).
    let mut full = lp.clone();
    for j in 0..lp.n {
        debug_assert!(lp.lower[j] >= 0.0, "dense reference requires x >= 0");
        if lp.lower[j] > 0.0 {
            full.add(vec![(j, 1.0)], Cmp::Ge, lp.lower[j]);
        }
        if lp.upper[j].is_finite() {
            full.add(vec![(j, 1.0)], Cmp::Le, lp.upper[j]);
        }
    }
    let mut t = Tableau::build(&full);
    let result = t.solve();
    (result, DenseInfo { pivots: t.pivots, capped: t.capped })
}

struct Tableau {
    /// rows m x cols (n + slacks + artificials + 1 rhs)
    a: Vec<Vec<f64>>,
    m: usize,
    cols: usize, // total structural+slack+artificial columns (excl. rhs)
    n: usize,    // original variables
    basis: Vec<usize>,
    /// `is_artificial[j]` for every column (O(1) membership — the seed
    /// scanned a `Vec` per column here).
    is_artificial: Vec<bool>,
    any_artificial: bool,
    obj: Vec<f64>, // original objective padded to `cols`
    pivots: usize,
    capped: bool,
}

impl Tableau {
    fn build(lp: &Lp) -> Tableau {
        let m = lp.constraints.len();
        // Count slack columns (one per inequality) and artificials.
        let mut n_slack = 0;
        for c in &lp.constraints {
            if c.cmp != Cmp::Eq {
                n_slack += 1;
            }
        }
        // worst case: one artificial per row
        let cols = lp.n + n_slack + m;
        let mut a = vec![vec![0.0; cols + 1]; m];
        let mut basis = vec![usize::MAX; m];
        let mut is_artificial = vec![false; cols];
        let mut any_artificial = false;
        let mut slack_idx = lp.n;
        let mut art_idx = lp.n + n_slack;

        for (i, c) in lp.constraints.iter().enumerate() {
            let mut rhs = c.rhs;
            let mut sign = 1.0;
            if rhs < 0.0 {
                // normalize rhs >= 0 by flipping the row
                rhs = -rhs;
                sign = -1.0;
            }
            for &(j, v) in &c.coeffs {
                a[i][j] += sign * v;
            }
            a[i][cols] = rhs;
            let cmp = match (c.cmp, sign < 0.0) {
                (Cmp::Le, false) | (Cmp::Ge, true) => Cmp::Le,
                (Cmp::Ge, false) | (Cmp::Le, true) => Cmp::Ge,
                (Cmp::Eq, _) => Cmp::Eq,
            };
            match cmp {
                Cmp::Le => {
                    a[i][slack_idx] = 1.0;
                    basis[i] = slack_idx;
                    slack_idx += 1;
                }
                Cmp::Ge => {
                    a[i][slack_idx] = -1.0; // surplus
                    slack_idx += 1;
                    a[i][art_idx] = 1.0;
                    basis[i] = art_idx;
                    is_artificial[art_idx] = true;
                    any_artificial = true;
                    art_idx += 1;
                }
                Cmp::Eq => {
                    a[i][art_idx] = 1.0;
                    basis[i] = art_idx;
                    is_artificial[art_idx] = true;
                    any_artificial = true;
                    art_idx += 1;
                }
            }
        }

        let mut obj = vec![0.0; cols];
        obj[..lp.n].copy_from_slice(&lp.objective);
        Tableau {
            a,
            m,
            cols,
            n: lp.n,
            basis,
            is_artificial,
            any_artificial,
            obj,
            pivots: 0,
            capped: false,
        }
    }

    fn solve(&mut self) -> LpResult {
        // Phase 1: minimize sum of artificials.
        if self.any_artificial {
            let mut phase1 = vec![0.0; self.cols];
            for (j, &art) in self.is_artificial.iter().enumerate() {
                if art {
                    phase1[j] = 1.0;
                }
            }
            match self.run_simplex(&phase1) {
                SimplexOutcome::Optimal(obj) => {
                    if obj > 1e-6 {
                        return LpResult::Infeasible;
                    }
                }
                SimplexOutcome::Unbounded => return LpResult::Infeasible,
            }
            // Drive remaining artificials out of the basis if possible.
            for i in 0..self.m {
                if self.is_artificial[self.basis[i]] {
                    let pivot_col = (0..self.cols).find(|&j| {
                        !self.is_artificial[j] && self.a[i][j].abs() > EPS
                    });
                    if let Some(j) = pivot_col {
                        self.pivot(i, j);
                    }
                    // else: redundant row; artificial stays basic at 0.
                }
            }
            // Freeze artificial columns at zero for phase 2.
            for j in 0..self.cols {
                if self.is_artificial[j] {
                    for row in self.a.iter_mut() {
                        row[j] = 0.0;
                    }
                }
            }
        }

        // Phase 2: original objective.
        let obj = self.obj.clone();
        match self.run_simplex(&obj) {
            SimplexOutcome::Optimal(objective) => {
                let mut x = vec![0.0; self.n];
                for i in 0..self.m {
                    let b = self.basis[i];
                    if b < self.n {
                        x[b] = self.a[i][self.cols];
                    }
                }
                LpResult::Optimal { x, objective }
            }
            SimplexOutcome::Unbounded => LpResult::Unbounded,
        }
    }

    /// Reduced-cost simplex loop on objective `c`; returns optimal value.
    fn run_simplex(&mut self, c: &[f64]) -> SimplexOutcome {
        let max_iters = 200 * (self.m + self.cols);
        for iter in 0..max_iters {
            // reduced costs: z_j = c_j - c_B' B^-1 A_j (computed row-wise)
            let mut reduced = c.to_vec();
            for i in 0..self.m {
                let cb = c[self.basis[i]];
                if cb.abs() > EPS {
                    for j in 0..self.cols {
                        reduced[j] -= cb * self.a[i][j];
                    }
                }
            }
            // entering column: Dantzig normally, Bland past a burn-in to
            // guarantee termination under degeneracy.
            let entering = if iter < max_iters / 2 {
                let mut best = None;
                let mut best_val = -EPS;
                for (j, &r) in reduced.iter().enumerate() {
                    if r < best_val {
                        best_val = r;
                        best = Some(j);
                    }
                }
                best
            } else {
                reduced.iter().position(|&r| r < -EPS)
            };
            let Some(e) = entering else {
                // optimal; objective = c_B' b
                let mut obj = 0.0;
                for i in 0..self.m {
                    obj += c[self.basis[i]] * self.a[i][self.cols];
                }
                return SimplexOutcome::Optimal(obj);
            };
            // ratio test (Bland tie-break on basis index)
            let mut leave: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            for i in 0..self.m {
                if self.a[i][e] > EPS {
                    let ratio = self.a[i][self.cols] / self.a[i][e];
                    let tie = match leave {
                        Some(l) => self.basis[i] < self.basis[l],
                        None => true,
                    };
                    if ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS && tie)
                    {
                        best_ratio = ratio;
                        leave = Some(i);
                    }
                }
            }
            let Some(l) = leave else {
                return SimplexOutcome::Unbounded;
            };
            self.pivot(l, e);
        }
        // Iteration cap: surface it instead of silently reporting the
        // current point as optimal (callers check `DenseInfo::capped`).
        self.capped = true;
        log::warn!(
            "dense simplex hit the iteration cap ({max_iters} iters, m={} \
             cols={}); reporting the current basic point",
            self.m, self.cols);
        let mut obj = 0.0;
        for i in 0..self.m {
            obj += c[self.basis[i]] * self.a[i][self.cols];
        }
        SimplexOutcome::Optimal(obj)
    }

    fn pivot(&mut self, row: usize, col: usize) {
        let pv = self.a[row][col];
        debug_assert!(pv.abs() > EPS);
        let inv = 1.0 / pv;
        for v in self.a[row].iter_mut() {
            *v *= inv;
        }
        let pivot_row = self.a[row].clone();
        for (i, r) in self.a.iter_mut().enumerate() {
            if i != row && r[col].abs() > EPS {
                let factor = r[col];
                for (v, pv) in r.iter_mut().zip(&pivot_row) {
                    *v -= factor * pv;
                }
            }
        }
        self.basis[row] = col;
        self.pivots += 1;
    }
}

enum SimplexOutcome {
    Optimal(f64),
    Unbounded,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} != {b}");
    }

    #[test]
    fn matches_seed_behaviour_on_classic_instances() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 -> -36 in min form
        let mut lp = Lp::new(2);
        lp.set_obj(0, -3.0);
        lp.set_obj(1, -5.0);
        lp.add(vec![(0, 1.0)], Cmp::Le, 4.0);
        lp.add(vec![(1, 2.0)], Cmp::Le, 12.0);
        lp.add(vec![(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let res = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, -36.0);
        assert_close(x[0], 2.0);
        assert_close(x[1], 6.0);
    }

    #[test]
    fn first_class_bounds_are_materialized() {
        // bounds set via the Lp API (variable bounds) must still bind here
        let mut lp = Lp::new(2);
        lp.set_obj(0, -1.0);
        lp.set_obj(1, -1.0);
        lp.set_bounds(0, 1.0, 3.0);
        lp.bound_le(1, 4.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 10.0);
        let res = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, -7.0);
        assert_close(x[0], 3.0);
        assert_close(x[1], 4.0);
    }

    #[test]
    fn infeasible_and_unbounded_detected() {
        let mut lp = Lp::new(1);
        lp.bound_ge(0, 5.0);
        lp.bound_le(0, 3.0);
        assert_eq!(solve(&lp), LpResult::Infeasible);

        let mut lp = Lp::new(1);
        lp.set_obj(0, -1.0);
        assert_eq!(solve(&lp), LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_normalization() {
        // x - y <= -2  (i.e. y >= x + 2), min y -> x=0, y=2
        let mut lp = Lp::new(2);
        lp.set_obj(1, 1.0);
        lp.add(vec![(0, 1.0), (1, -1.0)], Cmp::Le, -2.0);
        let res = solve(&lp);
        let (x, obj) = res.optimal().expect("optimal");
        assert_close(obj, 2.0);
        assert_close(x[1], 2.0);
    }

    #[test]
    fn pivots_reported_and_cap_untripped_on_small_lps() {
        let mut lp = Lp::new(2);
        lp.set_obj(0, -1.0);
        lp.set_obj(1, -2.0);
        lp.bound_le(0, 1.0);
        lp.bound_le(1, 1.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 1.5);
        let (res, info) = solve_with_info(&lp);
        assert!(res.optimal().is_some());
        assert!(!info.capped);
        assert!(info.pivots > 0);
    }
}
