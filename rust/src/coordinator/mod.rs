//! Leader coordinator: the end-to-end REAL execution path.
//!
//! Mirrors Figure 1A with actual compute: the user submits an HPO grid
//! over the runnable GPT-mini models; the Trial Runner probes real PJRT
//! step times; the Solver plans; executor lanes (stand-ins for GPUs on
//! this CPU-only testbed) train the jobs to completion concurrently.
//! Python is never invoked — only `artifacts/*.hlo.txt` are loaded.

use std::collections::HashMap;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use log::info;

use crate::cluster::ClusterSpec;
use crate::runtime::{Engine, Manifest, Trainer};
use crate::saturn::solver::{solve_joint, SolverMode};
use crate::trials::ProfileTable;
use crate::parallelism::StepEstimate;
use crate::util::threadpool::ThreadPool;

/// One real fine-tuning job (a point of the HPO grid over runnable models).
#[derive(Debug, Clone)]
pub struct RealJob {
    pub id: usize,
    pub model: String,
    pub batch: u32,
    pub lr: f32,
    pub steps: u64,
}

impl RealJob {
    pub fn name(&self) -> String {
        format!("{}-bs{}-lr{:.0e}", self.model, self.batch, self.lr)
    }
}

/// Grid constructor (Table 1 in miniature, over runnable artifacts).
pub fn real_grid(models: &[(&str, u32)], lrs: &[f32], steps: u64) -> Vec<RealJob> {
    let mut jobs = Vec::new();
    for &(model, batch) in models {
        for &lr in lrs {
            jobs.push(RealJob {
                id: jobs.len(),
                model: model.to_string(),
                batch,
                lr,
                steps,
            });
        }
    }
    jobs
}

#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job: RealJob,
    pub first_loss: f32,
    pub final_loss: f32,
    pub mean_step_ms: f64,
    pub wall_s: f64,
    pub lane: usize,
}

#[derive(Debug, Clone)]
pub struct SelectionReport {
    pub outcomes: Vec<JobOutcome>,
    pub makespan_s: f64,
    pub profiling_s: f64,
    pub solver_s: f64,
    pub order: Vec<usize>,
    /// Winning configuration (lowest final loss).
    pub best: usize,
}

pub struct Coordinator {
    engine: Arc<Engine>,
    manifest: Manifest,
    /// Executor lanes standing in for GPUs (CPU-only testbed).
    pub lanes: usize,
}

impl Coordinator {
    pub fn new(lanes: usize) -> Result<Coordinator> {
        Ok(Coordinator {
            engine: Arc::new(Engine::cpu()?),
            manifest: Manifest::load_default()?,
            lanes: lanes.max(1),
        })
    }

    pub fn with_manifest(manifest: Manifest, lanes: usize) -> Result<Coordinator> {
        Ok(Coordinator {
            engine: Arc::new(Engine::cpu()?),
            manifest,
            lanes: lanes.max(1),
        })
    }

    /// Trial Runner over real artifacts: probe each distinct (model,batch)
    /// once (2 timed steps) and build a ProfileTable where "GPU count" is
    /// an executor lane (jobs occupy exactly one lane).
    pub fn profile(&self, jobs: &[RealJob]) -> Result<(ProfileTable, f64)> {
        let t0 = Instant::now();
        let mut per_variant: HashMap<(String, u32), f64> = HashMap::new();
        for job in jobs {
            let key = (job.model.clone(), job.batch);
            if per_variant.contains_key(&key) {
                continue;
            }
            let mut probe = Trainer::new(self.engine.clone(), &self.manifest,
                                         &job.model, job.batch, 0)?;
            let step_s = probe.time_step(job.lr, 2, 17)?;
            info!("probe {}: {:.1} ms/step", job.name(), step_s * 1e3);
            per_variant.insert(key, step_s);
        }
        let mut table = ProfileTable::new(vec![vec![1]], 1);
        for job in jobs {
            let step = per_variant[&(job.model.clone(), job.batch)];
            table.insert(job.id, 0, 1, 0, StepEstimate {
                step_time_s: step,
                mem_per_gpu: 0.0,
                mfu: 0.0,
            });
            table.profiling_cost_s += 2.0 * step;
        }
        Ok((table, t0.elapsed().as_secs_f64()))
    }

    /// Full pipeline: profile -> solve -> execute on `lanes` workers.
    pub fn run_model_selection(&self, jobs: &[RealJob], seed: u64)
        -> Result<SelectionReport> {
        let (profiles, profiling_s) = self.profile(jobs)?;

        // Solve: lanes-as-GPUs cluster (1 node, `lanes` gpus, one class)
        let mut node = crate::cluster::NodeSpec::p4d_24xlarge();
        node.gpus_per_node = self.lanes as u32;
        let cluster = ClusterSpec::single("lanes", 1, node, 50e9);
        let remaining: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.steps)).collect();
        let t0 = Instant::now();
        let (plan, _) = solve_joint(&remaining, &profiles, &cluster,
                                    SolverMode::Joint);
        let solver_s = t0.elapsed().as_secs_f64();
        info!("plan order: {:?} (predicted makespan {:.1}s)", plan.order,
              plan.predicted_makespan_s);

        // Execute: workers pull jobs in plan order. PJRT client handles are
        // not Send (internal Rc), so each lane owns a private Engine —
        // "one compiled executable per model variant" per lane.
        let pool = ThreadPool::new(self.lanes);
        let (tx, rx) = channel::<JobOutcome>();
        let queue = Arc::new(std::sync::Mutex::new(
            plan.order.iter().rev().cloned().collect::<Vec<usize>>(),
        ));
        let t_start = Instant::now();
        for lane in 0..self.lanes {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let manifest = self.manifest.clone();
            let jobs = jobs.to_vec();
            let seed = seed;
            pool.execute(move || {
                let engine = match Engine::cpu() {
                    Ok(e) => Arc::new(e),
                    Err(e) => {
                        log::error!("lane {lane}: no PJRT client: {e:#}");
                        return;
                    }
                };
                loop {
                let next = queue.lock().unwrap().pop();
                let Some(id) = next else { break };
                let job = jobs[id].clone();
                let t0 = Instant::now();
                let outcome = (|| -> Result<JobOutcome> {
                    let mut t = Trainer::new(engine.clone(), &manifest,
                                             &job.model, job.batch,
                                             seed as i32 + id as i32)?;
                    let rep = t.train_synthetic(job.lr, job.steps,
                                                seed ^ id as u64)?;
                    Ok(JobOutcome {
                        job: job.clone(),
                        first_loss: rep.first_loss,
                        final_loss: rep.last_loss,
                        mean_step_ms: rep.mean_step_ms,
                        wall_s: t0.elapsed().as_secs_f64(),
                        lane,
                    })
                })();
                match outcome {
                    Ok(o) => {
                        info!("lane {lane} finished {} loss={:.3} ({:.1}s)",
                              o.job.name(), o.final_loss, o.wall_s);
                        let _ = tx.send(o);
                    }
                    Err(e) => {
                        log::error!("lane {lane} job {} failed: {e:#}",
                                    job.name());
                    }
                }
                }
            });
        }
        drop(tx);
        let mut outcomes: Vec<JobOutcome> = rx.into_iter().collect();
        let makespan_s = t_start.elapsed().as_secs_f64();
        outcomes.sort_by_key(|o| o.job.id);
        let best = outcomes
            .iter()
            .min_by(|a, b| a.final_loss.partial_cmp(&b.final_loss).unwrap())
            .map(|o| o.job.id)
            .unwrap_or(0);
        Ok(SelectionReport {
            outcomes,
            makespan_s,
            profiling_s,
            solver_s,
            order: plan.order,
            best,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_builds_cartesian() {
        let jobs = real_grid(&[("tiny", 8), ("small", 8)], &[1e-3, 1e-4], 10);
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[3].model, "small");
        assert!(jobs.iter().enumerate().all(|(i, j)| j.id == i));
    }

    #[test]
    fn end_to_end_mini_selection() {
        // real profile -> solve -> train, kept tiny for CI speed
        let coord = match Coordinator::new(2) {
            Ok(c) => c,
            Err(e) => {
                // PJRT stub / missing artifacts: skip instead of failing
                eprintln!("skipping e2e test: {e:#}");
                return;
            }
        };
        let jobs = real_grid(&[("tiny", 8)], &[3e-3, 1e-4], 6);
        let r = coord.run_model_selection(&jobs, 5).unwrap();
        assert_eq!(r.outcomes.len(), 2);
        assert!(r.makespan_s > 0.0);
        assert!(r.outcomes.iter().all(|o| o.final_loss.is_finite()));
        assert!(r.profiling_s > 0.0);
    }
}
