//! Hardware constants for the simulated fleet.
//!
//! Sources for the numbers (cited so the calibration is auditable):
//!  * A100-40GB SXM: 312 TFLOP/s bf16 dense, 40 GB HBM2e (NVIDIA A100
//!    datasheet, 2020).
//!  * p4d.24xlarge: 8x A100-40GB, 600 GB/s NVSwitch per-GPU bidirectional
//!    (we use 240 GB/s effective all-reduce bus bandwidth, the standard
//!    NCCL ring-effective figure), 400 Gbps EFA => ~50 GB/s, PCIe gen4
//!    x16 => 32 GB/s (AWS EC2 docs, 2021).

/// A single accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    pub mem_bytes: f64,
    /// Dense bf16/fp16 peak, FLOP/s.
    pub peak_flops: f64,
}

impl GpuSpec {
    pub fn a100_40gb() -> Self {
        GpuSpec {
            name: "A100-40GB".into(),
            mem_bytes: 40e9,
            peak_flops: 312e12,
        }
    }

    pub fn mem_gb(&self) -> f64 {
        self.mem_bytes / 1e9
    }

    /// Memory actually available to a training job: framework/driver
    /// reserves ~2 GB and fragmentation eats ~8% in practice.
    pub fn usable_bytes(&self) -> f64 {
        0.92 * self.mem_bytes - 2e9
    }
}

/// One server (the paper's unit of task parallelism).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub gpus_per_node: u32,
    pub gpu: GpuSpec,
    /// Effective intra-node collective bus bandwidth, bytes/s.
    pub intra_bw: f64,
    /// Host<->GPU PCIe bandwidth, bytes/s (offloading cost model).
    pub pcie_bw: f64,
}

impl NodeSpec {
    pub fn p4d_24xlarge() -> Self {
        NodeSpec {
            gpus_per_node: 8,
            gpu: GpuSpec::a100_40gb(),
            intra_bw: 240e9,
            pcie_bw: 32e9,
        }
    }
}

/// The whole fleet visible to the scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub nodes: u32,
    pub node: NodeSpec,
    /// Effective inter-node collective bandwidth, bytes/s.
    pub inter_bw: f64,
}

impl ClusterSpec {
    /// The paper's testbed: `nodes` x p4d.24xlarge.
    pub fn p4d(nodes: u32) -> Self {
        ClusterSpec { nodes, node: NodeSpec::p4d_24xlarge(), inter_bw: 50e9 }
    }

    pub fn total_gpus(&self) -> u32 {
        self.nodes * self.node.gpus_per_node
    }

    /// Effective collective bandwidth for a `gpus`-wide ring: NVSwitch when
    /// the ring fits in one node, EFA-bound otherwise.
    pub fn collective_bw(&self, gpus: u32) -> f64 {
        if gpus <= self.node.gpus_per_node {
            self.node.intra_bw
        } else {
            self.inter_bw
        }
    }

    /// GPU counts a job may be assigned (powers of two up to the fleet,
    /// whole-node multiples beyond one node — the granularities DL
    /// practitioners actually use and the paper's solver searches over).
    pub fn allocation_options(&self) -> Vec<u32> {
        let per = self.node.gpus_per_node;
        let mut opts: Vec<u32> = [1u32, 2, 4]
            .into_iter()
            .filter(|&g| g <= per)
            .collect();
        let mut g = per;
        while g <= self.total_gpus() {
            opts.push(g);
            g *= 2;
        }
        if !opts.contains(&self.total_gpus()) && self.total_gpus() > per {
            opts.push(self.total_gpus());
        }
        opts.sort_unstable();
        opts.dedup();
        opts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p4d_shape() {
        let c = ClusterSpec::p4d(2);
        assert_eq!(c.total_gpus(), 16);
        assert_eq!(c.node.gpu.mem_gb(), 40.0);
        assert!(c.node.gpu.peak_flops > 3e14);
    }

    #[test]
    fn collective_bw_hierarchy() {
        let c = ClusterSpec::p4d(2);
        assert!(c.collective_bw(8) > c.collective_bw(16));
    }

    #[test]
    fn allocation_options_one_node() {
        let c = ClusterSpec::p4d(1);
        assert_eq!(c.allocation_options(), vec![1, 2, 4, 8]);
    }

    #[test]
    fn allocation_options_two_nodes() {
        let c = ClusterSpec::p4d(2);
        assert_eq!(c.allocation_options(), vec![1, 2, 4, 8, 16]);
    }
}
