//! Hardware constants for the simulated fleet, including heterogeneous
//! (multi-class) fleets mixing GPU generations.
//!
//! Sources for the numbers (cited so the calibration is auditable):
//!  * A100-40GB SXM: 312 TFLOP/s bf16 dense, 40 GB HBM2e (NVIDIA A100
//!    datasheet, 2020).
//!  * H100-80GB SXM: 989 TFLOP/s bf16 dense (non-sparse), 80 GB HBM3
//!    (NVIDIA H100 datasheet, 2022).
//!  * p4d.24xlarge: 8x A100-40GB, 600 GB/s NVSwitch per-GPU bidirectional
//!    (we use 240 GB/s effective all-reduce bus bandwidth, the standard
//!    NCCL ring-effective figure), 400 Gbps EFA => ~50 GB/s, PCIe gen4
//!    x16 => 32 GB/s (AWS EC2 docs, 2021).
//!  * p5.48xlarge: 8x H100-80GB, 900 GB/s NVSwitch per-GPU (=> 360 GB/s
//!    ring-effective at the same 0.4 ratio), 3200 Gbps EFA, PCIe gen5
//!    x16 => 64 GB/s (AWS EC2 docs, 2023).
//!
//! A fleet is a list of **GPU classes** (homogeneous node groups). The
//! parallelism cost models always receive a single-class *view*
//! ([`ClusterSpec::class_view`]) so step times and memory feasibility are
//! computed against that class's `GpuSpec` and bandwidths; the solver and
//! placement layers then treat the class index as a first-class dimension.

/// A single accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    pub mem_bytes: f64,
    /// Dense bf16/fp16 peak, FLOP/s.
    pub peak_flops: f64,
}

impl GpuSpec {
    pub fn a100_40gb() -> Self {
        GpuSpec {
            name: "A100-40GB".into(),
            mem_bytes: 40e9,
            peak_flops: 312e12,
        }
    }

    pub fn h100_80gb() -> Self {
        GpuSpec {
            name: "H100-80GB".into(),
            mem_bytes: 80e9,
            peak_flops: 989e12,
        }
    }

    pub fn mem_gb(&self) -> f64 {
        self.mem_bytes / 1e9
    }

    /// Memory actually available to a training job: framework/driver
    /// reserves ~2 GB and fragmentation eats ~8% in practice.
    pub fn usable_bytes(&self) -> f64 {
        0.92 * self.mem_bytes - 2e9
    }
}

/// One server (the paper's unit of task parallelism).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub gpus_per_node: u32,
    pub gpu: GpuSpec,
    /// Effective intra-node collective bus bandwidth, bytes/s.
    pub intra_bw: f64,
    /// Host<->GPU PCIe bandwidth, bytes/s (offloading cost model).
    pub pcie_bw: f64,
    /// Fraction of streamed PCIe traffic the node's copy engines hide
    /// behind compute (double-buffered offload). Gen4 parts sustain the
    /// classic 0.4; gen5 doubles the lanes and adds H100's async TMA
    /// copy engines, so the stream hides much deeper. Cost-model readers
    /// treat it as a floor on their own overlap knob.
    pub pcie_overlap: f64,
}

impl NodeSpec {
    pub fn p4d_24xlarge() -> Self {
        NodeSpec {
            gpus_per_node: 8,
            gpu: GpuSpec::a100_40gb(),
            intra_bw: 240e9,
            pcie_bw: 32e9,
            pcie_overlap: 0.4,
        }
    }

    pub fn p5_48xlarge() -> Self {
        NodeSpec {
            gpus_per_node: 8,
            gpu: GpuSpec::h100_80gb(),
            intra_bw: 360e9,
            pcie_bw: 64e9,
            // PCIe gen5 offload-overlap term: 2x lanes + async copy
            // engines keep the weight stream ahead of compute
            pcie_overlap: 0.7,
        }
    }
}

/// A homogeneous group of nodes sharing one GPU class — the unit the
/// heterogeneous solver, placement rules and CLI fleet syntax speak.
/// Each class carries its OWN inter-node fabric: the EFA generations on
/// p4d (400 Gbps) and p5 (3200 Gbps) differ ~4x, so one fleet-wide
/// figure under-states H100 rings and over-states A100 rings.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuClass {
    /// Class tag ("a100", "h100") used by `--fleet` and reports.
    pub name: String,
    pub nodes: u32,
    pub node: NodeSpec,
    /// Effective inter-node collective bandwidth within this class's
    /// fabric, bytes/s (jobs never span classes, so cross-class
    /// bandwidth never enters a cost model).
    pub inter_bw: f64,
    /// Checkpoint lag charged when a job migrates INTO this class from a
    /// DIFFERENT class: a clean sequential checkpoint stream over the
    /// destination's PCIe — cheaper than the same-class
    /// reshape-in-place ([`crate::sim::engine::SimConfig`]'s
    /// `checkpoint_penalty_s`), which must re-shard optimizer state
    /// among overlapping ranks.
    pub reload_penalty_s: f64,
}

impl GpuClass {
    pub fn a100(nodes: u32) -> Self {
        GpuClass {
            name: "a100".into(),
            nodes,
            node: NodeSpec::p4d_24xlarge(),
            inter_bw: 50e9,
            reload_penalty_s: 45.0,
        }
    }

    pub fn h100(nodes: u32) -> Self {
        GpuClass {
            name: "h100".into(),
            nodes,
            node: NodeSpec::p5_48xlarge(),
            // 3200 Gbps EFA vs p4d's 400 Gbps: ~4x effective
            inter_bw: 200e9,
            // PCIe gen5 streams the checkpoint twice as fast
            reload_penalty_s: 30.0,
        }
    }

    pub fn gpus(&self) -> u32 {
        self.nodes * self.node.gpus_per_node
    }

    /// Class-wide dense peak, FLOP/s.
    pub fn peak_flops(&self) -> f64 {
        self.gpus() as f64 * self.node.gpu.peak_flops
    }
}

/// The whole fleet visible to the scheduler: one or more GPU classes,
/// each with its own inter-node fabric. Single-class fleets behave
/// exactly like the original homogeneous `ClusterSpec` (the degenerate
/// probe in `bench_hetero` holds this to 1e-6).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// Homogeneous node groups, one per GPU class. Class indices used by
    /// the profiles/solver/placement layers index into this vector.
    pub classes: Vec<GpuClass>,
}

impl ClusterSpec {
    /// The paper's testbed: `nodes` x p4d.24xlarge (single A100 class).
    pub fn p4d(nodes: u32) -> Self {
        ClusterSpec { classes: vec![GpuClass::a100(nodes)] }
    }

    /// All-H100 fleet: `nodes` x p5.48xlarge.
    pub fn p5(nodes: u32) -> Self {
        ClusterSpec { classes: vec![GpuClass::h100(nodes)] }
    }

    /// Mixed-generation fleet: `a100_nodes` x p4d + `h100_nodes` x p5.
    /// Each class rides its own EFA generation (p5's is ~4x p4d's).
    pub fn hetero(a100_nodes: u32, h100_nodes: u32) -> Self {
        let mut classes = Vec::new();
        if a100_nodes > 0 {
            classes.push(GpuClass::a100(a100_nodes));
        }
        if h100_nodes > 0 {
            classes.push(GpuClass::h100(h100_nodes));
        }
        assert!(!classes.is_empty(), "fleet must have at least one node");
        ClusterSpec { classes }
    }

    /// Force ONE fabric figure on every class — the pre-PR-4 semantics,
    /// kept so call sites and benches modeling a shared back-network
    /// stay one-line.
    pub fn uniform_inter_bw(mut classes: Vec<GpuClass>, inter_bw: f64)
        -> Self {
        assert!(!classes.is_empty(), "fleet must have at least one class");
        for c in classes.iter_mut() {
            c.inter_bw = inter_bw;
        }
        ClusterSpec { classes }
    }

    /// One custom class (used by the coordinator's lanes-as-GPUs cluster).
    pub fn single(name: &str, nodes: u32, node: NodeSpec, inter_bw: f64)
        -> Self {
        ClusterSpec {
            classes: vec![GpuClass {
                name: name.into(),
                nodes,
                node,
                inter_bw,
                reload_penalty_s: 45.0,
            }],
        }
    }

    /// Parse the CLI fleet syntax `a100:32,h100:16` (GPU counts per class;
    /// whole-node multiples of 8). Known classes: `a100`, `h100`.
    ///
    /// Every malformed spec returns a clear `Err` naming the offending
    /// entry: unknown class names, zero/negative/non-numeric counts,
    /// non-whole-node counts, and DUPLICATE class entries (which an
    /// earlier version silently folded together) all refuse to parse.
    pub fn parse_fleet(spec: &str) -> Result<ClusterSpec, String> {
        let mut a100 = 0u32;
        let mut h100 = 0u32;
        let mut seen: Vec<&str> = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, count) = part
                .split_once(':')
                .ok_or_else(|| format!("bad fleet entry '{part}' \
                                        (expected class:gpus, e.g. a100:32)"))?;
            let name = name.trim();
            let count = count.trim();
            let gpus: i64 = count.parse().map_err(|_| {
                format!("bad GPU count '{count}' in fleet entry '{part}' \
                         (expected a whole number, e.g. a100:32)")
            })?;
            if gpus <= 0 {
                return Err(format!(
                    "fleet entry '{part}': GPU count must be positive, \
                     got {gpus}"));
            }
            if gpus % 8 != 0 {
                return Err(format!(
                    "fleet entry '{part}': GPU count must be a positive \
                     multiple of 8 (whole nodes)"));
            }
            if gpus / 8 > u32::MAX as i64 {
                return Err(format!(
                    "fleet entry '{part}': GPU count exceeds the \
                     supported fleet size ({} nodes max)", u32::MAX));
            }
            if seen.contains(&name) {
                return Err(format!(
                    "duplicate GPU class '{name}' in fleet spec '{spec}' \
                     (merge the entries into one, e.g. {name}:N)"));
            }
            let nodes = (gpus / 8) as u32;
            match name {
                "a100" => a100 = nodes,
                "h100" => h100 = nodes,
                other => {
                    return Err(format!(
                        "unknown GPU class '{other}' (known: a100, h100)"))
                }
            }
            seen.push(name);
        }
        if a100 == 0 && h100 == 0 {
            return Err(format!("empty fleet spec '{spec}'"));
        }
        Ok(ClusterSpec::hetero(a100, h100))
    }

    /// Human-readable fleet description, e.g. `a100:16+h100:8`.
    pub fn fleet_desc(&self) -> String {
        self.classes
            .iter()
            .map(|c| format!("{}:{}", c.name, c.gpus()))
            .collect::<Vec<_>>()
            .join("+")
    }

    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    pub fn is_single_class(&self) -> bool {
        self.classes.len() == 1
    }

    pub fn class(&self, ci: usize) -> &GpuClass {
        &self.classes[ci]
    }

    pub fn class_index(&self, name: &str) -> Option<usize> {
        self.classes.iter().position(|c| c.name == name)
    }

    /// GPUs in one class.
    pub fn class_gpus(&self, ci: usize) -> u32 {
        self.classes[ci].gpus()
    }

    pub fn total_gpus(&self) -> u32 {
        self.classes.iter().map(|c| c.gpus()).sum()
    }

    pub fn total_nodes(&self) -> u32 {
        self.classes.iter().map(|c| c.nodes).sum()
    }

    /// Fleet-wide dense peak, FLOP/s (the "equivalent-FLOPs" currency the
    /// hetero bench compares fleets in).
    pub fn peak_flops(&self) -> f64 {
        self.classes.iter().map(|c| c.peak_flops()).sum()
    }

    /// The first class — what the cost-model accessors below refer to.
    /// Cost models always receive a single-class view, where "primary" IS
    /// the whole fleet.
    pub fn primary(&self) -> &GpuClass {
        &self.classes[0]
    }

    /// Restrict the fleet to one class: a homogeneous `ClusterSpec` the
    /// parallelism cost models profile against. The view carries the
    /// class's OWN fabric.
    pub fn class_view(&self, ci: usize) -> ClusterSpec {
        ClusterSpec { classes: vec![self.classes[ci].clone()] }
    }

    /// GPU spec of the primary class (cost-model view accessor).
    pub fn gpu(&self) -> &GpuSpec {
        &self.primary().node.gpu
    }

    pub fn gpus_per_node(&self) -> u32 {
        self.primary().node.gpus_per_node
    }

    pub fn intra_bw(&self) -> f64 {
        self.primary().node.intra_bw
    }

    pub fn pcie_bw(&self) -> f64 {
        self.primary().node.pcie_bw
    }

    /// PCIe stream overlap of the primary class's nodes (offload cost
    /// model; see [`NodeSpec::pcie_overlap`]).
    pub fn pcie_overlap(&self) -> f64 {
        self.primary().node.pcie_overlap
    }

    /// Inter-node fabric of the primary class (cost-model view accessor).
    pub fn inter_bw(&self) -> f64 {
        self.primary().inter_bw
    }

    /// Effective collective bandwidth for a `gpus`-wide ring within the
    /// primary class: NVSwitch when the ring fits in one node, bound by
    /// the class's own EFA fabric otherwise.
    pub fn collective_bw(&self, gpus: u32) -> f64 {
        if gpus <= self.gpus_per_node() {
            self.primary().node.intra_bw
        } else {
            self.primary().inter_bw
        }
    }

    /// GPU counts a job may be assigned within the PRIMARY class (powers
    /// of two up to the class, whole-node multiples beyond one node — the
    /// granularities DL practitioners actually use and the paper's solver
    /// searches over). On a multi-class fleet use
    /// [`ClusterSpec::class_allocation_options`].
    pub fn allocation_options(&self) -> Vec<u32> {
        let group = self.primary();
        let per = group.node.gpus_per_node;
        let class_total = group.gpus();
        let mut opts: Vec<u32> = [1u32, 2, 4]
            .into_iter()
            .filter(|&g| g <= per)
            .collect();
        let mut g = per;
        while g <= class_total {
            opts.push(g);
            g *= 2;
        }
        if !opts.contains(&class_total) && class_total > per {
            opts.push(class_total);
        }
        opts.sort_unstable();
        opts.dedup();
        opts
    }

    /// Allocation options within class `ci`.
    pub fn class_allocation_options(&self, ci: usize) -> Vec<u32> {
        self.class_view(ci).allocation_options()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p4d_shape() {
        let c = ClusterSpec::p4d(2);
        assert_eq!(c.total_gpus(), 16);
        assert_eq!(c.gpu().mem_gb(), 40.0);
        assert!(c.gpu().peak_flops > 3e14);
        assert!(c.is_single_class());
    }

    #[test]
    fn h100_class_is_bigger_and_faster() {
        let h = GpuSpec::h100_80gb();
        let a = GpuSpec::a100_40gb();
        assert!(h.mem_bytes > a.mem_bytes);
        assert!(h.peak_flops > 3.0 * a.peak_flops);
        assert!(h.usable_bytes() > 2.0 * a.usable_bytes());
    }

    #[test]
    fn collective_bw_hierarchy() {
        let c = ClusterSpec::p4d(2);
        assert!(c.collective_bw(8) > c.collective_bw(16));
        let p5 = ClusterSpec::p5(2);
        assert!(p5.collective_bw(8) > c.collective_bw(8));
    }

    #[test]
    fn allocation_options_one_node() {
        let c = ClusterSpec::p4d(1);
        assert_eq!(c.allocation_options(), vec![1, 2, 4, 8]);
    }

    #[test]
    fn allocation_options_two_nodes() {
        let c = ClusterSpec::p4d(2);
        assert_eq!(c.allocation_options(), vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn hetero_fleet_partitions_into_classes() {
        let c = ClusterSpec::hetero(2, 1);
        assert_eq!(c.n_classes(), 2);
        assert_eq!(c.total_gpus(), 24);
        assert_eq!(c.class_gpus(0), 16);
        assert_eq!(c.class_gpus(1), 8);
        assert_eq!(c.total_nodes(), 3);
        assert_eq!(c.class_index("h100"), Some(1));
        assert_eq!(c.fleet_desc(), "a100:16+h100:8");
        // per-class allocation options stay within the class
        assert_eq!(c.class_allocation_options(0), vec![1, 2, 4, 8, 16]);
        assert_eq!(c.class_allocation_options(1), vec![1, 2, 4, 8]);
    }

    #[test]
    fn class_view_is_homogeneous() {
        let c = ClusterSpec::hetero(2, 1);
        let v = c.class_view(1);
        assert!(v.is_single_class());
        assert_eq!(v.total_gpus(), 8);
        assert_eq!(v.gpu().name, "H100-80GB");
        // the view carries the class's OWN fabric, not class 0's
        assert_eq!(v.inter_bw(), c.class(1).inter_bw);
        assert!(v.inter_bw() > c.class(0).inter_bw);
    }

    #[test]
    fn per_class_fabrics_differ_about_4x() {
        let c = ClusterSpec::hetero(1, 1);
        let ratio = c.class(1).inter_bw / c.class(0).inter_bw;
        assert!((3.0..5.0).contains(&ratio), "EFA ratio {ratio}");
        // multi-node rings within each class view ride that class's EFA
        let a = c.class_view(0);
        let h = c.class_view(1);
        assert_eq!(a.collective_bw(16), c.class(0).inter_bw);
        assert_eq!(h.collective_bw(16), c.class(1).inter_bw);
    }

    #[test]
    fn uniform_inter_bw_overrides_every_class() {
        let c = ClusterSpec::uniform_inter_bw(
            vec![GpuClass::a100(1), GpuClass::h100(1)], 75e9);
        assert!(c.classes.iter().all(|k| k.inter_bw == 75e9));
        assert_eq!(c.inter_bw(), 75e9);
    }

    #[test]
    fn cross_class_reload_cheaper_than_reshape() {
        // the class constants: reload into either class undercuts the
        // 60 s same-class reshape default, gen5 PCIe streaming fastest
        let c = ClusterSpec::hetero(1, 1);
        assert!(c.class(0).reload_penalty_s < 60.0);
        assert!(c.class(1).reload_penalty_s < c.class(0).reload_penalty_s);
    }

    #[test]
    fn parse_fleet_roundtrip() {
        let c = ClusterSpec::parse_fleet("a100:32,h100:16").unwrap();
        assert_eq!(c.n_classes(), 2);
        assert_eq!(c.class_gpus(0), 32);
        assert_eq!(c.class_gpus(1), 16);
        assert_eq!(c.fleet_desc(), "a100:32+h100:16");
        // single-class spec degenerates to the homogeneous fleet
        let solo = ClusterSpec::parse_fleet("a100:16").unwrap();
        assert_eq!(solo.classes, ClusterSpec::p4d(2).classes);
    }

    #[test]
    fn parse_fleet_rejects_bad_specs() {
        assert!(ClusterSpec::parse_fleet("a100:12").is_err()); // not nodes
        assert!(ClusterSpec::parse_fleet("v100:8").is_err()); // unknown
        assert!(ClusterSpec::parse_fleet("a100").is_err()); // no count
        assert!(ClusterSpec::parse_fleet("").is_err()); // empty
        assert!(ClusterSpec::parse_fleet("a100:zero").is_err());
    }

    #[test]
    fn parse_fleet_names_the_unknown_class() {
        let err = ClusterSpec::parse_fleet("v100:8").unwrap_err();
        assert!(err.contains("unknown GPU class 'v100'"), "{err}");
        assert!(err.contains("a100"), "{err}"); // names the known set
    }

    #[test]
    fn parse_fleet_rejects_zero_and_negative_counts() {
        let err = ClusterSpec::parse_fleet("a100:0").unwrap_err();
        assert!(err.contains("must be positive"), "{err}");
        let err = ClusterSpec::parse_fleet("a100:-8").unwrap_err();
        assert!(err.contains("must be positive"), "{err}");
        // mixed with a valid entry the bad one still refuses
        assert!(ClusterSpec::parse_fleet("h100:16,a100:-8").is_err());
    }

    #[test]
    fn parse_fleet_rejects_duplicate_classes_instead_of_folding() {
        // an earlier version summed "a100:8,a100:16" to 3 nodes silently
        let err = ClusterSpec::parse_fleet("a100:8,a100:16").unwrap_err();
        assert!(err.contains("duplicate GPU class 'a100'"), "{err}");
        let err = ClusterSpec::parse_fleet("h100:8,a100:8,h100:8")
            .unwrap_err();
        assert!(err.contains("duplicate GPU class 'h100'"), "{err}");
    }

    #[test]
    fn equivalent_flops_accounting() {
        let mixed = ClusterSpec::hetero(2, 2);
        let expect = 16.0 * 312e12 + 16.0 * 989e12;
        assert!((mixed.peak_flops() - expect).abs() < 1e-3 * expect);
    }
}
