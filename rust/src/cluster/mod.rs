//! Cluster substrate: hardware specifications of the simulated GPU fleet.
//!
//! The paper evaluates on AWS `p4d.24xlarge` nodes (8x A100-40GB, NVSwitch
//! intra-node, EFA inter-node). No GPUs exist on this testbed, so the specs
//! here drive the analytic cost models in `parallelism/` and the
//! discrete-event simulator in `sim/` (DESIGN.md §Hardware-Adaptation).

pub mod specs;

pub use specs::{ClusterSpec, GpuSpec, NodeSpec};
