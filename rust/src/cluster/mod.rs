//! Cluster substrate: hardware specifications of the simulated GPU fleet.
//!
//! The paper evaluates on AWS `p4d.24xlarge` nodes (8x A100-40GB, NVSwitch
//! intra-node, EFA inter-node); the heterogeneous extension adds
//! `p5.48xlarge` (8x H100-80GB) node groups so a fleet partitions into GPU
//! classes. No GPUs exist on this testbed, so the specs here drive the
//! analytic cost models in `parallelism/` and the discrete-event simulator
//! in `sim/` (DESIGN.md §Hardware-Adaptation, §Fleets).

pub mod specs;

pub use specs::{ClusterSpec, GpuClass, GpuSpec, NodeSpec};
