//! Analytic specifications of the paper's evaluation models (Table 1) and
//! the datasets they fine-tune on.
//!
//! Parameter counts / FLOP models follow the usual conventions:
//!  * training FLOPs per token ~= 6 * params (fwd 2P + bwd 4P) for dense
//!    transformers (Kaplan et al. 2020), plus the attention term;
//!  * ResNet FLOPs taken from published per-image GFLOPs;
//!  * activation footprints use the flash-attention-era approximation
//!    (bytes/token ~= c * layers * hidden, c ~= 14 for mixed precision).
//!
//! These feed the parallelism cost models; absolute hours in Table 2 shift
//! with these constants but the *ordering and speedup factors* — what the
//! reproduction validates — are robust to them (see EXPERIMENTS.md).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Text,
    Vision,
}

/// Analytic description of a trainable model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub family: Family,
    pub params: f64,
    pub layers: u32,
    pub hidden: u32,
    /// Tokens (text) or patch-tokens (ViT) or pixels-proxy (CNN) per sample.
    pub tokens_per_sample: u32,
    /// Training FLOPs for ONE sample (fwd+bwd).
    pub flops_per_sample: f64,
    /// Activation bytes for ONE sample in mixed precision.
    pub act_bytes_per_sample: f64,
    /// Name of the runnable AOT artifact family standing in for this model
    /// in empirical (PJRT) trial mode, if any.
    pub artifact: Option<String>,
}

impl ModelSpec {
    fn transformer(name: &str, family: Family, params: f64, layers: u32,
                   hidden: u32, tokens: u32) -> Self {
        let flops = 6.0 * params * tokens as f64
            + 12.0 * layers as f64 * (tokens as f64).powi(2) * hidden as f64;
        // Paper-era (2022, pre-flash) mixed-precision activations: the
        // seq x seq attention matrices are materialized per head.
        let heads = (hidden / 64).max(1) as f64;
        let act = 2.0
            * layers as f64
            * (16.0 * hidden as f64 * tokens as f64
                + heads * (tokens as f64).powi(2));
        ModelSpec {
            name: name.into(),
            family,
            params,
            layers,
            hidden,
            tokens_per_sample: tokens,
            flops_per_sample: flops,
            act_bytes_per_sample: act,
            artifact: None,
        }
    }

    /// GPT-2 XL (1.5B): 48 layers, d=1600, fine-tuned at seq 1024.
    pub fn gpt2_xl() -> Self {
        Self::transformer("GPT-2", Family::Text, 1.5e9, 48, 1600, 1024)
            .with_artifact("small")
    }

    /// GPT-J (6B): 28 layers, d=4096, seq 1024 (2048 native, 1024 for FT).
    pub fn gpt_j() -> Self {
        Self::transformer("GPT-J", Family::Text, 6.05e9, 28, 4096, 1024)
            .with_artifact("small")
    }

    /// ViT-G/14 (1.8B): 48 layers, d=1664, 256 patch tokens + cls.
    pub fn vit_g() -> Self {
        Self::transformer("ViT-G", Family::Vision, 1.84e9, 48, 1664, 257)
            .with_artifact("tiny")
    }

    /// ResNet-200 (~64.7M params, ~30 GFLOPs/img fwd at 224^2).
    pub fn resnet200() -> Self {
        ModelSpec {
            name: "ResNet-200".into(),
            family: Family::Vision,
            params: 64.7e6,
            layers: 200,
            hidden: 2048,
            tokens_per_sample: 49, // 7x7 final grid, used only for ratios
            flops_per_sample: 3.0 * 30e9, // fwd+bwd
            act_bytes_per_sample: 250e6,  // deep CNN activations dominate
            artifact: Some("tiny".into()),
        }
    }

    fn with_artifact(mut self, a: &str) -> Self {
        self.artifact = Some(a.to_string());
        self
    }

    /// Training FLOPs for a whole mini-batch.
    pub fn flops_per_step(&self, batch: u32) -> f64 {
        self.flops_per_sample * batch as f64
    }

    /// Activation bytes for a whole mini-batch (per replica).
    pub fn act_bytes(&self, batch: u32) -> f64 {
        self.act_bytes_per_sample * batch as f64
    }

    /// Mixed-precision AdamW training state: fp32 master + grad + m + v
    /// (16 B) plus bf16 weight/grad working copies (4 B) = 20 bytes/param.
    pub fn state_bytes(&self) -> f64 {
        20.0 * self.params
    }

    /// Bytes crossing a pipeline-stage boundary per sample (bf16 acts).
    pub fn boundary_bytes_per_sample(&self) -> f64 {
        2.0 * self.hidden as f64 * self.tokens_per_sample as f64
    }
}

/// Dataset spec: enough to turn epochs into steps.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSpec {
    pub name: String,
    pub samples: u64,
}

impl DatasetSpec {
    /// WikiText-2: ~2.4M training tokens -> sequences of 1024 tokens.
    pub fn wikitext2() -> Self {
        DatasetSpec { name: "WikiText-2".into(), samples: 2_400 }
    }

    /// ImageNet-1k: 1.28M training images.
    pub fn imagenet() -> Self {
        DatasetSpec { name: "ImageNet".into(), samples: 1_281_167 }
    }

    pub fn steps_per_epoch(&self, batch: u32) -> u64 {
        (self.samples + batch as u64 - 1) / batch as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_param_counts() {
        assert!((ModelSpec::gpt2_xl().params - 1.5e9).abs() < 1e8);
        assert!((ModelSpec::gpt_j().params - 6.05e9).abs() < 1e8);
        assert!(ModelSpec::vit_g().params > 1.5e9);
        assert!(ModelSpec::resnet200().params < 1e8);
    }

    #[test]
    fn flops_scale_with_batch() {
        let m = ModelSpec::gpt2_xl();
        assert!((m.flops_per_step(32) / m.flops_per_step(16) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gptj_costs_more_than_gpt2() {
        let a = ModelSpec::gpt2_xl().flops_per_step(16);
        let b = ModelSpec::gpt_j().flops_per_step(16);
        assert!(b > 2.0 * a);
    }

    #[test]
    fn state_bytes_rule() {
        let m = ModelSpec::gpt2_xl();
        assert!((m.state_bytes() - 30e9).abs() < 1e9); // 1.5B * 20B
    }

    #[test]
    fn epochs_to_steps() {
        let d = DatasetSpec::imagenet();
        assert_eq!(d.steps_per_epoch(128), 10_010);
        let w = DatasetSpec::wikitext2();
        assert_eq!(w.steps_per_epoch(16), 150);
    }

    #[test]
    fn gpt2_memory_exceeds_single_a100() {
        // the premise of the paper: these models do NOT fit one GPU with DDP
        let m = ModelSpec::gpt2_xl();
        let usable = crate::cluster::GpuSpec::a100_40gb().usable_bytes();
        assert!(m.state_bytes() + m.act_bytes(2) > usable);
    }
}
