//! Model zoo: analytic specs of the paper's evaluation models plus the
//! runnable GPT-mini variants whose AOT artifacts live in `artifacts/`.

pub mod zoo;

pub use zoo::{DatasetSpec, Family, ModelSpec};
