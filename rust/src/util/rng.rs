//! Deterministic PRNG + distributions.
//!
//! The offline crate set has no `rand`, so this is a first-class substrate:
//! a SplitMix64 generator (Steele et al., "Fast Splittable Pseudorandom
//! Number Generators") with the distribution helpers the simulator,
//! workload generator and property-testing framework need. Everything is
//! seedable and reproducible — simulator runs and experiments cite their
//! seeds in EXPERIMENTS.md.

/// SplitMix64: tiny, fast, passes BigCrush when used as a 64-bit stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (for sub-tasks / jobs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (empty range -> `lo`).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        if hi <= lo {
            return lo;
        }
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    pub fn usize(&mut self, n: usize) -> usize {
        if n == 0 { 0 } else { (self.next_u64() % n as u64) as usize }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Log-normal with underlying mean/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (used by the
    /// synthetic WikiText-like token stream).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the harmonic weights; O(n) setup avoided by
        // rejection sampling (Devroye) for large n.
        debug_assert!(n > 0);
        loop {
            let u = self.f64();
            let v = self.f64();
            let x = ((n as f64 + 1.0).powf(1.0 - s) * u + 1.0 - u)
                .powf(1.0 / (1.0 - s));
            let k = x.floor().max(1.0);
            let ratio = (1.0 + 1.0 / k).powf(s - 1.0) * k / (n as f64 + 1.0);
            let t = (1.0 + 1.0 / n as f64).powf(s - 1.0);
            if v * k * (t - 1.0) / (t * ratio.max(1e-300)) <= 1.0 && k <= n as f64 {
                return k as usize - 1;
            }
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::new(1);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let x = r.range(-5, 12);
            assert!((-5..12).contains(&x));
        }
        assert_eq!(r.range(3, 3), 3);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_skewed_and_in_range() {
        let mut r = Rng::new(6);
        let n = 100;
        let mut counts = vec![0usize; n];
        for _ in 0..20_000 {
            counts[r.zipf(n, 1.2)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[n - 1]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
