//! Minimal JSON parser + writer (substrate: no `serde` in the offline set).
//!
//! Used for the artifact manifest produced by `python/compile/aot.py`,
//! experiment result dumps, and checkpoint metadata. Covers the full JSON
//! grammar (RFC 8259) minus `\u` surrogate pairs outside the BMP.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code)
                            .ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    let chunk = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"nested":{"x":-2}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
