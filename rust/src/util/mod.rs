//! Infrastructure substrates built in-repo (the offline crate set has no
//! serde/clap/rand/tokio/proptest — see DESIGN.md §2).

pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
