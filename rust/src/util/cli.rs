//! Tiny CLI argument parser (substrate: no `clap` in the offline set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Used by `main.rs` and every example binary.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_forms() {
        // NB: a bare `--flag` greedily consumes a following non-flag token
        // as its value; boolean flags next to positionals use `--flag=true`
        // (documented semantics, asserted by flag_before_positional below).
        let a = parse("run extra --x 3 --y=4 --verbose");
        assert_eq!(a.usize_or("x", 0), 3);
        assert_eq!(a.usize_or("y", 0), 4);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.positional, vec!["run", "extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.f64_or("lr", 1e-3), 1e-3);
        assert_eq!(a.str_or("name", "d"), "d");
        assert!(!a.has("missing"));
    }

    #[test]
    fn flag_before_positional() {
        // `--flag positional` consumes the positional as value; the
        // documented workaround is `--flag=true`.
        let a = parse("--dry=true go");
        assert!(a.bool_or("dry", false));
        assert_eq!(a.positional, vec!["go"]);
    }

    #[test]
    fn negative_number_values() {
        let a = parse("--offset -3");
        assert_eq!(a.f64_or("offset", 0.0), -3.0);
    }
}
