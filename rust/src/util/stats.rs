//! Streaming statistics + percentile helpers (used by the bench harness,
//! the trial runner, and simulator telemetry).

/// Welford online mean/variance accumulator.
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample set (linear interpolation, `q` in [0,1]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { f64::NAN } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Geometric mean (for speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fixed-width ASCII histogram (simulator/e2e telemetry dumps).
pub fn ascii_histogram(xs: &[f64], bins: usize, width: usize) -> String {
    if xs.is_empty() || bins == 0 {
        return String::new();
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let i = (((x - lo) / span) * bins as f64) as usize;
        counts[i.min(bins - 1)] += 1;
    }
    let maxc = *counts.iter().max().unwrap() as f64;
    let mut out = String::new();
    for (i, &c) in counts.iter().enumerate() {
        let b_lo = lo + span * i as f64 / bins as f64;
        let bar = "#".repeat(((c as f64 / maxc) * width as f64).round() as usize);
        out.push_str(&format!("{b_lo:>12.4} | {bar} {c}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&xs, 0.5) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_smoke() {
        let xs: Vec<f64> = (0..100).map(|x| x as f64 / 10.0).collect();
        let h = ascii_histogram(&xs, 5, 20);
        assert_eq!(h.lines().count(), 5);
    }
}
