//! Fixed-size thread pool (substrate: no `tokio`/`rayon` in the offline set).
//!
//! Used by the Trial Runner to profile several (model, parallelism, gpus)
//! combinations concurrently and by the coordinator's executor lanes in
//! `examples/e2e_train.rs`. Plain `std::thread` + MPSC channels; `scope_map`
//! offers a rayon-like parallel map.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("saturn-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool alive");
    }

    /// Parallel map preserving input order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|x| x.expect("worker completed")).collect()
    }
}

/// Rayon-like parallel map over BORROWED data via `std::thread::scope`:
/// no `'static` bound, so callers can capture references to stack state
/// (the MILP hands out `&Simplex` plus per-node bound vectors). Spawns up
/// to `threads` scoped workers, each mapping a strided share of `items`;
/// the output order always matches the input order, so a deterministic
/// caller gets identical results for every thread count (including 1,
/// which short-circuits to a plain sequential map).
pub fn scope_map<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len());
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let mut shares: Vec<Vec<(usize, T)>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        shares[i % threads].push((i, item));
    }
    let f = &f;
    let per_worker: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let handles: Vec<_> = shares
            .into_iter()
            .map(|share| {
                s.spawn(move || {
                    share
                        .into_iter()
                        .map(|(i, t)| (i, f(t)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scope_map worker"))
            .collect()
    });
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in per_worker.into_iter().flatten() {
        out[i] = Some(r);
    }
    out.into_iter().map(|x| x.expect("all indices mapped")).collect()
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..64 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..100).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn scope_map_borrows_and_preserves_order() {
        let base: Vec<i64> = (0..97).collect();
        // closure borrows `base` from the stack — no 'static anywhere
        let f = |i: usize| base[i] * base[i];
        let serial = scope_map(1, (0..97).collect::<Vec<usize>>(), f);
        for threads in [2usize, 3, 8] {
            let parallel = scope_map(threads, (0..97).collect(), f);
            assert_eq!(parallel, serial, "threads={threads}");
        }
        assert_eq!(serial[10], 100);
    }

    #[test]
    fn scope_map_handles_empty_and_tiny_inputs() {
        let out: Vec<i32> = scope_map(4, Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
        let out = scope_map(4, vec![7], |x: i32| x + 1);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(20)));
        drop(pool); // must not hang or panic
    }
}
