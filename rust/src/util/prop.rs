//! Miniature property-based testing framework (substrate: no `proptest`).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` random inputs
//! drawn by `gen`; on failure it greedily shrinks the input via the
//! strategy's `shrink` candidates and reports the minimal counterexample
//! with the seed needed to replay it.
//!
//! Used across the repo for solver/scheduler/cost-model invariants — see
//! `rust/tests/prop_invariants.rs`.

use crate::util::rng::Rng;

/// A generation + shrinking strategy for `T`.
pub trait Strategy {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate "smaller" values; empty when fully shrunk.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run a property over random inputs; panics with the minimal failing case.
pub fn forall<S, P>(seed: u64, cases: usize, strat: &S, prop: P)
where
    S: Strategy,
    P: Fn(&S::Value) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = strat.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            let (min_v, min_msg) = shrink_loop(strat, v, msg, &prop);
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {min_v:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<S, P>(strat: &S, mut v: S::Value, mut msg: String, prop: &P)
    -> (S::Value, String)
where
    S: Strategy,
    P: Fn(&S::Value) -> Result<(), String>,
{
    // Greedy: take the first shrink candidate that still fails; bound work.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in strat.shrink(&v) {
            if let Err(m) = prop(&cand) {
                v = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (v, msg)
}

// ---------------------------------------------------------------------------
// Built-in strategies
// ---------------------------------------------------------------------------

/// Uniform `i64` in `[lo, hi]`, shrinking toward `lo`.
pub struct IntRange(pub i64, pub i64);

impl Strategy for IntRange {
    type Value = i64;

    fn generate(&self, rng: &mut Rng) -> i64 {
        rng.range(self.0, self.1 + 1)
    }

    fn shrink(&self, v: &i64) -> Vec<i64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform `f64` in `[lo, hi)`, shrinking toward `lo`.
pub struct FloatRange(pub f64, pub f64);

impl Strategy for FloatRange {
    type Value = f64;

    fn generate(&self, rng: &mut Rng) -> f64 {
        self.0 + rng.f64() * (self.1 - self.0)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        if (*v - self.0).abs() < 1e-9 {
            Vec::new()
        } else {
            vec![self.0, self.0 + (*v - self.0) / 2.0]
        }
    }
}

/// Vector of `inner` with length in `[min_len, max_len]`; shrinks by
/// halving the tail and element-wise shrinking of the first offender.
pub struct VecOf<S> {
    pub inner: S,
    pub min_len: usize,
    pub max_len: usize,
}

impl<S: Strategy> Strategy for VecOf<S> {
    type Value = Vec<S::Value>;

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let len = self.min_len + rng.usize(self.max_len - self.min_len + 1);
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..self.min_len].to_vec());
            out.push(v[..self.min_len + (v.len() - self.min_len) / 2].to_vec());
            let mut drop_last = v.clone();
            drop_last.pop();
            out.push(drop_last);
        }
        for (i, x) in v.iter().enumerate() {
            for cand in self.inner.shrink(x) {
                let mut w = v.clone();
                w[i] = cand;
                out.push(w);
                break; // only the first shrink per index; keeps it O(n)
            }
        }
        out
    }
}

/// Pair strategy.
pub struct PairOf<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for PairOf<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(1, 200, &IntRange(0, 100), |&x| {
            if (0..=100).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 200, &IntRange(0, 100), |&x| {
            if x < 50 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn shrinks_to_minimal_counterexample() {
        let caught = std::panic::catch_unwind(|| {
            forall(3, 500, &IntRange(0, 1000), |&x| {
                if x < 123 {
                    Ok(())
                } else {
                    Err("ge 123".into())
                }
            });
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // greedy halving shrink should land near the boundary (not at 1000)
        assert!(msg.contains("input: 123") || msg.contains("input: 12"),
                "unexpected shrink result: {msg}");
    }

    #[test]
    fn vec_strategy_respects_bounds() {
        let strat = VecOf { inner: IntRange(0, 9), min_len: 2, max_len: 6 };
        forall(4, 100, &strat, |v| {
            if (2..=6).contains(&v.len()) && v.iter().all(|x| (0..=9).contains(x)) {
                Ok(())
            } else {
                Err(format!("bad vec {v:?}"))
            }
        });
    }
}
