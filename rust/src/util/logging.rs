//! Leveled stderr logger wired to the `log` crate facade.
//!
//! `SATURN_LOG=debug|info|warn|error` selects the level (default `info`).
//! Timestamps are monotonic seconds since process start — enough for
//! correlating coordinator/executor events without pulling in `chrono`.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERR ",
                Level::Warn => "WARN",
                Level::Info => "INFO",
                Level::Debug => "DBG ",
                Level::Trace => "TRC ",
            };
            eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Called by `main.rs` and examples.
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    if log::set_logger(logger).is_ok() {
        let level = match std::env::var("SATURN_LOG").as_deref() {
            Ok("trace") => LevelFilter::Trace,
            Ok("debug") => LevelFilter::Debug,
            Ok("warn") => LevelFilter::Warn,
            Ok("error") => LevelFilter::Error,
            _ => LevelFilter::Info,
        };
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
