//! Leveled stderr logger wired to the `log` crate facade.
//!
//! `SATURN_LOG` selects levels, `env_logger`-style: a bare level
//! (`debug`) sets the default, and comma-separated
//! `module::path=level` entries override it per module prefix —
//! `SATURN_LOG=info,saturn::solver=debug` keeps the process at `info`
//! while the solver logs at `debug`. Longest matching prefix wins, and
//! a prefix only matches at a `::` boundary (`saturn::sim` does not
//! capture `saturn::simulate`). Default `info`.
//!
//! Timestamps are monotonic seconds since process start — enough for
//! correlating coordinator/executor events without pulling in `chrono`.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    start: Instant,
    default: LevelFilter,
    /// Per-module overrides, longest prefix first.
    modules: Vec<(String, LevelFilter)>,
}

fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.trim() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Parse a `SATURN_LOG` spec into (default level, per-module overrides).
/// Unrecognized fragments are ignored rather than erroring — a logging
/// knob must never take the process down.
fn parse_spec(spec: &str) -> (LevelFilter, Vec<(String, LevelFilter)>) {
    let mut default = LevelFilter::Info;
    let mut modules: Vec<(String, LevelFilter)> = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('=') {
            None => {
                if let Some(lvl) = parse_level(part) {
                    default = lvl;
                }
            }
            Some((module, lvl)) => {
                if let Some(lvl) = parse_level(lvl) {
                    let module = module.trim();
                    if !module.is_empty() {
                        modules.push((module.to_string(), lvl));
                    }
                }
            }
        }
    }
    // longest prefix first so the most specific override wins
    modules.sort_by(|a, b| {
        b.0.len().cmp(&a.0.len()).then_with(|| a.0.cmp(&b.0))
    });
    (default, modules)
}

impl StderrLogger {
    /// Effective level for a log target: the longest module override
    /// whose prefix matches at a path boundary, else the default.
    fn level_for(&self, target: &str) -> LevelFilter {
        for (prefix, lvl) in &self.modules {
            if let Some(rest) = target.strip_prefix(prefix.as_str()) {
                if rest.is_empty() || rest.starts_with("::") {
                    return *lvl;
                }
            }
        }
        self.default
    }
}

impl log::Log for StderrLogger {
    fn enabled(&self, meta: &Metadata) -> bool {
        meta.level() <= self.level_for(meta.target())
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERR ",
                Level::Warn => "WARN",
                Level::Info => "INFO",
                Level::Debug => "DBG ",
                Level::Trace => "TRC ",
            };
            eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Called by `main.rs` and examples.
pub fn init() {
    let logger = LOGGER.get_or_init(|| {
        let spec = std::env::var("SATURN_LOG").unwrap_or_default();
        let (default, modules) = parse_spec(&spec);
        StderrLogger { start: Instant::now(), default, modules }
    });
    if log::set_logger(logger).is_ok() {
        // the facade's fast-path gate must admit the most verbose
        // module; per-target filtering happens in `enabled`
        let max = logger
            .modules
            .iter()
            .map(|&(_, lvl)| lvl)
            .fold(logger.default, |a, b| a.max(b));
        log::set_max_level(max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }

    #[test]
    fn bare_level_sets_the_default() {
        let (default, modules) = parse_spec("debug");
        assert_eq!(default, LevelFilter::Debug);
        assert!(modules.is_empty());
    }

    #[test]
    fn per_module_overrides_parse_and_apply() {
        let (default, modules) =
            parse_spec("info,saturn::solver=debug,saturn=warn");
        assert_eq!(default, LevelFilter::Info);
        let lg = StderrLogger {
            start: Instant::now(),
            default,
            modules,
        };
        assert_eq!(lg.level_for("saturn::solver"), LevelFilter::Debug);
        assert_eq!(lg.level_for("saturn::solver::milp"),
                   LevelFilter::Debug);
        assert_eq!(lg.level_for("saturn::sim"), LevelFilter::Warn);
        assert_eq!(lg.level_for("other::crate"), LevelFilter::Info);
    }

    #[test]
    fn prefixes_match_only_at_path_boundaries() {
        let (default, modules) = parse_spec("info,saturn::sim=trace");
        let lg = StderrLogger {
            start: Instant::now(),
            default,
            modules,
        };
        assert_eq!(lg.level_for("saturn::sim"), LevelFilter::Trace);
        assert_eq!(lg.level_for("saturn::sim::engine"),
                   LevelFilter::Trace);
        // NOT a boundary match: simulate != sim::*
        assert_eq!(lg.level_for("saturn::simulate"), LevelFilter::Info);
    }

    #[test]
    fn targets_shorter_than_a_prefix_do_not_panic() {
        let (default, modules) = parse_spec("info,saturn::solver=debug");
        let lg = StderrLogger {
            start: Instant::now(),
            default,
            modules,
        };
        // shorter than the override prefix: must fall back, not slice
        assert_eq!(lg.level_for("saturn"), LevelFilter::Info);
        assert_eq!(lg.level_for("saturn::perf"), LevelFilter::Info);
        assert_eq!(lg.level_for(""), LevelFilter::Info);
    }

    #[test]
    fn garbage_fragments_are_ignored() {
        let (default, modules) =
            parse_spec("bogus,=debug,saturn=notalevel,warn");
        assert_eq!(default, LevelFilter::Warn);
        assert!(modules.is_empty());
    }
}
