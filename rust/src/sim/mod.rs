//! Discrete-event GPU-cluster simulator (hardware-substitution substrate).
//!
//! Executes a multi-job under a scheduling `Policy`, reproducing exactly
//! what determines Table 2's makespans: per-job runtimes from the Trial
//! Runner's estimates, GPU capacity over time, node placement rules, and
//! Gandiva-style checkpoint/restart penalties on introspective replans.

pub mod engine;
pub mod placement;

pub use engine::{simulate, simulate_online, simulate_online_perf,
                 JobProgress, Launch, OnlineSimResult, PlanContext, Policy,
                 Running, RungConfig, SimConfig, SimResult};
pub use placement::{FreeState, Placement};
