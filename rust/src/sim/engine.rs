//! The simulation engine: advances virtual time between job completions
//! and introspection points, asks the `Policy` for launch decisions, and
//! enforces capacity/placement/checkpoint semantics.
//!
//! Three entrypoints share one event loop:
//!  * [`simulate`] — the paper's batch setting: every job known at t=0.
//!  * [`simulate_online`] — the streaming setting (DESIGN.md §Online):
//!    jobs arrive over virtual time, ASHA-style rung boundaries early-stop
//!    the worst fraction of each HPO grid, and policies may opt into
//!    preempt-and-replan on arrival/departure events (checkpoint penalties
//!    charged whenever a relaunched job's (technique, gpus) changed).
//!  * [`simulate_online_perf`] — the full estimate-vs-truth split
//!    (DESIGN.md §4.4): running jobs are charged the [`PerfModel`]'s TRUE
//!    step times (truth is read here and nowhere else), while policies
//!    plan against its estimate table; wherever progress is banked the
//!    engine emits [`Observation`] records that feed the estimate's
//!    online correction. The other two entrypoints are zero-drift
//!    wrappers and remain bit-identical to the pre-split engine
//!    (`tests/prop_drift.rs` holds them to it).
//!
//! Determinism: given the same policy (and policy seed), the simulation is
//! bit-reproducible — Table 2 rows in EXPERIMENTS.md cite seeds, and the
//! `online` CLI replays traces to bit-identical schedules. Drift is a
//! pure function of `(job, class, time, seed)`, so this holds with the
//! perf split too.

use crate::cluster::ClusterSpec;
use crate::faults::{FaultConfig, FaultModel};
use crate::objective::Objective;
use crate::obs::metrics::Histogram;
use crate::obs::trace::Tracer;
use crate::perf::{Observation, PerfModel};
use crate::sim::placement::{FreeState, Placement};
use crate::trials::ProfileTable;
use crate::util::json::Json;
use crate::workload::arrivals::OnlineJob;
use crate::workload::Job;

use std::time::Instant;

/// A policy's decision: run `job_id` with `tech` on `gpus` GPUs of one
/// GPU `class`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Launch {
    pub job_id: usize,
    pub tech: usize,
    pub gpus: u32,
    pub class: usize,
}

/// A job currently holding GPUs.
#[derive(Debug, Clone)]
pub struct Running {
    pub tech: usize,
    pub gpus: u32,
    pub class: usize,
    pub placement: Vec<Placement>,
    pub step_time: f64,
    /// Virtual time at which steps start accumulating (start + restart lag).
    pub resume_at: f64,
    pub planned_finish: f64,
    /// Seconds of this stint already reported to the estimate layer
    /// (surviving rung boundaries observe incrementally, so later
    /// observations of the same stint never re-count earlier steps).
    pub observed_s: f64,
}

/// Job + live progress (+ online metadata; batch mode uses the defaults).
#[derive(Debug, Clone)]
pub struct JobProgress {
    pub job: Job,
    pub steps_done: u64,
    pub running: Option<Running>,
    pub finished_at: Option<f64>,
    /// Last (tech, gpus, class) this job ran under (checkpoint-penalty
    /// detection — a class move is a migration like any other reshape).
    pub last_alloc: Option<(usize, u32, usize)>,
    /// Virtual time at which the job becomes schedulable (0 in batch mode).
    pub arrival_s: f64,
    /// Flipped by the engine once virtual time reaches `arrival_s`.
    pub arrived: bool,
    /// Killed by an early-stopping rung rather than trained to completion.
    pub early_stopped: bool,
    /// Multi-job (HPO grid) this job belongs to; rung kills rank in-group.
    pub group: usize,
    /// Tenant priority weight (>= 1.0; online policies launch high first).
    pub priority: f64,
    /// Optional completion deadline, seconds after arrival.
    pub deadline_s: Option<f64>,
    /// Latent validation score (higher = better) driving rung kills.
    pub score: f64,
    /// Next index into `RungConfig::fractions` this job has yet to cross.
    next_rung: usize,
    /// A fault kill rolled this job back to its last checkpoint: its
    /// next launch pays the class reload penalty even if the allocation
    /// shape is unchanged (restart-from-checkpoint is never free).
    needs_reload: bool,
    /// When the pending fault-kill happened (recovery-latency clock,
    /// cleared at the next successful launch).
    fault_preempted_at: Option<f64>,
}

impl JobProgress {
    pub fn remaining_steps(&self) -> u64 {
        self.job.total_steps().saturating_sub(self.steps_done)
    }

    pub fn is_pending(&self) -> bool {
        self.arrived && self.finished_at.is_none() && self.running.is_none()
    }
}

/// Why the engine is asking the policy to (re)plan right now — the
/// flight recorder's cause attribution for re-solve episodes. When an
/// instant carries several event kinds the strongest wins
/// (failure > introspection > arrival > departure).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplanCause {
    /// The t=0 planning call.
    Initial,
    /// A job arrived at this instant.
    Arrival,
    /// A job departed (completion or rung kill) at this instant.
    Departure,
    /// A periodic introspection point (preempt-everything replan).
    Introspection,
    /// Nothing runnable: the engine force-planned to avoid deadlock.
    Idle,
    /// An event instant that changed no membership (e.g. a surviving
    /// rung crossing).
    Tick,
    /// A fault-layer event at this instant: a node died (jobs on it
    /// rolled back to checkpoint), a node came back, or a job crashed.
    Failure,
}

impl ReplanCause {
    pub fn name(self) -> &'static str {
        match self {
            ReplanCause::Initial => "initial",
            ReplanCause::Arrival => "arrival",
            ReplanCause::Departure => "departure",
            ReplanCause::Introspection => "introspection",
            ReplanCause::Idle => "idle",
            ReplanCause::Tick => "tick",
            ReplanCause::Failure => "failure",
        }
    }
}

/// Everything a policy may look at when planning. `profiles` is the
/// planner-facing ESTIMATE table (the perf layer's belief, never the
/// truth) — Saturn and every baseline observe the cluster through the
/// same interface, so comparisons stay fair under drift.
pub struct PlanContext<'a> {
    pub now: f64,
    pub jobs: &'a [JobProgress],
    pub free: &'a FreeState,
    pub profiles: &'a ProfileTable,
    pub cluster: &'a ClusterSpec,
    /// The scheduling objective every system competes under
    /// ([`SimConfig::objective`]): Saturn threads it into the joint
    /// MILP, baselines use it for queue ordering. `Makespan` reproduces
    /// the historical behavior of every policy bit for bit.
    pub objective: Objective,
    /// Observations delivered to the estimate layer so far (monotone).
    /// Policies snapshot this to detect "new evidence since my last
    /// solve" for drift-triggered re-solves.
    pub obs_seen: usize,
    /// Worst current |ln(observed/estimated)| across jobs' latest
    /// observations — zero while estimates are perfect (e.g. no drift).
    pub drift_alarm: f64,
    /// Why this planning call fired (trace cause attribution).
    pub cause: ReplanCause,
    /// Flight-recorder sink ([`SimConfig::trace`]); policies stamp
    /// re-solve spans through it. Off (no-op) by default.
    pub trace: &'a Tracer,
}

/// Scheduling policy plugged into the simulator (Saturn + all baselines).
pub trait Policy {
    fn name(&self) -> &'static str;

    /// Called at t=0, after every completion, and at each introspection
    /// point. Returns desired launches for PENDING jobs; at introspection
    /// points it is called with ALL unfinished jobs marked pending
    /// (preempt-and-replan semantics) and may reassign freely.
    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch>;

    /// `Some(interval)` enables Gandiva-style introspection every
    /// `interval` virtual seconds.
    fn introspection_interval(&self) -> Option<f64> {
        None
    }

    /// Online mode: when true, arrival and departure events ALSO trigger
    /// preempt-and-replan (all unfinished jobs offered back to the policy;
    /// checkpoint lag charged only where the allocation shape changes).
    fn replan_on_events(&self) -> bool {
        false
    }

    /// Cumulative wall-clock seconds the policy spent deciding (solver
    /// cost reporting, bench E9).
    fn decision_time_s(&self) -> f64 {
        0.0
    }

    /// Solver stress counters accumulated across the run, as
    /// `(lp_capped, milp_limit_reached)`: node LPs that hit the simplex
    /// iteration cap, and MILP solves stopped by a node/time limit.
    /// Zero for solver-free policies; surfaced in [`OnlineSimResult`] so
    /// silent plan degradation under event-rate re-solving is visible.
    fn solver_pressure(&self) -> (usize, usize) {
        (0, 0)
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Seconds charged when a running job is checkpointed and relaunched
    /// under a different allocation WITHIN the same GPU class — a
    /// reshape-in-place that re-shards optimizer state among overlapping
    /// ranks (Gandiva/AntMan-style migration). Cross-class moves charge
    /// the destination class's cheaper
    /// [`crate::cluster::GpuClass::reload_penalty_s`] instead: a clean
    /// sequential checkpoint stream over the destination's PCIe.
    pub checkpoint_penalty_s: f64,
    /// Safety valve for runaway simulations.
    pub max_virtual_time_s: f64,
    /// Scheduling objective handed to every policy via
    /// [`PlanContext::objective`] (see `objective::Objective`).
    pub objective: Objective,
    /// Flight-recorder sink. `Tracer::off()` (the default) makes every
    /// emission a no-op and keeps replays bit-identical to untraced
    /// builds; wall stamps never feed back into scheduling decisions.
    pub trace: Tracer,
    /// Seeded fault injection (DESIGN.md §4.7). `FaultConfig::none()`
    /// (the default) keeps the engine bit-identical to the fault-free
    /// build — no fault model is even constructed.
    pub faults: FaultConfig,
    /// Periodic checkpoint cadence, virtual seconds: a fault kill rolls
    /// a stint's progress back to the last multiple of this interval
    /// (work past it is lost and re-run). `0` means continuous
    /// checkpointing — fault kills lose nothing. Planned preemptions
    /// (introspection/replan) still checkpoint exactly, as before.
    pub checkpoint_interval_s: f64,
    /// Event-coalescing debounce window, virtual seconds
    /// (`--coalesce-window-s`). When an instant carries ONLY arrivals
    /// and another arrival lands within this window — before any other
    /// event — the replan is deferred so an HPO cohort's burst of
    /// sibling arrivals folds into ONE re-solve. `0` (the default)
    /// replans at every arrival instant, bit-identical to the
    /// historical engine.
    pub coalesce_window_s: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            checkpoint_penalty_s: 60.0,
            max_virtual_time_s: 1e9,
            objective: Objective::Makespan,
            trace: Tracer::off(),
            faults: FaultConfig::none(),
            checkpoint_interval_s: 1800.0,
            coalesce_window_s: 0.0,
        }
    }
}

/// Early-stopping rule for streaming HPO grids (successive-halving rungs,
/// applied asynchronously as each job reaches a rung — ASHA).
#[derive(Debug, Clone)]
pub struct RungConfig {
    /// Progress fractions in (0, 1), ascending, at which jobs hit rungs.
    pub fractions: Vec<f64>,
    /// Fraction of each rung cohort killed (worst scores first), in [0, 1).
    pub kill_fraction: f64,
}

impl RungConfig {
    /// Two rungs at 25%/50% progress killing the worst half seen so far —
    /// the classic eta=2 successive-halving shape.
    pub fn halving() -> Self {
        RungConfig { fractions: vec![0.25, 0.5], kill_fraction: 0.5 }
    }
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan_s: f64,
    pub finish_times: Vec<(usize, f64)>,
    pub preemptions: usize,
    /// busy GPU-seconds / (total GPUs * makespan)
    pub gpu_utilization: f64,
    pub launches: usize,
    pub policy_decision_s: f64,
}

/// Result of an online (streaming) simulation.
#[derive(Debug, Clone)]
pub struct OnlineSimResult {
    /// Last departure (completion or rung kill) time.
    pub makespan_s: f64,
    /// Departure time per job, in job-id order (kills included).
    pub finish_times: Vec<(usize, f64)>,
    /// Job completion time (departure - arrival) per job, job-id order.
    pub jct_s: Vec<(usize, f64)>,
    /// Jobs trained to completion.
    pub completed: Vec<usize>,
    /// Jobs killed at a rung boundary.
    pub early_stopped: Vec<usize>,
    /// Completed jobs that blew their deadline.
    pub deadline_misses: usize,
    /// Sum over completed deadlined jobs of `(finish - deadline)+`,
    /// seconds — the tardiness currency the `tardiness` objective
    /// minimizes (early-stopped jobs count 0, like `deadline_misses`).
    pub total_tardiness_s: f64,
    /// Priority-weighted mean tardiness: `sum_j w_j T_j / sum_j w_j`
    /// over ALL jobs (deadline-less and early-stopped jobs count 0) —
    /// the same denominator as the weighted-JCT metric.
    pub weighted_tardiness_s: f64,
    /// Running jobs whose allocation changed across a replan.
    pub preemptions: usize,
    /// Launches that paid the checkpoint/restart penalty.
    pub migrations: usize,
    /// busy GPU-seconds / (total GPUs * makespan)
    pub gpu_utilization: f64,
    /// Max GPUs simultaneously busy (capacity invariant diagnostics).
    pub peak_gpus: u32,
    pub launches: usize,
    pub policy_decision_s: f64,
    /// Median / 99th-percentile wall latency of a single policy
    /// decision (`Policy::plan` call), from the engine's log-bucketed
    /// histogram — the ROADMAP's service-loop metric. 0.0 when the
    /// policy was never called.
    pub decision_p50_s: f64,
    pub decision_p99_s: f64,
    /// Node LPs that hit the simplex iteration cap across the policy's
    /// solves ([`Policy::solver_pressure`]) — solver stress under
    /// event-rate re-solving, not silent degradation.
    pub lp_capped: usize,
    /// MILP solves stopped by a node/time limit across the run.
    pub milp_limit_reached: usize,
    /// Observations the engine delivered to the estimate layer.
    pub observations: usize,
    /// Mean |ln(observed/estimated)| across those observations — the
    /// run's realized estimate error (0.0 without drift).
    pub estimate_mae: f64,
    /// Node-down events the run actually hit (fault layer; 0 without
    /// faults).
    pub failures: usize,
    /// Node-repair events the run actually hit.
    pub repairs: usize,
    /// Jobs killed by a node death or crash hazard (rolled back to
    /// their last checkpoint).
    pub fault_preemptions: usize,
    /// GPU-seconds of work re-run because fault kills rolled progress
    /// back past the last checkpoint.
    pub lost_work_gpu_s: f64,
    /// Mean seconds from a fault kill to the victim's next launch.
    pub mean_recovery_s: f64,
    /// (busy - lost) GPU-seconds / (total GPUs * makespan): utilization
    /// counting only work that stuck. Equals `gpu_utilization` bit for
    /// bit when faults are off.
    pub goodput: f64,
    /// Arrival instants whose replan was deferred into a later one by
    /// the coalescing window (0 when `coalesce_window_s` is 0).
    pub coalesced_events: usize,
}

impl OnlineSimResult {
    pub fn avg_jct_s(&self) -> f64 {
        if self.jct_s.is_empty() {
            return 0.0;
        }
        self.jct_s.iter().map(|(_, j)| j).sum::<f64>() / self.jct_s.len() as f64
    }

    pub fn p95_jct_s(&self) -> f64 {
        let xs: Vec<f64> = self.jct_s.iter().map(|&(_, j)| j).collect();
        crate::util::stats::percentile(&xs, 0.95)
    }
}

/// Run `jobs` to completion under `policy` (batch mode: all jobs known at
/// t=0, no early stopping). Panics if the policy deadlocks (no job running
/// and the policy refuses to launch any pending job).
pub fn simulate(jobs: &[Job], profiles: &ProfileTable, cluster: &ClusterSpec,
                policy: &mut dyn Policy, cfg: &SimConfig) -> SimResult {
    let online: Vec<OnlineJob> = jobs.iter().map(OnlineJob::batch).collect();
    let r = simulate_online(&online, None, profiles, cluster, policy, cfg);
    SimResult {
        makespan_s: r.makespan_s,
        finish_times: r.finish_times,
        preemptions: r.preemptions,
        gpu_utilization: r.gpu_utilization,
        launches: r.launches,
        policy_decision_s: r.policy_decision_s,
    }
}

/// Streaming event loop with a PERFECT performance model: truth and
/// estimate are both the profiled table (zero drift). Bit-identical to
/// the pre-split engine; see [`simulate_online_perf`] for the split.
pub fn simulate_online(jobs: &[OnlineJob], rungs: Option<&RungConfig>,
                       profiles: &ProfileTable, cluster: &ClusterSpec,
                       policy: &mut dyn Policy, cfg: &SimConfig)
    -> OnlineSimResult {
    let mut perf = PerfModel::exact(profiles);
    simulate_online_perf(jobs, rungs, &mut perf, cluster, policy, cfg)
}

/// Streaming event loop: arrivals, rung-boundary departures, completions
/// and introspection points, in deterministic order. `jobs` must carry
/// dense ids 0..n (policies index job state by id).
///
/// The estimate-vs-truth split: running jobs are charged `perf`'s TRUE
/// step times (sampled at each (re)launch instant — a stint runs at
/// constant speed, and every introspective replan re-samples the drifted
/// truth, which is the mid-run `Running::step_time` refresh); policies
/// see only `perf`'s estimate table via [`PlanContext`]. Wherever the
/// engine banks progress — completions, rung kills, preemption
/// checkpoints — it emits an [`Observation`] to the estimate layer.
pub fn simulate_online_perf(jobs: &[OnlineJob], rungs: Option<&RungConfig>,
                            perf: &mut PerfModel, cluster: &ClusterSpec,
                            policy: &mut dyn Policy, cfg: &SimConfig)
    -> OnlineSimResult {
    for (i, oj) in jobs.iter().enumerate() {
        assert_eq!(oj.job.id, i, "online jobs must have dense ids");
    }
    let mut state: Vec<JobProgress> = jobs
        .iter()
        .map(|oj| JobProgress {
            job: oj.job.clone(),
            steps_done: 0,
            running: None,
            finished_at: None,
            last_alloc: None,
            arrival_s: oj.arrival_s.max(0.0),
            arrived: oj.arrival_s <= 0.0,
            early_stopped: false,
            group: oj.group,
            priority: oj.priority.max(1e-6),
            deadline_s: oj.deadline_s,
            score: oj.score,
            next_rung: 0,
            needs_reload: false,
            fault_preempted_at: None,
        })
        .collect();
    let mut free = FreeState::new(cluster);
    // fault layer: constructed only when active, so the zero-fault path
    // adds no work (and stays bit-identical to the fault-free engine)
    let faults = cfg
        .faults
        .is_active()
        .then(|| FaultModel::new(cfg.faults.clone(), cluster));
    let mut fb = FaultBook::default();
    let mut now = 0.0f64;
    let mut preemptions = 0usize;
    let mut migrations = 0usize;
    let mut launches = 0usize;
    let mut coalesced = 0usize;
    let mut busy_gpu_seconds = 0.0f64;
    let mut peak_gpus = 0u32;
    let interval = policy.introspection_interval();
    let mut next_introspect = interval.map(|i| i.max(1.0));

    // Asynchronous-ASHA bookkeeping: scores seen so far per (group, rung).
    let n_groups = state.iter().map(|s| s.group + 1).max().unwrap_or(0);
    let n_rungs = rungs.map(|r| r.fractions.len()).unwrap_or(0);
    let mut cohorts: Vec<Vec<Vec<f64>>> =
        vec![vec![Vec::new(); n_rungs]; n_groups];

    let trace = &cfg.trace;
    let mut decision = Histogram::new();

    // initial plan over the jobs already arrived at t=0
    perf.refresh(now);
    if trace.is_enabled() {
        trace.set_time(now);
        trace.instant(
            "meta",
            "run_begin",
            Json::obj(vec![
                ("policy", Json::str(policy.name())),
                ("jobs", Json::num(state.len() as f64)),
                ("gpus", Json::num(cluster.total_gpus() as f64)),
            ]),
        );
        for s in state.iter().filter(|s| s.arrived) {
            trace.instant(
                "job",
                "arrival",
                Json::obj(vec![("job", Json::num(s.job.id as f64))]),
            );
        }
    }
    apply_plan(policy, &mut state, &mut free, perf, cluster, now,
               &mut launches, &mut migrations, cfg,
               ReplanCause::Initial, &mut decision, &mut fb);

    let max_iters = 400_000;
    for _ in 0..max_iters {
        if state.iter().all(|s| s.finished_at.is_some()) {
            break;
        }
        // --- candidate events ---------------------------------------------
        let next_finish = state
            .iter()
            .filter_map(|s| s.running.as_ref().map(|r| r.planned_finish))
            .fold(f64::INFINITY, f64::min);
        let next_arrival = state
            .iter()
            .filter(|s| !s.arrived)
            .map(|s| s.arrival_s)
            .fold(f64::INFINITY, f64::min);
        let next_rung = match rungs {
            Some(rc) => state
                .iter()
                .filter_map(|s| rung_crossing(s, rc, now))
                .fold(f64::INFINITY, f64::min),
            None => f64::INFINITY,
        };
        let next_intro = next_introspect.unwrap_or(f64::INFINITY);
        // fault-layer events: the fleet's next node fail/repair edge,
        // plus the next crash instant of any RUNNING job
        let next_fault = match &faults {
            Some(fm) => {
                let node_ev = fm
                    .next_node_event_after(now)
                    .unwrap_or(f64::INFINITY);
                state
                    .iter()
                    .filter(|s| s.running.is_some())
                    .filter_map(|s| fm.next_crash_after(s.job.id, now))
                    .fold(node_ev, f64::min)
            }
            None => f64::INFINITY,
        };
        let t_next = next_finish
            .min(next_arrival)
            .min(next_rung)
            .min(next_intro)
            .min(next_fault);

        if !t_next.is_finite() {
            // nothing running/arriving: force-plan; if still nothing, deadlock
            let before = launches;
            perf.refresh(now);
            apply_plan(policy, &mut state, &mut free, perf, cluster, now,
                       &mut launches, &mut migrations, cfg,
                       ReplanCause::Idle, &mut decision, &mut fb);
            if launches == before {
                panic!(
                    "policy '{}' deadlocked at t={now:.1}s with {} pending jobs",
                    policy.name(),
                    state.iter().filter(|s| s.is_pending()).count()
                );
            }
            continue;
        }
        assert!(t_next >= now - 1e-6, "time went backwards");
        assert!(t_next < cfg.max_virtual_time_s, "virtual time runaway");

        // accumulate busy gpu-seconds over [now, t_next)
        let busy: u32 = state
            .iter()
            .filter_map(|s| s.running.as_ref().map(|r| r.gpus))
            .sum();
        peak_gpus = peak_gpus.max(busy);
        busy_gpu_seconds += busy as f64 * (t_next - now);
        if trace.is_enabled() {
            // sample holds over [now, t_next): stamp the interval start
            let mut by_class = vec![0u32; cluster.n_classes()];
            for r in state.iter().filter_map(|s| s.running.as_ref()) {
                by_class[r.class] += r.gpus;
            }
            trace.instant(
                "metrics",
                "busy_gpus",
                Json::obj(vec![
                    ("total", Json::num(busy as f64)),
                    (
                        "by_class",
                        Json::arr(
                            by_class
                                .iter()
                                .map(|&g| Json::num(g as f64)),
                        ),
                    ),
                ]),
            );
        }
        now = t_next;
        if trace.is_enabled() {
            trace.set_time(now);
        }
        let mut arrived_now = false;
        let mut departed_now = false;

        // (1) completions due now
        for s in state.iter_mut() {
            let done_now = s
                .running
                .as_ref()
                .map(|r| (r.planned_finish - now).abs() < 1e-9)
                .unwrap_or(false);
            if done_now {
                let r = s.running.take().unwrap();
                s.steps_done = s.job.total_steps();
                s.finished_at = Some(now);
                free.release(&r.placement);
                if let Some(o) = stint_observation(&r, s.job.id, now) {
                    perf.observe(&o);
                }
                perf.retire_job(s.job.id);
                departed_now = true;
                if trace.is_enabled() {
                    trace.instant(
                        "job",
                        "complete",
                        Json::obj(vec![(
                            "job",
                            Json::num(s.job.id as f64),
                        )]),
                    );
                }
            }
        }

        // (2) rung crossings due now: rank within the cohort seen so far;
        // the worst `kill_fraction` depart early (banked and released).
        // Jobs are visited in id order, so cohort growth is deterministic.
        if let Some(rc) = rungs {
            for i in 0..state.len() {
                while let Some(t) = rung_crossing(&state[i], rc, now) {
                    if t > now + 1e-9 {
                        break;
                    }
                    let s = &mut state[i];
                    let rung = s.next_rung;
                    s.next_rung += 1;
                    let cohort = &mut cohorts[s.group][rung];
                    cohort.push(s.score);
                    let worse = cohort.iter().filter(|&&x| x < s.score).count();
                    let quota =
                        (cohort.len() as f64 * rc.kill_fraction).floor() as usize;
                    if worse < quota {
                        if let Some(r) = s.running.take() {
                            let done =
                                ((now - r.resume_at) / r.step_time).floor();
                            s.steps_done = (s.steps_done + done.max(0.0) as u64)
                                .min(s.job.total_steps());
                            free.release(&r.placement);
                            if let Some(o) =
                                stint_observation(&r, s.job.id, now)
                            {
                                perf.observe(&o);
                            }
                        }
                        s.finished_at = Some(now);
                        s.early_stopped = true;
                        perf.retire_job(s.job.id);
                        departed_now = true;
                        if trace.is_enabled() {
                            trace.instant(
                                "job",
                                "rung_kill",
                                Json::obj(vec![
                                    ("job", Json::num(s.job.id as f64)),
                                    ("rung", Json::num(rung as f64)),
                                ]),
                            );
                        }
                    } else if let Some(r) = s.running.as_mut() {
                        // survivor at a rung boundary: the natural point
                        // a real system reads step timings — observe the
                        // stint INCREMENT since the last report, then
                        // mark it reported
                        if let Some(o) = stint_observation(r, s.job.id, now)
                        {
                            perf.observe(&o);
                            r.observed_s = now - r.resume_at;
                        }
                        if trace.is_enabled() {
                            trace.instant(
                                "job",
                                "rung_cross",
                                Json::obj(vec![
                                    ("job", Json::num(s.job.id as f64)),
                                    ("rung", Json::num(rung as f64)),
                                ]),
                            );
                        }
                    }
                }
            }
        }

        // (3) arrivals due now
        for s in state.iter_mut() {
            if !s.arrived && s.arrival_s <= now + 1e-9 {
                s.arrived = true;
                arrived_now = true;
                if trace.is_enabled() {
                    trace.instant(
                        "job",
                        "arrival",
                        Json::obj(vec![(
                            "job",
                            Json::num(s.job.id as f64),
                        )]),
                    );
                }
            }
        }

        // (3.5) fault sync: reconcile the fleet with the fault model's
        // pure view at `now`. State comparison (model says down, books
        // say up) rather than exact event-time matching, so a boundary
        // within the event tolerance is caught at the next instant
        // instead of lost. Dying nodes preempt-and-rollback their jobs
        // BEFORE capacity is zeroed (release must see the grants).
        let mut fault_now = false;
        if let Some(fm) = &faults {
            for ci in 0..cluster.n_classes() {
                for ni in 0..cluster.class(ci).nodes as usize {
                    let down = fm.node_down(ci, ni, now);
                    if down && !free.node_is_down(ci, ni) {
                        for s in state.iter_mut() {
                            let hit = s
                                .running
                                .as_ref()
                                .map(|r| {
                                    r.placement.iter().any(|p| {
                                        p.class == ci && p.node == ni
                                    })
                                })
                                .unwrap_or(false);
                            if hit {
                                fault_preempt(s, now, cfg, &mut free,
                                              perf, &mut fb, trace);
                                departed_now |=
                                    s.finished_at.is_some();
                            }
                        }
                        free.set_node_down(ci, ni);
                        fb.failures += 1;
                        fault_now = true;
                        if trace.is_enabled() {
                            trace.instant(
                                "fault",
                                "node_down",
                                Json::obj(vec![
                                    ("class", Json::num(ci as f64)),
                                    ("node", Json::num(ni as f64)),
                                ]),
                            );
                        }
                    } else if !down && free.node_is_down(ci, ni) {
                        free.set_node_up(ci, ni);
                        fb.repairs += 1;
                        fault_now = true;
                        if trace.is_enabled() {
                            trace.instant(
                                "fault",
                                "node_up",
                                Json::obj(vec![
                                    ("class", Json::num(ci as f64)),
                                    ("node", Json::num(ni as f64)),
                                ]),
                            );
                        }
                    }
                }
            }
            // per-job crash hazards: only running jobs can crash
            for s in state.iter_mut() {
                if s.running.is_some() && fm.crash_due(s.job.id, now) {
                    fault_preempt(s, now, cfg, &mut free, perf,
                                  &mut fb, trace);
                    departed_now |= s.finished_at.is_some();
                    fault_now = true;
                    if trace.is_enabled() {
                        trace.instant(
                            "fault",
                            "crash",
                            Json::obj(vec![(
                                "job",
                                Json::num(s.job.id as f64),
                            )]),
                        );
                    }
                }
            }
        }

        // (3.6) event coalescing: when this instant carries ONLY
        // arrivals and another arrival lands within the debounce window
        // — no sooner than which any other event fires — defer the
        // replan to that later instant. A staggered HPO burst then
        // folds into one re-solve over the whole cohort. Deferred
        // arrivals are already marked `arrived`, so they are planned
        // (as one batch) at the instant that ends the burst.
        if cfg.coalesce_window_s > 0.0
            && arrived_now
            && !departed_now
            && !fault_now
            && next_introspect != Some(now)
        {
            let pending_arrival = state
                .iter()
                .filter(|s| !s.arrived)
                .map(|s| s.arrival_s)
                .fold(f64::INFINITY, f64::min);
            let next_finish = state
                .iter()
                .filter_map(|s| s.running.as_ref().map(|r| r.planned_finish))
                .fold(f64::INFINITY, f64::min);
            let next_rung = match rungs {
                Some(rc) => state
                    .iter()
                    .filter_map(|s| rung_crossing(s, rc, now))
                    .fold(f64::INFINITY, f64::min),
                None => f64::INFINITY,
            };
            let next_fault = match &faults {
                Some(fm) => {
                    let node_ev = fm
                        .next_node_event_after(now)
                        .unwrap_or(f64::INFINITY);
                    state
                        .iter()
                        .filter(|s| s.running.is_some())
                        .filter_map(|s| fm.next_crash_after(s.job.id, now))
                        .fold(node_ev, f64::min)
                }
                None => f64::INFINITY,
            };
            let others = next_finish
                .min(next_rung)
                .min(next_fault)
                .min(next_introspect.unwrap_or(f64::INFINITY));
            if pending_arrival <= now + cfg.coalesce_window_s + 1e-9
                && pending_arrival <= others
            {
                coalesced += 1;
                if trace.is_enabled() {
                    trace.instant(
                        "sched",
                        "coalesce",
                        Json::obj(vec![
                            ("until", Json::num(pending_arrival)),
                            (
                                "window_s",
                                Json::num(cfg.coalesce_window_s),
                            ),
                        ]),
                    );
                }
                continue;
            }
        }

        // (4) replan: periodic introspection always preempts everything;
        // arrival/departure events do so only when the policy opts in;
        // fault events count as set changes (victims went pending,
        // capacity moved).
        let introspect_now = next_introspect == Some(now);
        let set_changed = arrived_now || departed_now || fault_now;
        // strongest event at this instant wins the cause attribution
        let cause = if fault_now {
            ReplanCause::Failure
        } else if introspect_now {
            ReplanCause::Introspection
        } else if arrived_now {
            ReplanCause::Arrival
        } else if departed_now {
            ReplanCause::Departure
        } else {
            ReplanCause::Tick
        };
        if introspect_now || (set_changed && policy.replan_on_events()) {
            // checkpoint-everything: bank progress, mark all unfinished
            // jobs pending, let the policy replan from scratch.
            for s in state.iter_mut() {
                if let Some(r) = s.running.take() {
                    let done = ((now - r.resume_at) / r.step_time).floor();
                    s.steps_done = (s.steps_done + done.max(0.0) as u64)
                        .min(s.job.total_steps());
                    free.release(&r.placement);
                    if let Some(o) = stint_observation(&r, s.job.id, now) {
                        perf.observe(&o);
                    }
                    if s.remaining_steps() == 0 {
                        s.finished_at = Some(now);
                        perf.retire_job(s.job.id);
                        if trace.is_enabled() {
                            trace.instant(
                                "job",
                                "complete",
                                Json::obj(vec![(
                                    "job",
                                    Json::num(s.job.id as f64),
                                )]),
                            );
                        }
                    } else {
                        s.last_alloc = Some((r.tech, r.gpus, r.class));
                        if trace.is_enabled() {
                            trace.instant(
                                "job",
                                "preempt",
                                Json::obj(vec![(
                                    "job",
                                    Json::num(s.job.id as f64),
                                )]),
                            );
                        }
                    }
                }
            }
            if introspect_now {
                next_introspect = Some(now + interval.unwrap());
            }
            let pre_launch = snapshot_allocs(&state);
            perf.refresh(now);
            apply_plan(policy, &mut state, &mut free, perf, cluster, now,
                       &mut launches, &mut migrations, cfg, cause,
                       &mut decision, &mut fb);
            preemptions += count_migrations(&pre_launch, &state);
        } else {
            perf.refresh(now);
            apply_plan(policy, &mut state, &mut free, perf, cluster, now,
                       &mut launches, &mut migrations, cfg, cause,
                       &mut decision, &mut fb);
        }
    }

    let makespan = state
        .iter()
        .map(|s| s.finished_at.expect("all jobs finished"))
        .fold(0.0, f64::max);
    if trace.is_enabled() {
        trace.set_time(makespan);
        trace.instant(
            "meta",
            "run_end",
            Json::obj(vec![
                ("makespan_s", Json::num(makespan)),
                ("launches", Json::num(launches as f64)),
            ]),
        );
    }
    let mut completed = Vec::new();
    let mut early_stopped = Vec::new();
    let mut deadline_misses = 0usize;
    let mut total_tardiness = 0.0f64;
    let mut weighted_tardiness = 0.0f64;
    let total_priority: f64 = state.iter().map(|s| s.priority).sum();
    for s in &state {
        if s.early_stopped {
            early_stopped.push(s.job.id);
        } else {
            completed.push(s.job.id);
            if let Some(d) = s.deadline_s {
                if s.finished_at.unwrap() > s.arrival_s + d {
                    deadline_misses += 1;
                }
                let tard =
                    (s.finished_at.unwrap() - (s.arrival_s + d)).max(0.0);
                total_tardiness += tard;
                weighted_tardiness += s.priority * tard;
            }
        }
    }
    let (lp_capped, milp_limit_reached) = policy.solver_pressure();
    let finite = |x: f64| if x.is_nan() { 0.0 } else { x };
    OnlineSimResult {
        makespan_s: makespan,
        finish_times: state
            .iter()
            .map(|s| (s.job.id, s.finished_at.unwrap()))
            .collect(),
        jct_s: state
            .iter()
            .map(|s| (s.job.id, s.finished_at.unwrap() - s.arrival_s))
            .collect(),
        completed,
        early_stopped,
        deadline_misses,
        total_tardiness_s: total_tardiness,
        weighted_tardiness_s: weighted_tardiness
            / total_priority.max(1e-9),
        preemptions,
        migrations,
        gpu_utilization: busy_gpu_seconds
            / (cluster.total_gpus() as f64 * makespan.max(1e-9)),
        peak_gpus,
        launches,
        policy_decision_s: policy.decision_time_s(),
        decision_p50_s: finite(decision.percentile(0.50)),
        decision_p99_s: finite(decision.percentile(0.99)),
        lp_capped,
        milp_limit_reached,
        observations: perf.obs_seen(),
        estimate_mae: perf.estimate_mae(),
        failures: fb.failures,
        repairs: fb.repairs,
        fault_preemptions: fb.fault_preemptions,
        lost_work_gpu_s: fb.lost_work_gpu_s,
        mean_recovery_s: if fb.recoveries > 0 {
            fb.recovery_total_s / fb.recoveries as f64
        } else {
            0.0
        },
        goodput: (busy_gpu_seconds - fb.lost_work_gpu_s).max(0.0)
            / (cluster.total_gpus() as f64 * makespan.max(1e-9)),
        coalesced_events: coalesced,
    }
}

/// Run-level fault accounting (all zero when faults are off).
#[derive(Debug, Default)]
struct FaultBook {
    failures: usize,
    repairs: usize,
    fault_preemptions: usize,
    lost_work_gpu_s: f64,
    recovery_total_s: f64,
    recoveries: usize,
}

/// Kill one running stint from a fault: bank progress only up to the
/// last periodic checkpoint (work past it is lost and re-run), release
/// the grant, and leave the job pending with a mandatory reload on its
/// next launch. Completion is still honored if the checkpointed
/// progress happens to cover the job.
fn fault_preempt(s: &mut JobProgress, now: f64, cfg: &SimConfig,
                 free: &mut FreeState, perf: &mut PerfModel,
                 fb: &mut FaultBook, trace: &Tracer) {
    let Some(r) = s.running.take() else { return };
    let ran = (now - r.resume_at).max(0.0);
    let kept = if cfg.checkpoint_interval_s > 0.0 {
        (ran / cfg.checkpoint_interval_s).floor()
            * cfg.checkpoint_interval_s
    } else {
        ran
    };
    let done = if r.step_time > 0.0 {
        (kept / r.step_time).floor() as u64
    } else {
        0
    };
    s.steps_done = (s.steps_done + done).min(s.job.total_steps());
    fb.lost_work_gpu_s += (ran - kept).max(0.0) * r.gpus as f64;
    free.release(&r.placement);
    // telemetry streamed before the fault: the estimate layer keeps the
    // whole stint's observation even though the tail's progress is lost
    if let Some(o) = stint_observation(&r, s.job.id, now) {
        perf.observe(&o);
    }
    if s.remaining_steps() == 0 {
        s.finished_at = Some(now);
        perf.retire_job(s.job.id);
        if trace.is_enabled() {
            trace.instant(
                "job",
                "complete",
                Json::obj(vec![("job", Json::num(s.job.id as f64))]),
            );
        }
        return;
    }
    s.last_alloc = Some((r.tech, r.gpus, r.class));
    s.needs_reload = true;
    s.fault_preempted_at = Some(now);
    fb.fault_preemptions += 1;
    if trace.is_enabled() {
        trace.instant(
            "job",
            "fault_preempt",
            Json::obj(vec![
                ("job", Json::num(s.job.id as f64)),
                ("lost_s", Json::num((ran - kept).max(0.0))),
            ]),
        );
    }
}

/// The observed record of one running stint ending (or being read) at
/// `now`, covering only the NOT-yet-reported part (`Running::observed_s`
/// tracks what surviving rung boundaries already reported): `None` while
/// the checkpoint-restart lag has not elapsed or nothing new ran.
fn stint_observation(r: &Running, job_id: usize, now: f64)
    -> Option<Observation> {
    let dur = now - r.resume_at - r.observed_s;
    if dur <= 1e-9 || r.step_time <= 0.0 {
        return None;
    }
    Some(Observation {
        job_id,
        tech: r.tech,
        gpus: r.gpus,
        class: r.class,
        steps: dur / r.step_time,
        step_time_s: r.step_time,
        at_s: now,
    })
}

/// Virtual time at which a RUNNING job crosses its next rung threshold,
/// `None` if it isn't running, is out of rungs, or completes first.
/// Clamped to `now` defensively so time never runs backwards.
fn rung_crossing(s: &JobProgress, rc: &RungConfig, now: f64) -> Option<f64> {
    let r = s.running.as_ref()?;
    let frac = *rc.fractions.get(s.next_rung)?;
    let threshold = (s.job.total_steps() as f64 * frac).ceil() as u64;
    if threshold >= s.job.total_steps() {
        return None; // degenerate rung: completion handles it
    }
    let delta = threshold.saturating_sub(s.steps_done);
    let t = r.resume_at + delta as f64 * r.step_time;
    if t >= r.planned_finish - 1e-9 {
        return None; // finishes before (or at) the rung
    }
    Some(t.max(now))
}

fn snapshot_allocs(state: &[JobProgress]) -> Vec<Option<(usize, u32, usize)>> {
    state.iter().map(|s| s.last_alloc).collect()
}

fn count_migrations(before: &[Option<(usize, u32, usize)>],
                    state: &[JobProgress]) -> usize {
    state
        .iter()
        .zip(before)
        .filter(|(s, prev)| {
            if let (Some(r), Some(prev)) = (&s.running, prev) {
                (r.tech, r.gpus, r.class) != *prev
            } else {
                false
            }
        })
        .count()
}

#[allow(clippy::too_many_arguments)]
fn apply_plan(policy: &mut dyn Policy, state: &mut [JobProgress],
              free: &mut FreeState, perf: &PerfModel,
              cluster: &ClusterSpec, now: f64, launches: &mut usize,
              migrations: &mut usize, cfg: &SimConfig,
              cause: ReplanCause, decision: &mut Histogram,
              fb: &mut FaultBook) {
    let trace = &cfg.trace;
    if trace.is_enabled() {
        let pending = state.iter().filter(|s| s.is_pending()).count();
        trace.begin(
            "sched",
            "plan",
            Json::obj(vec![
                ("policy", Json::str(policy.name())),
                ("cause", Json::str(cause.name())),
                ("pending", Json::num(pending as f64)),
            ]),
        );
    }
    let t0 = Instant::now();
    let proposals = {
        let ctx = PlanContext {
            now,
            jobs: state,
            free,
            profiles: perf.table(),
            cluster,
            objective: cfg.objective,
            obs_seen: perf.obs_seen(),
            drift_alarm: perf.drift_alarm(),
            cause,
            trace,
        };
        policy.plan(&ctx)
    };
    // wall time of the decision only feeds telemetry, never the sim
    let dt = t0.elapsed().as_secs_f64();
    decision.observe(dt);
    crate::obs::metrics::global().observe("engine.decision_s", dt);
    let before = *launches;
    for l in proposals {
        let Some(s) = state.get_mut(l.job_id) else { continue };
        if !s.is_pending() {
            continue; // policy asked for a running/finished job; ignore
        }
        // feasibility is judged on the ESTIMATE the policy planned with;
        // the hardware then charges the TRUE step time (same support —
        // drift perturbs magnitudes, never feasibility)
        if perf.table().step_time(l.job_id, l.tech, l.gpus, l.class)
            .is_none()
        {
            continue; // infeasible plan; ignore defensively
        }
        let Some(step_time) =
            perf.true_step_time(l.job_id, l.tech, l.gpus, l.class, now)
        else {
            continue;
        };
        let Some(placement) = free.place(l.class, l.gpus) else { continue };
        // checkpoint/restart lag when the allocation changed shape: a
        // same-class reshape re-shards in place; a cross-class move is a
        // cheaper clean reload into the destination class
        let migrated = s.last_alloc.map(|a| a != (l.tech, l.gpus, l.class))
            .unwrap_or(false);
        let mut lag = match s.last_alloc {
            Some((_, _, prev_class)) if migrated && prev_class != l.class => {
                cluster.class(l.class).reload_penalty_s
            }
            _ if migrated => cfg.checkpoint_penalty_s,
            _ => 0.0,
        };
        if s.needs_reload {
            // restart-from-checkpoint after a fault kill: a clean
            // reload even when the allocation shape is unchanged
            lag = lag.max(cluster.class(l.class).reload_penalty_s);
            s.needs_reload = false;
        }
        if migrated {
            *migrations += 1;
        }
        let resume_at = now + lag;
        let remaining = s.remaining_steps() as f64;
        s.running = Some(Running {
            tech: l.tech,
            gpus: l.gpus,
            class: l.class,
            placement,
            step_time,
            resume_at,
            planned_finish: resume_at + remaining * step_time,
            observed_s: 0.0,
        });
        s.last_alloc = Some((l.tech, l.gpus, l.class));
        if let Some(t0) = s.fault_preempted_at.take() {
            fb.recovery_total_s += now - t0;
            fb.recoveries += 1;
        }
        *launches += 1;
        if trace.is_enabled() {
            trace.instant(
                "job",
                "launch",
                Json::obj(vec![
                    ("job", Json::num(l.job_id as f64)),
                    ("tech", Json::num(l.tech as f64)),
                    ("gpus", Json::num(l.gpus as f64)),
                    ("class", Json::num(l.class as f64)),
                    ("lag_s", Json::num(lag)),
                ]),
            );
            if migrated {
                trace.instant(
                    "job",
                    "migrate",
                    Json::obj(vec![
                        ("job", Json::num(l.job_id as f64)),
                        ("lag_s", Json::num(lag)),
                    ]),
                );
            }
        }
    }
    if trace.is_enabled() {
        trace.end(
            "sched",
            "plan",
            Json::obj(vec![(
                "launches",
                Json::num((*launches - before) as f64),
            )]),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallelism::default_library;
    use crate::trials::profile_analytic;
    use crate::workload::toy_workload;

    /// Trivial FIFO policy: whole node per job, best technique at 8 GPUs.
    struct Fifo;

    impl Policy for Fifo {
        fn name(&self) -> &'static str {
            "fifo-test"
        }

        fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
            let mut free = ctx.free.clone();
            let mut out = Vec::new();
            for s in ctx.jobs.iter().filter(|s| s.is_pending()) {
                let g = ctx.cluster.gpus_per_node();
                if let Some((tech, _)) = ctx.profiles.best_at(s.job.id, g, 0) {
                    if free.place(0, g).is_some() {
                        out.push(Launch {
                            job_id: s.job.id,
                            tech,
                            gpus: g,
                            class: 0,
                        });
                    }
                }
            }
            out
        }
    }

    fn setup(n: usize) -> (Vec<crate::workload::Job>, ProfileTable, ClusterSpec) {
        let jobs = toy_workload(n);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        (jobs, profiles, cluster)
    }

    #[test]
    fn fifo_completes_all_jobs() {
        let (jobs, profiles, cluster) = setup(4);
        let mut p = Fifo;
        let r = simulate(&jobs, &profiles, &cluster, &mut p,
                         &SimConfig::default());
        assert_eq!(r.finish_times.len(), 4);
        assert!(r.makespan_s > 0.0);
        assert_eq!(r.preemptions, 0);
        assert!(r.gpu_utilization > 0.0 && r.gpu_utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn sequential_makespan_is_sum_of_runtimes() {
        let (jobs, profiles, cluster) = setup(3);
        let mut p = Fifo;
        let r = simulate(&jobs, &profiles, &cluster, &mut p,
                         &SimConfig::default());
        let expected: f64 = jobs
            .iter()
            .map(|j| {
                let (tech, _) = profiles.best_at(j.id, 8, 0).unwrap();
                profiles.step_time(j.id, tech, 8, 0).unwrap()
                    * j.total_steps() as f64
            })
            .sum();
        assert!((r.makespan_s - expected).abs() / expected < 1e-6,
                "{} vs {expected}", r.makespan_s);
    }

    #[test]
    fn determinism() {
        let (jobs, profiles, cluster) = setup(6);
        let a = simulate(&jobs, &profiles, &cluster, &mut Fifo,
                         &SimConfig::default());
        let b = simulate(&jobs, &profiles, &cluster, &mut Fifo,
                         &SimConfig::default());
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.finish_times, b.finish_times);
    }

    // -- online mode -------------------------------------------------------

    fn online_jobs(n: usize, gap_s: f64) -> Vec<OnlineJob> {
        toy_workload(n)
            .into_iter()
            .enumerate()
            .map(|(i, job)| OnlineJob {
                job,
                arrival_s: gap_s * i as f64,
                group: 0,
                priority: 1.0,
                deadline_s: None,
                // descending: every later job ranks below the cohort seen
                // so far, so rung kills actually trigger under FIFO order
                score: (n - i) as f64,
            })
            .collect()
    }

    #[test]
    fn staggered_arrivals_delay_schedulability() {
        let (_, profiles, cluster) = setup(3);
        let jobs = online_jobs(3, 5_000.0);
        let r = simulate_online(&jobs, None, &profiles, &cluster, &mut Fifo,
                                &SimConfig::default());
        assert_eq!(r.completed.len(), 3);
        assert!(r.early_stopped.is_empty());
        // job i cannot depart before it arrived + its own runtime
        for &(id, fin) in &r.finish_times {
            assert!(fin >= jobs[id].arrival_s, "job {id} finished pre-arrival");
        }
        // JCT bookkeeping is relative to arrival
        for &(id, jct) in &r.jct_s {
            let fin = r.finish_times[id].1;
            assert!((jct - (fin - jobs[id].arrival_s)).abs() < 1e-9);
        }
    }

    #[test]
    fn coalescing_folds_staggered_arrivals_into_one_replan() {
        let (_, profiles, cluster) = setup(4);
        // arrivals at 0/10/20/30 s; runtimes are hours, so nothing else
        // fires inside the burst
        let jobs = online_jobs(4, 10.0);
        let base = simulate_online(&jobs, None, &profiles, &cluster,
                                   &mut Fifo, &SimConfig::default());
        assert_eq!(base.coalesced_events, 0,
                   "window 0 must never coalesce");
        let cfg = SimConfig { coalesce_window_s: 60.0,
                              ..SimConfig::default() };
        let r = simulate_online(&jobs, None, &profiles, &cluster,
                                &mut Fifo, &cfg);
        assert_eq!(r.completed.len(), 4);
        assert!(r.peak_gpus <= cluster.total_gpus());
        assert_eq!(r.coalesced_events, 2,
                   "arrival instants 10 s and 20 s must defer into 30 s");
        // deferral is deterministic
        let r2 = simulate_online(&jobs, None, &profiles, &cluster,
                                 &mut Fifo, &cfg);
        assert_eq!(r.finish_times, r2.finish_times);
        assert_eq!(r.coalesced_events, r2.coalesced_events);
    }

    #[test]
    fn coalescing_window_shorter_than_the_gap_is_inert() {
        let (_, profiles, cluster) = setup(4);
        let jobs = online_jobs(4, 10.0);
        let cfg = SimConfig { coalesce_window_s: 5.0,
                              ..SimConfig::default() };
        let r = simulate_online(&jobs, None, &profiles, &cluster,
                                &mut Fifo, &cfg);
        assert_eq!(r.coalesced_events, 0,
                   "no sibling lands within 5 s of any arrival");
        assert_eq!(r.completed.len(), 4);
    }

    #[test]
    fn online_with_zero_arrivals_matches_batch() {
        let (jobs, profiles, cluster) = setup(5);
        let batch = simulate(&jobs, &profiles, &cluster, &mut Fifo,
                             &SimConfig::default());
        let online: Vec<OnlineJob> =
            jobs.iter().map(OnlineJob::batch).collect();
        let r = simulate_online(&online, None, &profiles, &cluster, &mut Fifo,
                                &SimConfig::default());
        assert_eq!(batch.makespan_s, r.makespan_s);
        assert_eq!(batch.finish_times, r.finish_times);
    }

    #[test]
    fn rung_kills_depart_early_and_release_gpus() {
        let (_, profiles, cluster) = setup(6);
        // all six arrive at t=0 in one grid; scores ascend with id
        let jobs = online_jobs(6, 0.0);
        let rungs = RungConfig { fractions: vec![0.25], kill_fraction: 0.5 };
        let with = simulate_online(&jobs, Some(&rungs), &profiles, &cluster,
                                   &mut Fifo, &SimConfig::default());
        let without = simulate_online(&jobs, None, &profiles, &cluster,
                                      &mut Fifo, &SimConfig::default());
        assert!(!with.early_stopped.is_empty(), "no job was early-stopped");
        assert_eq!(with.early_stopped.len() + with.completed.len(), 6);
        assert!(with.makespan_s < without.makespan_s,
                "early stopping did not shorten the schedule: {} vs {}",
                with.makespan_s, without.makespan_s);
        // killed jobs departed strictly before their full runtime elapsed
        for &id in &with.early_stopped {
            assert!(with.jct_s[id].1 < without.jct_s[id].1);
        }
    }

    #[test]
    fn online_replay_is_bit_identical() {
        let (_, profiles, cluster) = setup(6);
        let jobs = online_jobs(6, 1_000.0);
        let rungs = RungConfig::halving();
        let run = || {
            simulate_online(&jobs, Some(&rungs), &profiles, &cluster,
                            &mut Fifo, &SimConfig::default())
        };
        let (a, b) = (run(), run());
        assert_eq!(a.finish_times, b.finish_times);
        assert_eq!(a.jct_s, b.jct_s);
        assert_eq!(a.early_stopped, b.early_stopped);
        assert_eq!(a.launches, b.launches);
    }

    #[test]
    fn tardiness_metrics_match_the_finish_times() {
        let (_, profiles, cluster) = setup(4);
        let mut jobs = online_jobs(4, 2_000.0);
        // every even job is due the moment it arrives (tardiness = JCT),
        // odd jobs carry no deadline (count 0 in both metrics)
        for (i, oj) in jobs.iter_mut().enumerate() {
            oj.deadline_s = if i % 2 == 0 { Some(0.0) } else { None };
            oj.priority = 1.0 + i as f64;
        }
        let r = simulate_online(&jobs, None, &profiles, &cluster, &mut Fifo,
                                &SimConfig::default());
        let w_sum: f64 = jobs.iter().map(|j| j.priority).sum();
        let mut total = 0.0;
        let mut weighted = 0.0;
        let mut late = 0usize;
        for &(id, fin) in &r.finish_times {
            let Some(d) = jobs[id].deadline_s else { continue };
            let t = (fin - (jobs[id].arrival_s + d)).max(0.0);
            total += t;
            weighted += jobs[id].priority * t;
            if fin > jobs[id].arrival_s + d {
                late += 1;
            }
        }
        assert!(total > 0.0, "zero-slack deadlines produced no tardiness");
        assert!((r.total_tardiness_s - total).abs() <= 1e-9 * total);
        let expect_w = weighted / w_sum;
        assert!((r.weighted_tardiness_s - expect_w).abs()
                    <= 1e-9 * expect_w.max(1.0));
        assert_eq!(r.deadline_misses, late);
    }

    #[test]
    fn peak_gpus_never_exceed_capacity() {
        let (_, profiles, cluster) = setup(8);
        let jobs = online_jobs(8, 2_000.0);
        let r = simulate_online(&jobs, Some(&RungConfig::halving()), &profiles,
                                &cluster, &mut Fifo, &SimConfig::default());
        assert!(r.peak_gpus <= cluster.total_gpus());
        assert!(r.gpu_utilization <= 1.0 + 1e-9);
    }

    // -- estimate-vs-truth split ------------------------------------------

    #[test]
    fn zero_drift_perf_path_matches_the_plain_wrapper() {
        let (_, profiles, cluster) = setup(6);
        let jobs = online_jobs(6, 1_000.0);
        let rungs = RungConfig::halving();
        let a = simulate_online(&jobs, Some(&rungs), &profiles, &cluster,
                                &mut Fifo, &SimConfig::default());
        let mut perf = crate::perf::PerfModel::with_drift(
            &profiles, crate::perf::DriftConfig::none(), true);
        let b = simulate_online_perf(&jobs, Some(&rungs), &mut perf,
                                     &cluster, &mut Fifo,
                                     &SimConfig::default());
        assert_eq!(a.finish_times, b.finish_times);
        assert_eq!(a.jct_s, b.jct_s);
        assert_eq!(a.early_stopped, b.early_stopped);
        assert_eq!(a.estimate_mae, 0.0);
        assert_eq!(b.estimate_mae, 0.0);
    }

    #[test]
    fn drifting_truth_emits_observations_and_shifts_the_makespan() {
        let (_, profiles, cluster) = setup(6);
        let jobs = online_jobs(6, 1_000.0);
        let rungs = RungConfig::halving();
        let base = simulate_online(&jobs, Some(&rungs), &profiles, &cluster,
                                   &mut Fifo, &SimConfig::default());
        let mut perf = crate::perf::PerfModel::with_drift(
            &profiles, crate::perf::DriftConfig::uniform(5, 0.3), true);
        let r = simulate_online_perf(&jobs, Some(&rungs), &mut perf,
                                     &cluster, &mut Fifo,
                                     &SimConfig::default());
        assert!(r.observations > 0, "no observations under drift");
        assert!(r.estimate_mae > 0.0, "drift produced no estimate error");
        assert!((r.makespan_s - base.makespan_s).abs()
                    > 1e-6 * base.makespan_s,
                "30% drift left the makespan untouched");
        assert_eq!(r.finish_times.len(), 6);
    }

    #[test]
    fn drift_replay_is_bit_identical() {
        let (_, profiles, cluster) = setup(6);
        let jobs = online_jobs(6, 1_000.0);
        let run = || {
            let mut perf = crate::perf::PerfModel::with_drift(
                &profiles, crate::perf::DriftConfig::uniform(13, 0.2), true);
            simulate_online_perf(&jobs, Some(&RungConfig::halving()),
                                 &mut perf, &cluster, &mut Fifo,
                                 &SimConfig::default())
        };
        let (a, b) = (run(), run());
        assert_eq!(a.finish_times, b.finish_times);
        assert_eq!(a.estimate_mae, b.estimate_mae);
        assert_eq!(a.observations, b.observations);
    }

    // -- faults ------------------------------------------------------------

    #[test]
    fn fault_free_run_reports_zero_fault_metrics() {
        let (_, profiles, cluster) = setup(4);
        let jobs = online_jobs(4, 1_000.0);
        let r = simulate_online(&jobs, None, &profiles, &cluster,
                                &mut Fifo, &SimConfig::default());
        assert_eq!(r.failures, 0);
        assert_eq!(r.repairs, 0);
        assert_eq!(r.fault_preemptions, 0);
        assert_eq!(r.lost_work_gpu_s, 0.0);
        assert_eq!(r.mean_recovery_s, 0.0);
        assert_eq!(r.goodput.to_bits(), r.gpu_utilization.to_bits(),
                   "zero-fault goodput must BE utilization");
    }

    #[test]
    fn crash_hazard_rolls_back_and_delays_completion() {
        let (_, profiles, cluster) = setup(3);
        let jobs = online_jobs(3, 0.0);
        let clean = simulate_online(&jobs, None, &profiles, &cluster,
                                    &mut Fifo, &SimConfig::default());
        // crash-only faults, aggressive hazard so toy-length stints get
        // hit; coarse checkpoints so each kill visibly loses work
        let cfg = SimConfig {
            faults: FaultConfig {
                seed: 3,
                crash_per_hour: 4.0,
                ..FaultConfig::none()
            },
            checkpoint_interval_s: 600.0,
            ..SimConfig::default()
        };
        let r = simulate_online(&jobs, None, &profiles, &cluster,
                                &mut Fifo, &cfg);
        assert_eq!(r.completed.len(), 3, "crashes must not lose jobs");
        assert!(r.fault_preemptions > 0,
                "4/h hazard never fired on a toy run");
        assert!(r.lost_work_gpu_s > 0.0);
        assert!(r.makespan_s > clean.makespan_s,
                "lost work did not lengthen the schedule: {} vs {}",
                r.makespan_s, clean.makespan_s);
        assert!(r.goodput < r.gpu_utilization);
        assert!(r.mean_recovery_s >= 0.0);
        // replay stays bit-identical under faults
        let r2 = simulate_online(&jobs, None, &profiles, &cluster,
                                 &mut Fifo, &cfg);
        assert_eq!(r.finish_times, r2.finish_times);
        assert_eq!(r.lost_work_gpu_s.to_bits(),
                   r2.lost_work_gpu_s.to_bits());
    }

    #[test]
    fn node_outage_preempts_and_capacity_returns_after_repair() {
        let (_, profiles, cluster) = setup(4);
        let jobs = online_jobs(4, 0.0);
        let cfg = SimConfig {
            faults: FaultConfig::uniform(7, 1.0), // 1h MTBF: outages hit
            checkpoint_interval_s: 900.0,
            ..SimConfig::default()
        };
        let r = simulate_online(&jobs, None, &profiles, &cluster,
                                &mut Fifo, &cfg);
        assert_eq!(r.finish_times.len(), 4, "outages must not lose jobs");
        assert!(r.failures > 0, "1h MTBF drew no node failures");
        assert!(r.repairs > 0, "no node ever came back");
        assert!(r.peak_gpus <= cluster.total_gpus());
        assert!(r.gpu_utilization <= 1.0 + 1e-9);
    }
}
