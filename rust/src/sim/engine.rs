//! The simulation engine: advances virtual time between job completions
//! and introspection points, asks the `Policy` for launch decisions, and
//! enforces capacity/placement/checkpoint semantics.
//!
//! Determinism: given the same policy (and policy seed), the simulation is
//! bit-reproducible — Table 2 rows in EXPERIMENTS.md cite seeds.

use crate::cluster::ClusterSpec;
use crate::sim::placement::FreeState;
use crate::trials::ProfileTable;
use crate::workload::Job;

/// A policy's decision: run `job_id` with `tech` on `gpus` GPUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Launch {
    pub job_id: usize,
    pub tech: usize,
    pub gpus: u32,
}

/// A job currently holding GPUs.
#[derive(Debug, Clone)]
pub struct Running {
    pub tech: usize,
    pub gpus: u32,
    pub placement: Vec<(usize, u32)>,
    pub step_time: f64,
    /// Virtual time at which steps start accumulating (start + restart lag).
    pub resume_at: f64,
    pub planned_finish: f64,
}

/// Job + live progress.
#[derive(Debug, Clone)]
pub struct JobProgress {
    pub job: Job,
    pub steps_done: u64,
    pub running: Option<Running>,
    pub finished_at: Option<f64>,
    /// Last (tech, gpus) this job ran under (checkpoint-penalty detection).
    pub last_alloc: Option<(usize, u32)>,
}

impl JobProgress {
    pub fn remaining_steps(&self) -> u64 {
        self.job.total_steps().saturating_sub(self.steps_done)
    }

    pub fn is_pending(&self) -> bool {
        self.finished_at.is_none() && self.running.is_none()
    }
}

/// Everything a policy may look at when planning.
pub struct PlanContext<'a> {
    pub now: f64,
    pub jobs: &'a [JobProgress],
    pub free: &'a FreeState,
    pub profiles: &'a ProfileTable,
    pub cluster: &'a ClusterSpec,
}

/// Scheduling policy plugged into the simulator (Saturn + all baselines).
pub trait Policy {
    fn name(&self) -> &'static str;

    /// Called at t=0, after every completion, and at each introspection
    /// point. Returns desired launches for PENDING jobs; at introspection
    /// points it is called with ALL unfinished jobs marked pending
    /// (preempt-and-replan semantics) and may reassign freely.
    fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch>;

    /// `Some(interval)` enables Gandiva-style introspection every
    /// `interval` virtual seconds.
    fn introspection_interval(&self) -> Option<f64> {
        None
    }

    /// Cumulative wall-clock seconds the policy spent deciding (solver
    /// cost reporting, bench E9).
    fn decision_time_s(&self) -> f64 {
        0.0
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Seconds charged when a running job is checkpointed and relaunched
    /// under a different allocation (Gandiva/AntMan-style migration).
    pub checkpoint_penalty_s: f64,
    /// Safety valve for runaway simulations.
    pub max_virtual_time_s: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { checkpoint_penalty_s: 60.0, max_virtual_time_s: 1e9 }
    }
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan_s: f64,
    pub finish_times: Vec<(usize, f64)>,
    pub preemptions: usize,
    /// busy GPU-seconds / (total GPUs * makespan)
    pub gpu_utilization: f64,
    pub launches: usize,
    pub policy_decision_s: f64,
}

/// Run `jobs` to completion under `policy`. Panics if the policy deadlocks
/// (no job running and the policy refuses to launch any pending job).
pub fn simulate(jobs: &[Job], profiles: &ProfileTable, cluster: &ClusterSpec,
                policy: &mut dyn Policy, cfg: &SimConfig) -> SimResult {
    let mut state: Vec<JobProgress> = jobs
        .iter()
        .map(|j| JobProgress {
            job: j.clone(),
            steps_done: 0,
            running: None,
            finished_at: None,
            last_alloc: None,
        })
        .collect();
    let mut free = FreeState::new(cluster);
    let mut now = 0.0f64;
    let mut preemptions = 0usize;
    let mut launches = 0usize;
    let mut busy_gpu_seconds = 0.0f64;
    let interval = policy.introspection_interval();
    let mut next_introspect = interval.map(|i| i.max(1.0));

    // initial plan
    apply_plan(policy, &mut state, &mut free, profiles, cluster, now,
               &mut launches, cfg);

    let max_iters = 200_000;
    for _ in 0..max_iters {
        if state.iter().all(|s| s.finished_at.is_some()) {
            break;
        }
        // next completion event
        let next_finish = state
            .iter()
            .filter_map(|s| s.running.as_ref().map(|r| r.planned_finish))
            .fold(f64::INFINITY, f64::min);
        let t_next = match next_introspect {
            Some(ti) if ti < next_finish => ti,
            _ => next_finish,
        };
        if !t_next.is_finite() {
            // nothing running: force-plan; if still nothing, deadlock
            let before = launches;
            apply_plan(policy, &mut state, &mut free, profiles, cluster, now,
                       &mut launches, cfg);
            if launches == before {
                panic!(
                    "policy '{}' deadlocked at t={now:.1}s with {} pending jobs",
                    policy.name(),
                    state.iter().filter(|s| s.is_pending()).count()
                );
            }
            continue;
        }
        assert!(t_next >= now - 1e-6, "time went backwards");
        assert!(t_next < cfg.max_virtual_time_s, "virtual time runaway");

        // accumulate busy gpu-seconds over [now, t_next)
        let busy: u32 = state
            .iter()
            .filter_map(|s| s.running.as_ref().map(|r| r.gpus))
            .sum();
        busy_gpu_seconds += busy as f64 * (t_next - now);
        now = t_next;

        if Some(now) == next_introspect {
            // checkpoint-everything introspection point: bank progress,
            // mark all unfinished jobs pending, let the policy replan.
            for s in state.iter_mut() {
                if let Some(r) = s.running.take() {
                    let done = ((now - r.resume_at) / r.step_time).floor();
                    s.steps_done = (s.steps_done + done.max(0.0) as u64)
                        .min(s.job.total_steps());
                    free.release(&r.placement);
                    if s.remaining_steps() == 0 {
                        s.finished_at = Some(now);
                    } else {
                        s.last_alloc = Some((r.tech, r.gpus));
                    }
                }
            }
            let pre_launch = snapshot_allocs(&state);
            apply_plan(policy, &mut state, &mut free, profiles, cluster, now,
                       &mut launches, cfg);
            preemptions += count_migrations(&pre_launch, &state);
            next_introspect = Some(now + interval.unwrap());
        } else {
            // completions at `now`
            for s in state.iter_mut() {
                let done_now = s
                    .running
                    .as_ref()
                    .map(|r| (r.planned_finish - now).abs() < 1e-9)
                    .unwrap_or(false);
                if done_now {
                    let r = s.running.take().unwrap();
                    s.steps_done = s.job.total_steps();
                    s.finished_at = Some(now);
                    free.release(&r.placement);
                }
            }
            apply_plan(policy, &mut state, &mut free, profiles, cluster, now,
                       &mut launches, cfg);
        }
    }

    let makespan = state
        .iter()
        .map(|s| s.finished_at.expect("all jobs finished"))
        .fold(0.0, f64::max);
    SimResult {
        makespan_s: makespan,
        finish_times: state
            .iter()
            .map(|s| (s.job.id, s.finished_at.unwrap()))
            .collect(),
        preemptions,
        gpu_utilization: busy_gpu_seconds
            / (cluster.total_gpus() as f64 * makespan.max(1e-9)),
        launches,
        policy_decision_s: policy.decision_time_s(),
    }
}

fn snapshot_allocs(state: &[JobProgress]) -> Vec<Option<(usize, u32)>> {
    state.iter().map(|s| s.last_alloc).collect()
}

fn count_migrations(before: &[Option<(usize, u32)>], state: &[JobProgress])
    -> usize {
    state
        .iter()
        .zip(before)
        .filter(|(s, prev)| {
            if let (Some(r), Some(prev)) = (&s.running, prev) {
                (r.tech, r.gpus) != *prev
            } else {
                false
            }
        })
        .count()
}

fn apply_plan(policy: &mut dyn Policy, state: &mut [JobProgress],
              free: &mut FreeState, profiles: &ProfileTable,
              cluster: &ClusterSpec, now: f64, launches: &mut usize,
              cfg: &SimConfig) {
    let proposals = {
        let ctx = PlanContext { now, jobs: state, free, profiles, cluster };
        policy.plan(&ctx)
    };
    for l in proposals {
        let Some(s) = state.get_mut(l.job_id) else { continue };
        if !s.is_pending() {
            continue; // policy asked for a running/finished job; ignore
        }
        let Some(step_time) = profiles.step_time(l.job_id, l.tech, l.gpus)
        else {
            continue; // infeasible plan; ignore defensively
        };
        let Some(placement) = free.place(l.gpus) else { continue };
        // checkpoint/restart lag when the allocation changed shape
        let migrated = s.last_alloc.map(|a| a != (l.tech, l.gpus))
            .unwrap_or(false);
        let lag = if migrated { cfg.checkpoint_penalty_s } else { 0.0 };
        let resume_at = now + lag;
        let remaining = s.remaining_steps() as f64;
        s.running = Some(Running {
            tech: l.tech,
            gpus: l.gpus,
            placement,
            step_time,
            resume_at,
            planned_finish: resume_at + remaining * step_time,
        });
        s.last_alloc = Some((l.tech, l.gpus));
        *launches += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallelism::default_library;
    use crate::trials::profile_analytic;
    use crate::workload::toy_workload;

    /// Trivial FIFO policy: whole node per job, best technique at 8 GPUs.
    struct Fifo;

    impl Policy for Fifo {
        fn name(&self) -> &'static str {
            "fifo-test"
        }

        fn plan(&mut self, ctx: &PlanContext) -> Vec<Launch> {
            let mut free = ctx.free.clone();
            let mut out = Vec::new();
            for s in ctx.jobs.iter().filter(|s| s.is_pending()) {
                let g = ctx.cluster.node.gpus_per_node;
                if let Some((tech, _)) = ctx.profiles.best_at(s.job.id, g) {
                    if free.place(g).is_some() {
                        out.push(Launch { job_id: s.job.id, tech, gpus: g });
                    }
                }
            }
            out
        }
    }

    fn setup(n: usize) -> (Vec<crate::workload::Job>, ProfileTable, ClusterSpec) {
        let jobs = toy_workload(n);
        let cluster = ClusterSpec::p4d(1);
        let lib = default_library();
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        (jobs, profiles, cluster)
    }

    #[test]
    fn fifo_completes_all_jobs() {
        let (jobs, profiles, cluster) = setup(4);
        let mut p = Fifo;
        let r = simulate(&jobs, &profiles, &cluster, &mut p,
                         &SimConfig::default());
        assert_eq!(r.finish_times.len(), 4);
        assert!(r.makespan_s > 0.0);
        assert_eq!(r.preemptions, 0);
        assert!(r.gpu_utilization > 0.0 && r.gpu_utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn sequential_makespan_is_sum_of_runtimes() {
        let (jobs, profiles, cluster) = setup(3);
        let mut p = Fifo;
        let r = simulate(&jobs, &profiles, &cluster, &mut p,
                         &SimConfig::default());
        let expected: f64 = jobs
            .iter()
            .map(|j| {
                let (tech, _) = profiles.best_at(j.id, 8).unwrap();
                profiles.step_time(j.id, tech, 8).unwrap()
                    * j.total_steps() as f64
            })
            .sum();
        assert!((r.makespan_s - expected).abs() / expected < 1e-6,
                "{} vs {expected}", r.makespan_s);
    }

    #[test]
    fn determinism() {
        let (jobs, profiles, cluster) = setup(6);
        let a = simulate(&jobs, &profiles, &cluster, &mut Fifo,
                         &SimConfig::default());
        let b = simulate(&jobs, &profiles, &cluster, &mut Fifo,
                         &SimConfig::default());
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.finish_times, b.finish_times);
    }
}
