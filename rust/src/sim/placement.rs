//! GPU placement rules: jobs <= node size must be contained in one node
//! (NVLink domain); larger jobs take whole nodes. Mirrors how DL schedulers
//! place collective groups on p4d fleets.

use crate::cluster::ClusterSpec;

/// Free-GPU bookkeeping per node.
#[derive(Debug, Clone, PartialEq)]
pub struct FreeState {
    pub free: Vec<u32>,
    pub per_node: u32,
}

impl FreeState {
    pub fn new(cluster: &ClusterSpec) -> Self {
        FreeState {
            free: vec![cluster.node.gpus_per_node; cluster.nodes as usize],
            per_node: cluster.node.gpus_per_node,
        }
    }

    pub fn total_free(&self) -> u32 {
        self.free.iter().sum()
    }

    /// Try to place `gpus`; returns per-node grants and mutates `free`.
    /// Best-fit within a node for small jobs (reduces fragmentation);
    /// whole nodes for multi-node jobs.
    pub fn place(&mut self, gpus: u32) -> Option<Vec<(usize, u32)>> {
        if gpus == 0 {
            return None;
        }
        if gpus <= self.per_node {
            // best-fit: the feasible node with the least free capacity
            let node = self
                .free
                .iter()
                .enumerate()
                .filter(|(_, &f)| f >= gpus)
                .min_by_key(|(_, &f)| f)
                .map(|(i, _)| i)?;
            self.free[node] -= gpus;
            Some(vec![(node, gpus)])
        } else {
            if gpus % self.per_node != 0 {
                return None; // multi-node jobs use whole nodes
            }
            let need = (gpus / self.per_node) as usize;
            let full: Vec<usize> = self
                .free
                .iter()
                .enumerate()
                .filter(|(_, &f)| f == self.per_node)
                .map(|(i, _)| i)
                .take(need)
                .collect();
            if full.len() < need {
                return None;
            }
            for &i in &full {
                self.free[i] = 0;
            }
            Some(full.into_iter().map(|i| (i, self.per_node)).collect())
        }
    }

    /// Check placement feasibility without mutating.
    pub fn can_place(&self, gpus: u32) -> bool {
        self.clone().place(gpus).is_some()
    }

    pub fn release(&mut self, placement: &[(usize, u32)]) {
        for &(node, g) in placement {
            self.free[node] += g;
            debug_assert!(self.free[node] <= self.per_node,
                          "released more GPUs than the node has");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(nodes: u32) -> FreeState {
        FreeState::new(&ClusterSpec::p4d(nodes))
    }

    #[test]
    fn small_job_single_node() {
        let mut f = fleet(2);
        let p = f.place(4).unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(f.total_free(), 12);
    }

    #[test]
    fn best_fit_prefers_fuller_node() {
        let mut f = fleet(2);
        f.place(6).unwrap(); // node A now has 2 free
        let p = f.place(2).unwrap(); // should slot into node A
        assert_eq!(p[0].0, 0);
        assert_eq!(f.free, vec![0, 8]);
    }

    #[test]
    fn no_cross_node_fragmentation_for_small_jobs() {
        let mut f = fleet(2);
        f.place(5).unwrap();
        f.place(5).unwrap();
        // 3+3 free across nodes: a 5-GPU job must NOT span them
        assert!(f.place(5).is_none());
        assert_eq!(f.total_free(), 6);
    }

    #[test]
    fn multi_node_needs_whole_nodes() {
        let mut f = fleet(2);
        assert!(f.clone().place(16).is_some());
        f.place(1).unwrap();
        assert!(f.place(16).is_none()); // one node is no longer empty
        assert!(f.place(12).is_none()); // not a whole-node multiple
    }

    #[test]
    fn release_restores() {
        let mut f = fleet(1);
        let p = f.place(8).unwrap();
        assert_eq!(f.total_free(), 0);
        f.release(&p);
        assert_eq!(f.total_free(), 8);
    }
}
