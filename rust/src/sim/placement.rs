//! GPU placement rules: jobs <= node size must be contained in one node
//! (NVLink domain); larger jobs take whole nodes; jobs never span GPU
//! classes (a collective group mixes neither fabric generations nor
//! memory sizes). Mirrors how DL schedulers place collective groups on
//! p4d/p5 fleets.

use crate::cluster::ClusterSpec;

/// One per-node grant of a placement: `gpus` GPUs on `node` of `class`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub class: usize,
    pub node: usize,
    pub gpus: u32,
}

/// Free-GPU bookkeeping for one homogeneous class.
///
/// A node marked `down` (fault layer, DESIGN.md §4.7) carries zero free
/// GPUs, so the placement routines skip it without any fault-specific
/// branches of their own.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassFree {
    pub free: Vec<u32>,
    pub per_node: u32,
    pub down: Vec<bool>,
}

/// Free-GPU bookkeeping per class, per node.
#[derive(Debug, Clone, PartialEq)]
pub struct FreeState {
    pub classes: Vec<ClassFree>,
}

impl FreeState {
    pub fn new(cluster: &ClusterSpec) -> Self {
        FreeState {
            classes: cluster
                .classes
                .iter()
                .map(|c| ClassFree {
                    free: vec![c.node.gpus_per_node; c.nodes as usize],
                    per_node: c.node.gpus_per_node,
                    down: vec![false; c.nodes as usize],
                })
                .collect(),
        }
    }

    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    pub fn total_free(&self) -> u32 {
        self.classes.iter().map(|c| c.free.iter().sum::<u32>()).sum()
    }

    /// Free GPUs within one class.
    pub fn class_free(&self, class: usize) -> u32 {
        self.classes
            .get(class)
            .map(|c| c.free.iter().sum())
            .unwrap_or(0)
    }

    /// Total capacity of one class (free or busy).
    pub fn class_capacity(&self, class: usize) -> u32 {
        self.classes
            .get(class)
            .map(|c| c.per_node * c.free.len() as u32)
            .unwrap_or(0)
    }

    /// Take `node` of `class` out of service: its free count drops to
    /// zero so no placement can select it. The engine must preempt (and
    /// release) every job on the node first — marking a node down while
    /// its GPUs are still granted would double-count them on release.
    pub fn set_node_down(&mut self, class: usize, node: usize) {
        let Some(cf) = self.classes.get_mut(class) else { return };
        if node >= cf.free.len() || cf.down[node] {
            return;
        }
        debug_assert!(cf.free[node] == cf.per_node,
                      "mark down only after preempting the node's jobs");
        cf.down[node] = true;
        cf.free[node] = 0;
    }

    /// Return a repaired node to service with its full capacity.
    pub fn set_node_up(&mut self, class: usize, node: usize) {
        let Some(cf) = self.classes.get_mut(class) else { return };
        if node >= cf.free.len() || !cf.down[node] {
            return;
        }
        cf.down[node] = false;
        cf.free[node] = cf.per_node;
    }

    pub fn node_is_down(&self, class: usize, node: usize) -> bool {
        self.classes
            .get(class)
            .and_then(|c| c.down.get(node))
            .copied()
            .unwrap_or(false)
    }

    /// Capacity of one class counting only in-service nodes — the
    /// degraded figure failure-aware policies feed the MILP capacity
    /// rows.
    pub fn live_capacity(&self, class: usize) -> u32 {
        self.classes
            .get(class)
            .map(|c| {
                c.per_node
                    * c.down.iter().filter(|&&d| !d).count() as u32
            })
            .unwrap_or(0)
    }

    /// Try to place `gpus` on `class`; returns per-node grants and mutates
    /// the class's free counts. Best-fit within a node for small jobs
    /// (reduces fragmentation); whole nodes for multi-node jobs.
    pub fn place(&mut self, class: usize, gpus: u32)
        -> Option<Vec<Placement>> {
        if gpus == 0 {
            return None;
        }
        let cf = self.classes.get_mut(class)?;
        if gpus <= cf.per_node {
            // best-fit: the feasible node with the least free capacity
            let node = cf
                .free
                .iter()
                .enumerate()
                .filter(|(_, &f)| f >= gpus)
                .min_by_key(|(_, &f)| f)
                .map(|(i, _)| i)?;
            cf.free[node] -= gpus;
            Some(vec![Placement { class, node, gpus }])
        } else {
            if gpus % cf.per_node != 0 {
                return None; // multi-node jobs use whole nodes
            }
            let need = (gpus / cf.per_node) as usize;
            let full: Vec<usize> = cf
                .free
                .iter()
                .enumerate()
                .filter(|(_, &f)| f == cf.per_node)
                .map(|(i, _)| i)
                .take(need)
                .collect();
            if full.len() < need {
                return None;
            }
            for &i in &full {
                cf.free[i] = 0;
            }
            let per_node = cf.per_node;
            Some(
                full.into_iter()
                    .map(|node| Placement { class, node, gpus: per_node })
                    .collect(),
            )
        }
    }

    /// Check placement feasibility without mutating.
    pub fn can_place(&self, class: usize, gpus: u32) -> bool {
        self.clone().place(class, gpus).is_some()
    }

    pub fn release(&mut self, placement: &[Placement]) {
        for p in placement {
            let cf = &mut self.classes[p.class];
            cf.free[p.node] += p.gpus;
            debug_assert!(cf.free[p.node] <= cf.per_node,
                          "released more GPUs than the node has");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(nodes: u32) -> FreeState {
        FreeState::new(&ClusterSpec::p4d(nodes))
    }

    #[test]
    fn small_job_single_node() {
        let mut f = fleet(2);
        let p = f.place(0, 4).unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(f.total_free(), 12);
    }

    #[test]
    fn best_fit_prefers_fuller_node() {
        let mut f = fleet(2);
        f.place(0, 6).unwrap(); // node A now has 2 free
        let p = f.place(0, 2).unwrap(); // should slot into node A
        assert_eq!(p[0].node, 0);
        assert_eq!(f.classes[0].free, vec![0, 8]);
    }

    #[test]
    fn no_cross_node_fragmentation_for_small_jobs() {
        let mut f = fleet(2);
        f.place(0, 5).unwrap();
        f.place(0, 5).unwrap();
        // 3+3 free across nodes: a 5-GPU job must NOT span them
        assert!(f.place(0, 5).is_none());
        assert_eq!(f.total_free(), 6);
    }

    #[test]
    fn multi_node_needs_whole_nodes() {
        let mut f = fleet(2);
        assert!(f.clone().place(0, 16).is_some());
        f.place(0, 1).unwrap();
        assert!(f.place(0, 16).is_none()); // one node is no longer empty
        assert!(f.place(0, 12).is_none()); // not a whole-node multiple
    }

    #[test]
    fn release_restores() {
        let mut f = fleet(1);
        let p = f.place(0, 8).unwrap();
        assert_eq!(f.total_free(), 0);
        f.release(&p);
        assert_eq!(f.total_free(), 8);
    }

    #[test]
    fn classes_are_isolated_pools() {
        let mut f = FreeState::new(&ClusterSpec::hetero(1, 1));
        assert_eq!(f.n_classes(), 2);
        assert_eq!(f.class_free(0), 8);
        assert_eq!(f.class_free(1), 8);
        // fill the A100 class; the H100 class is untouched and a further
        // A100 placement must fail rather than spill across classes
        let p = f.place(0, 8).unwrap();
        assert!(p.iter().all(|g| g.class == 0));
        assert_eq!(f.class_free(0), 0);
        assert_eq!(f.class_free(1), 8);
        assert!(f.place(0, 1).is_none());
        assert!(f.place(1, 8).is_some());
        f.release(&p);
        assert_eq!(f.class_free(0), 8);
    }

    #[test]
    fn down_node_is_unplaceable_until_repaired() {
        let mut f = fleet(2);
        assert_eq!(f.live_capacity(0), 16);
        f.set_node_down(0, 0);
        assert!(f.node_is_down(0, 0));
        assert_eq!(f.live_capacity(0), 8);
        assert_eq!(f.class_free(0), 8);
        // capacity (nodes x per_node) is the static figure; live is not
        assert_eq!(f.class_capacity(0), 16);
        // only the surviving node can host, so a second 8-GPU job fails
        let p = f.place(0, 8).unwrap();
        assert_eq!(p[0].node, 1);
        assert!(f.place(0, 1).is_none());
        f.release(&p);
        f.set_node_up(0, 0);
        assert!(!f.node_is_down(0, 0));
        assert_eq!(f.live_capacity(0), 16);
        assert_eq!(f.total_free(), 16);
        assert!(f.place(0, 16).is_some());
    }

    #[test]
    fn down_up_transitions_are_idempotent_and_bounds_checked() {
        let mut f = fleet(1);
        f.set_node_down(0, 0);
        f.set_node_down(0, 0); // second down is a no-op
        assert_eq!(f.live_capacity(0), 0);
        f.set_node_up(0, 0);
        f.set_node_up(0, 0); // second up is a no-op
        assert_eq!(f.total_free(), 8);
        // out-of-range entities are ignored, not panics
        f.set_node_down(0, 99);
        f.set_node_down(7, 0);
        f.set_node_up(7, 0);
        assert!(!f.node_is_down(0, 99));
        assert!(!f.node_is_down(7, 0));
        assert_eq!(f.live_capacity(7), 0);
    }

    #[test]
    fn unknown_class_rejected() {
        let mut f = fleet(1);
        assert!(f.place(3, 1).is_none());
        assert!(!f.can_place(3, 1));
        assert_eq!(f.class_free(3), 0);
    }
}
