//! Arrival-trace model for the online (streaming) setting: multi-jobs —
//! whole HPO grids — arrive over virtual time from multiple tenants, and
//! each job carries a latent validation score that drives ASHA-style
//! early-stopping departures at rung boundaries (DESIGN.md §Online).
//!
//! Everything is generated from a seeded [`Rng`], so a trace replays to a
//! bit-identical event sequence: `saturn online --seed 42` twice yields
//! the same schedule.

use crate::models::{DatasetSpec, ModelSpec};
use crate::util::rng::Rng;
use crate::workload::{grid, Job, TABLE1_LRS};

/// One streaming job: a grid point plus its arrival metadata. The batch
/// setting is the degenerate trace where every job arrives at t=0.
#[derive(Debug, Clone)]
pub struct OnlineJob {
    pub job: Job,
    pub arrival_s: f64,
    /// Multi-job (HPO grid) this job belongs to; rung kills rank in-group.
    pub group: usize,
    /// Tenant priority weight (>= 1.0; higher launches first).
    pub priority: f64,
    /// Optional completion deadline, seconds after arrival.
    pub deadline_s: Option<f64>,
    /// Latent validation score (higher = better): the quality signal an
    /// early-stopping rule would read off the real loss curves.
    pub score: f64,
}

impl OnlineJob {
    /// Wrap a batch job: arrives at t=0, neutral priority, no deadline.
    pub fn batch(job: &Job) -> OnlineJob {
        OnlineJob {
            job: job.clone(),
            arrival_s: 0.0,
            group: 0,
            priority: 1.0,
            deadline_s: None,
            score: 0.0,
        }
    }
}

/// How multi-job arrival instants are drawn.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential inter-arrival gaps.
    Poisson { rate_per_hour: f64 },
    /// Bursty arrivals: Poisson burst instants at `rate_per_hour`, each
    /// burst dropping `burst_size` multi-jobs back to back (the "Monday
    /// morning" pattern that stresses elastic re-optimization).
    Burst { rate_per_hour: f64, burst_size: usize },
}

/// Knobs of the streaming scenario family (see README §Online knobs).
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub seed: u64,
    /// Number of multi-jobs (HPO grids) in the trace.
    pub multijobs: usize,
    pub process: ArrivalProcess,
    /// Learning rates per grid (<= TABLE1_LRS.len()).
    pub grid_lrs: usize,
    /// Batch sizes per grid (<= 2: {16, 32}).
    pub grid_batches: usize,
    pub epochs: u32,
    /// Tenant classes; tenant `k` gets priority weight `k + 1`.
    pub tenants: usize,
    /// Completion deadline granted to every job, seconds after arrival.
    pub deadline_slack_s: Option<f64>,
    /// Within a [`ArrivalProcess::Burst`], the k-th multi-job of a
    /// burst arrives `k * burst_stagger_s` seconds after the burst
    /// instant instead of exactly on it — 64 siblings submitted over a
    /// few seconds rather than one coincident tick. This is the shape
    /// the event-coalescing window (`SimConfig::coalesce_window_s`)
    /// folds back into one re-solve. `0` (the default) keeps bursts
    /// coincident, bit-identical to the historical generator.
    pub burst_stagger_s: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 0,
            multijobs: 4,
            process: ArrivalProcess::Poisson { rate_per_hour: 2.0 },
            grid_lrs: 2,
            grid_batches: 2,
            epochs: 1,
            tenants: 2,
            deadline_slack_s: None,
            burst_stagger_s: 0.0,
        }
    }
}

/// A generated stream of multi-jobs, ready for `sim::simulate_online`.
#[derive(Debug, Clone)]
pub struct Trace {
    pub jobs: Vec<OnlineJob>,
    /// Number of multi-jobs (groups).
    pub groups: usize,
    /// Last arrival instant.
    pub horizon_s: f64,
}

/// Generate a deterministic arrival trace. Job ids are dense (0..n) in
/// arrival order, as the simulator requires.
pub fn generate_trace(cfg: &TraceConfig) -> Trace {
    let mut rng = Rng::new(cfg.seed);
    let models = [ModelSpec::resnet200(), ModelSpec::gpt2_xl(),
                  ModelSpec::vit_g(), ModelSpec::gpt_j()];
    let lrs = &TABLE1_LRS[..cfg.grid_lrs.clamp(1, TABLE1_LRS.len())];
    let batches: &[u32] = match cfg.grid_batches.clamp(1, 2) {
        1 => &[32],
        _ => &[16, 32],
    };

    // arrival instants per multi-job
    let mut arrivals = Vec::with_capacity(cfg.multijobs);
    let mut t = 0.0f64;
    match cfg.process {
        ArrivalProcess::Poisson { rate_per_hour } => {
            let rate = (rate_per_hour / 3600.0).max(1e-9);
            for _ in 0..cfg.multijobs {
                t += rng.exp(rate);
                arrivals.push(t);
            }
        }
        ArrivalProcess::Burst { rate_per_hour, burst_size } => {
            let rate = (rate_per_hour / 3600.0).max(1e-9);
            let burst = burst_size.max(1);
            let stagger = cfg.burst_stagger_s.max(0.0);
            while arrivals.len() < cfg.multijobs {
                t += rng.exp(rate);
                for k in 0..burst {
                    if arrivals.len() < cfg.multijobs {
                        arrivals.push(t + k as f64 * stagger);
                    }
                }
                // keep arrival instants (and thus job ids) monotone
                // even when the staggered burst outlasts the next gap
                t = arrivals.last().copied().unwrap_or(t);
            }
        }
    }

    let mut jobs = Vec::new();
    for (group, &arrival_s) in arrivals.iter().enumerate() {
        let model = models[rng.usize(models.len())].clone();
        let dataset = DatasetSpec {
            name: format!("stream{group}"),
            samples: 1024 + rng.range(0, 4096) as u64,
        };
        let tenant = rng.usize(cfg.tenants.max(1));
        let priority = 1.0 + tenant as f64;
        let mut grid_jobs = grid(&[model], &dataset, lrs, batches, cfg.epochs);
        for j in grid_jobs.iter_mut() {
            let id = jobs.len() + j.id;
            j.name = format!("g{group}-{}", j.name);
            j.id = id;
        }
        for j in grid_jobs {
            jobs.push(OnlineJob {
                job: j,
                arrival_s,
                group,
                priority,
                deadline_s: cfg.deadline_slack_s,
                score: rng.f64(),
            });
        }
    }
    let horizon_s = arrivals.iter().copied().fold(t, f64::max);
    Trace { jobs, groups: arrivals.len(), horizon_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_seed_deterministic() {
        let cfg = TraceConfig { seed: 42, multijobs: 5, ..Default::default() };
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.job.name, y.job.name);
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.score, y.score);
            assert_eq!(x.priority, y.priority);
        }
        let c = generate_trace(&TraceConfig { seed: 43, ..cfg });
        assert!(a.jobs.iter().zip(&c.jobs).any(|(x, y)| {
            x.arrival_s != y.arrival_s || x.score != y.score
        }));
    }

    #[test]
    fn ids_are_dense_and_groups_sized() {
        let cfg = TraceConfig { seed: 7, multijobs: 3, grid_lrs: 2,
                                grid_batches: 2, ..Default::default() };
        let t = generate_trace(&cfg);
        assert_eq!(t.groups, 3);
        assert_eq!(t.jobs.len(), 3 * 4); // 2 lrs x 2 batches per grid
        for (i, oj) in t.jobs.iter().enumerate() {
            assert_eq!(oj.job.id, i);
            assert!(oj.group < 3);
            assert!((0.0..1.0).contains(&oj.score));
            assert!(oj.priority >= 1.0);
        }
    }

    #[test]
    fn arrivals_are_sorted_and_jobs_share_group_arrival() {
        let t = generate_trace(&TraceConfig { seed: 1, multijobs: 6,
                                              ..Default::default() });
        let mut last = 0.0;
        for oj in &t.jobs {
            assert!(oj.arrival_s >= last - 1e-12);
            last = last.max(oj.arrival_s);
        }
        assert!(t.horizon_s >= last - 1e-9);
    }

    #[test]
    fn burst_process_clusters_arrivals() {
        let t = generate_trace(&TraceConfig {
            seed: 3,
            multijobs: 6,
            process: ArrivalProcess::Burst { rate_per_hour: 1.0, burst_size: 3 },
            ..Default::default()
        });
        // 6 multijobs in bursts of 3 -> exactly 2 distinct arrival instants
        let mut instants: Vec<f64> =
            t.jobs.iter().map(|j| j.arrival_s).collect();
        instants.dedup();
        assert_eq!(instants.len(), 2, "{instants:?}");
    }

    #[test]
    fn burst_stagger_spreads_siblings_and_extends_horizon() {
        let cfg = TraceConfig {
            seed: 3,
            multijobs: 6,
            process: ArrivalProcess::Burst { rate_per_hour: 1.0,
                                             burst_size: 3 },
            burst_stagger_s: 2.0,
            ..Default::default()
        };
        let t = generate_trace(&cfg);
        // every multi-job now lands on its own instant, in order
        let instants: Vec<f64> =
            t.jobs.iter().map(|j| j.arrival_s).collect();
        let mut uniq = instants.clone();
        uniq.dedup();
        assert_eq!(uniq.len(), 6, "{instants:?}");
        let mut last = 0.0f64;
        for &a in &instants {
            assert!(a >= last - 1e-12, "staggered arrivals not monotone");
            last = last.max(a);
        }
        assert!(t.horizon_s >= last - 1e-9,
                "horizon {} < last staggered arrival {last}", t.horizon_s);
        // zero stagger reproduces the historical coincident bursts
        let t0 = generate_trace(&TraceConfig {
            burst_stagger_s: 0.0,
            ..cfg
        });
        let mut i0: Vec<f64> =
            t0.jobs.iter().map(|j| j.arrival_s).collect();
        i0.dedup();
        assert_eq!(i0.len(), 2);
    }

    #[test]
    fn batch_wrapper_is_neutral() {
        let jobs = crate::workload::toy_workload(3);
        let oj = OnlineJob::batch(&jobs[1]);
        assert_eq!(oj.arrival_s, 0.0);
        assert_eq!(oj.priority, 1.0);
        assert!(oj.deadline_s.is_none());
        assert_eq!(oj.job.id, 1);
    }
}
