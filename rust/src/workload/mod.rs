//! Model-selection workload generator (paper Table 1).
//!
//! A *multi-job* is the unit Saturn optimizes: a set of fine-tuning jobs
//! produced by hyper-parameter grids. Table 1's two workloads are
//! {GPT-2, GPT-J} x LR {1e-5,1e-4,1e-3} x batch {16,32} on WikiText-2 and
//! {ViT-G, ResNet-200} x same LRs x batch {64,128} on ImageNet, 10 epochs.

pub mod arrivals;

pub use arrivals::{generate_trace, ArrivalProcess, OnlineJob, Trace,
                   TraceConfig};

use crate::models::{DatasetSpec, ModelSpec};

/// One fine-tuning job in a multi-job (a point of the HPO grid).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: usize,
    pub name: String,
    pub model: ModelSpec,
    pub dataset: DatasetSpec,
    pub lr: f64,
    pub batch: u32,
    pub epochs: u32,
}

impl Job {
    pub fn total_steps(&self) -> u64 {
        self.dataset.steps_per_epoch(self.batch) * self.epochs as u64
    }
}

/// Cartesian HPO grid over models x LRs x batch sizes (the paper's trial
/// generation; mirrors `SaturnTrial` construction in Figure 1B).
pub fn grid(models: &[ModelSpec], dataset: &DatasetSpec, lrs: &[f64],
            batches: &[u32], epochs: u32) -> Vec<Job> {
    let mut jobs = Vec::new();
    for model in models {
        for &lr in lrs {
            for &batch in batches {
                let id = jobs.len();
                jobs.push(Job {
                    id,
                    name: format!("{}-lr{lr:.0e}-bs{batch}", model.name),
                    model: model.clone(),
                    dataset: dataset.clone(),
                    lr,
                    batch,
                    epochs,
                });
            }
        }
    }
    jobs
}

pub const TABLE1_LRS: [f64; 3] = [1e-5, 1e-4, 1e-3];

/// Table 1 row 1: language workload (12 jobs).
pub fn wikitext_workload() -> Vec<Job> {
    grid(&[ModelSpec::gpt2_xl(), ModelSpec::gpt_j()],
         &DatasetSpec::wikitext2(), &TABLE1_LRS, &[16, 32], 10)
}

/// Table 1 row 2: vision workload (12 jobs).
pub fn imagenet_workload() -> Vec<Job> {
    grid(&[ModelSpec::vit_g(), ModelSpec::resnet200()],
         &DatasetSpec::imagenet(), &TABLE1_LRS, &[64, 128], 10)
}

/// Smaller synthetic multi-job for tests/examples: `n` jobs cycling over
/// the zoo with short epochs.
pub fn toy_workload(n: usize) -> Vec<Job> {
    let zoo = [ModelSpec::resnet200(), ModelSpec::gpt2_xl(),
               ModelSpec::vit_g(), ModelSpec::gpt_j()];
    let mut jobs = Vec::new();
    for i in 0..n {
        let model = zoo[i % zoo.len()].clone();
        let dataset = DatasetSpec { name: "toy".into(), samples: 4096 };
        jobs.push(Job {
            id: i,
            name: format!("toy{i}-{}", model.name),
            model,
            dataset,
            lr: 1e-4,
            batch: 32,
            epochs: 1,
        });
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_grids_have_12_jobs() {
        assert_eq!(wikitext_workload().len(), 12);
        assert_eq!(imagenet_workload().len(), 12);
    }

    #[test]
    fn ids_are_dense_and_names_unique() {
        let jobs = wikitext_workload();
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i);
        }
        let mut names: Vec<_> = jobs.iter().map(|j| j.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 12);
    }

    #[test]
    fn steps_scale_inversely_with_batch() {
        let jobs = imagenet_workload();
        let bs64 = jobs.iter().find(|j| j.batch == 64).unwrap();
        let bs128 = jobs.iter().find(|j| j.batch == 128).unwrap();
        assert!(bs64.total_steps() > bs128.total_steps());
    }

    #[test]
    fn wikitext_epochs_to_steps() {
        let j = &wikitext_workload()[0]; // GPT-2 bs16
        assert_eq!(j.total_steps(), 150 * 10);
    }
}
