//! `saturn` CLI: the leader entrypoint.
//!
//! Subcommands:
//!   table2           reproduce paper Table 2 (simulated p4d fleet)
//!   plan             solve one workload and print the joint plan
//!   online           streaming multi-tenant HPO: arrivals + early stopping
//!   trace-summarize  analyze a flight-recorder journal (README §Tracing)
//!   workload         print the Table 1 HPO grids
//!   e2e              real model selection over the AOT GPT-mini artifacts
//!   info             runtime/artifact diagnostics

use anyhow::{anyhow, bail, Result};
use saturn::cluster::ClusterSpec;
use saturn::coordinator::{real_grid, Coordinator};
use saturn::exp;
use saturn::faults::FaultConfig;
use saturn::objective::{JobTerms, Objective};
use saturn::obs::summary;
use saturn::obs::trace::{chrome_trace, parse_jsonl, write_jsonl, Tracer};
use saturn::online::{profile_trace, run_trace_knobs, warm_cold_probe,
                     OnlineKnobs, ONLINE_SYSTEMS};
use saturn::parallelism::default_library;
use saturn::perf::{DriftConfig, PerfModel};
use saturn::saturn::introspect::DEFAULT_DRIFT_THRESHOLD;
use saturn::saturn::solver::{check_fleet_feasibility, solve_joint_traced,
                             SolverMode};
use saturn::sim::engine::{RungConfig, SimConfig};
use saturn::trials::profile_analytic;
use saturn::util::cli::Args;
use saturn::util::json::Json;
use saturn::util::logging;
use saturn::workload::{generate_trace, ArrivalProcess, TraceConfig};

fn main() -> Result<()> {
    logging::init();
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("table2") => cmd_table2(&args),
        Some("plan") => cmd_plan(&args),
        Some("online") => cmd_online(&args),
        Some("trace-summarize") => cmd_trace_summarize(&args),
        Some("workload") => cmd_workload(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("info") => cmd_info(),
        _ => {
            println!("saturn — efficient multi-large-model deep learning \
                      (reproduction)\n");
            println!("usage: saturn <command> [--flags]\n");
            println!("  table2    [--workload wikitext|imagenet|all] [--seed N]");
            println!("  plan      [--workload ...] [--nodes N]");
            println!("            [--fleet a100:32,h100:16]");
            println!("            [--mode joint|greedy|rolling|sharded]");
            println!("            [--cell-size N]");
            println!("            [--objective makespan|tardiness|wjct]");
            println!("            [--alpha F] [--deadline-weight F]");
            println!("            [--trace PATH] [--trace-chrome PATH]");
            println!("  online    [--seed N] [--multijobs N] [--rate-per-hour X]");
            println!("            [--burst N] [--tenants N] [--rungs 0.25,0.5]");
            println!("            [--kill-fraction F] [--deadline-slack-s S]");
            println!("            [--nodes N] [--fleet a100:32,h100:16]");
            println!("            [--mode joint|greedy|rolling|sharded]");
            println!("            [--cell-size N]");
            println!("            [--objective makespan|tardiness|wjct]");
            println!("            [--alpha F] [--deadline-weight F]");
            println!("            [--drift F] [--drift-seed N]");
            println!("            [--drift-correction on|off|oracle]");
            println!("            [--drift-threshold F]");
            println!("            [--drift-tenant-spread F]");
            println!("            [--faults] [--mtbf H] [--fault-seed N]");
            println!("            [--checkpoint-interval S]");
            println!("            [--incremental on|off] [--resolve-budget-ms MS]");
            println!("            [--node-budget N] [--coalesce-window-s S]");
            println!("            [--burst-stagger-s S]");
            println!("            [--json PATH]");
            println!("            [--trace PATH] [--trace-chrome PATH]");
            println!("            [--trace-system SYSTEM]");
            println!("  trace-summarize <trace.jsonl> [--json PATH]");
            println!("  workload  [--workload ...]");
            println!("  e2e       [--model tiny|small] [--lanes N] [--steps N]");
            println!("  info");
            Ok(())
        }
    }
}

fn cmd_table2(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 0);
    let which = args.str_or("workload", "all");
    let workloads: Vec<&str> = match which.as_str() {
        "all" => vec!["wikitext", "imagenet"],
        w => vec![Box::leak(w.to_string().into_boxed_str()) as &str],
    };
    for w in workloads {
        let cells = exp::run_row(w, seed);
        print!("{}", exp::format_row(w, &cells));
        println!();
    }
    Ok(())
}

/// Resolve the fleet from `--fleet a100:32,h100:16` (preferred) or the
/// homogeneous `--nodes N` shorthand.
fn fleet_from_args(args: &Args) -> Result<ClusterSpec> {
    match args.get("fleet") {
        Some(spec) => ClusterSpec::parse_fleet(spec).map_err(|e| anyhow!(e)),
        None => Ok(ClusterSpec::p4d(args.usize_or("nodes", 1) as u32)),
    }
}

/// Flight recorder from `--trace PATH` / `--trace-chrome PATH`: either
/// flag turns the journal on (with wall stamps — the CLI is a terminal
/// run, not a replay fixture); neither leaves it off at zero cost.
fn tracer_from_args(args: &Args) -> Tracer {
    if args.get("trace").is_some() || args.get("trace-chrome").is_some() {
        Tracer::on()
    } else {
        Tracer::off()
    }
}

/// Write the recorded journal to the `--trace` (JSONL) and/or
/// `--trace-chrome` (Perfetto-loadable trace_event JSON) paths.
fn write_trace_outputs(args: &Args, tracer: &Tracer) -> Result<()> {
    if !tracer.is_enabled() {
        return Ok(());
    }
    let events = tracer.events();
    if let Some(path) = args.get("trace") {
        std::fs::write(path, write_jsonl(&events))?;
        println!("wrote {path} ({} trace events)", events.len());
    }
    if let Some(path) = args.get("trace-chrome") {
        std::fs::write(path, chrome_trace(&events).to_string())?;
        println!("wrote {path} (chrome trace)");
    }
    Ok(())
}

/// Resolve `--objective makespan|tardiness|wjct` with its `--alpha` /
/// `--deadline-weight` knobs (README §Objectives).
fn objective_from_args(args: &Args) -> Result<Objective> {
    let name = args.str_or("objective", "makespan");
    let alpha = args.f64_or("alpha", 0.5);
    let deadline_weight = args.f64_or("deadline-weight", 1.0);
    Objective::parse(&name, alpha, deadline_weight).map_err(|e| anyhow!(e))
}

fn cmd_plan(args: &Args) -> Result<()> {
    let workload = args.str_or("workload", "wikitext");
    let mode = match args.str_or("mode", "joint").as_str() {
        "greedy" => SolverMode::Heuristic,
        "rolling" => SolverMode::rolling_default(),
        "sharded" => SolverMode::Sharded {
            cell_size: args.usize_or("cell-size", 64),
        },
        _ => SolverMode::Joint,
    };
    let objective = objective_from_args(args)?;
    let jobs = exp::workload_by_name(&workload);
    let cluster = fleet_from_args(args)?;
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, &cluster);
    let remaining: Vec<(usize, u64)> =
        jobs.iter().map(|j| (j.id, j.total_steps())).collect();
    // surface memory-infeasible jobs as a CLI error, not a solver panic
    check_fleet_feasibility(&remaining, &profiles, &cluster)
        .map_err(|e| anyhow!(e))?;
    // batch jobs carry no deadlines/arrivals: neutral objective terms
    let terms: Vec<JobTerms> = remaining
        .iter()
        .map(|&(id, _)| JobTerms::neutral(id))
        .collect();
    let tracer = tracer_from_args(args);
    let (plan, stats) =
        solve_joint_traced(&remaining, &profiles, &cluster, mode, 1.0,
                           None, objective, &terms, &tracer);
    println!("joint plan for '{workload}' ({} objective) on fleet [{}] \
              ({} GPUs, {} node(s)):", objective.name(),
             cluster.fleet_desc(), cluster.total_gpus(),
             cluster.total_nodes());
    println!("{:<24} {:>8} {:>6} {:>6} {:>12}", "job", "tech", "class",
             "gpus", "runtime");
    for p in &plan.choices {
        let job = &jobs[p.job_id];
        println!("{:<24} {:>8} {:>6} {:>6} {:>11.1}s", job.name,
                 lib.get(p.tech).name(), cluster.class(p.class).name,
                 p.gpus, p.runtime_s);
    }
    println!("\npredicted makespan: {:.2} h (lower bound {:.2} h)",
             plan.predicted_makespan_s / 3600.0, plan.lower_bound_s / 3600.0);
    println!("solver: {:.1} ms, {} B&B nodes, {} pivots ({} eta, {} \
              refactor), warm-basis {:.0}%, {} window(s), optimal={}",
             stats.wall_s * 1e3, stats.milp_nodes, stats.lp_pivots,
             stats.eta_updates, stats.refactorizations,
             100.0 * stats.warm_hit_rate(), stats.windows.max(1),
             stats.proved_optimal);
    if stats.cells > 0 {
        println!("sharded: {} cell(s), {} column(s) priced, shard gap \
                  {:.2}% vs monolithic bound",
                 stats.cells, stats.columns_priced,
                 100.0 * stats.shard_gap);
    }
    write_trace_outputs(args, &tracer)?;
    Ok(())
}

/// Analyze a flight-recorder journal offline: phase-time breakdown,
/// re-solve cause histogram, decision-latency tails, utilization
/// timeline (README §Tracing).
fn cmd_trace_summarize(args: &Args) -> Result<()> {
    let path = args.positional.get(1).ok_or_else(|| {
        anyhow!("usage: saturn trace-summarize <trace.jsonl> [--json PATH]")
    })?;
    let text = std::fs::read_to_string(path)?;
    let events = parse_jsonl(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    let s = summary::summarize(&events).map_err(|e| anyhow!(e))?;
    print!("{}", summary::render(&s));
    if let Some(out) = args.get("json") {
        std::fs::write(out, summary::to_json(&s).to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Streaming scenario driver: generate a seeded arrival trace, run every
/// online system on it, verify the replay is bit-identical, and report
/// the warm-vs-cold re-solve cost on the last arrival event.
fn cmd_online(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let multijobs = args.usize_or("multijobs", 4);
    let rate = args.f64_or("rate-per-hour", 2.0);
    let burst = args.usize_or("burst", 0);
    let tenants = args.usize_or("tenants", 2);
    let kill_fraction = args.f64_or("kill-fraction", 0.5);
    let mode = match args.str_or("mode", "joint").as_str() {
        "greedy" => SolverMode::Heuristic,
        "rolling" => SolverMode::rolling_default(),
        "sharded" => SolverMode::Sharded {
            cell_size: args.usize_or("cell-size", 64),
        },
        _ => SolverMode::Joint,
    };
    let objective = objective_from_args(args)?;
    let process = if burst > 0 {
        ArrivalProcess::Burst { rate_per_hour: rate, burst_size: burst }
    } else {
        ArrivalProcess::Poisson { rate_per_hour: rate }
    };
    let cfg = TraceConfig {
        seed,
        multijobs,
        process,
        grid_lrs: args.usize_or("grid-lrs", 2),
        grid_batches: args.usize_or("grid-batches", 2),
        epochs: args.usize_or("epochs", 1) as u32,
        tenants,
        deadline_slack_s: args.get("deadline-slack-s")
            .and_then(|s| s.parse().ok()),
        burst_stagger_s: args.f64_or("burst-stagger-s", 0.0).max(0.0),
    };
    let trace = generate_trace(&cfg);
    let fractions: Vec<f64> = args
        .str_or("rungs", "0.25,0.5")
        .split(',')
        .filter_map(|s| s.trim().parse::<f64>().ok())
        .filter(|f| (0.0..1.0).contains(f) && *f > 0.0)
        .collect();
    let rungs = if kill_fraction > 0.0 && !fractions.is_empty() {
        Some(RungConfig { fractions, kill_fraction: kill_fraction.min(0.95) })
    } else {
        None
    };

    // estimate-drift knobs (DESIGN.md §4.4): --drift 0.1 turns on 10%
    // seeded truth drift; the planner corrects online unless --drift-
    // correction is off (frozen profiled estimates) or oracle (reads
    // the frozen truth at each replan — the unreachable upper bound)
    let drift_mag = args.f64_or("drift", 0.0);
    let drift_seed = args.u64_or("drift-seed", seed);
    let correction = args.str_or("drift-correction", "on");
    if !matches!(correction.as_str(), "on" | "off" | "oracle") {
        bail!("--drift-correction must be on|off|oracle, got '{correction}'");
    }
    let threshold = args.f64_or("drift-threshold", DEFAULT_DRIFT_THRESHOLD);
    let drift_threshold = if threshold > 0.0 { Some(threshold) } else { None };
    // per-tenant drift profiles: tenant class k ramps at
    // magnitude * (1 + spread * k); 0 = every tenant drifts alike
    let tenant_spread = args.f64_or("drift-tenant-spread", 0.0);
    let mut drift_cfg = if drift_mag > 0.0 {
        DriftConfig::uniform(drift_seed, drift_mag)
    } else {
        DriftConfig::none()
    };
    drift_cfg.tenant_spread = tenant_spread;

    // fault-injection knobs (DESIGN.md §4.7): --faults (or an explicit
    // --mtbf) turns on the seeded node-failure + crash-hazard layer;
    // --checkpoint-interval sets the rollback granularity (0 =
    // continuous checkpointing, i.e. no lost work)
    let faults_on = args.has("faults") || args.get("mtbf").is_some();
    let mtbf_h = args.f64_or("mtbf", 8.0);
    let fault_seed = args.u64_or("fault-seed", seed);
    let checkpoint_interval_s =
        args.f64_or("checkpoint-interval", 1800.0);
    let fault_cfg = if faults_on {
        FaultConfig::uniform(fault_seed, mtbf_h)
    } else {
        FaultConfig::none()
    };

    // incremental re-solve knobs (DESIGN.md §4.9): --incremental on keeps
    // the column pools / basis warm across events; --resolve-budget-ms /
    // --node-budget cap each re-solve (best incumbent on expiry);
    // --coalesce-window-s debounces staggered arrival bursts into one
    // delta re-solve. All default off -> bit-identical to the historical
    // event loop.
    let incremental = match args.str_or("incremental", "off").as_str() {
        "on" => true,
        "off" => false,
        other => bail!("--incremental must be on|off, got '{other}'"),
    };
    let resolve_budget_ms = args.get("resolve-budget-ms")
        .and_then(|s| s.parse::<f64>().ok())
        .filter(|v| *v > 0.0);
    let node_budget = args.get("node-budget")
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|v| *v > 0);
    let coalesce_window_s = args.f64_or("coalesce-window-s", 0.0).max(0.0);
    let knobs = OnlineKnobs { incremental, resolve_budget_ms, node_budget };

    let cluster = fleet_from_args(args)?;
    println!("=== online: {} multi-jobs / {} jobs over {:.1} h on fleet \
              [{}], seed {seed} ===",
             trace.groups, trace.jobs.len(), trace.horizon_s / 3600.0,
             cluster.fleet_desc());
    if let Some(rc) = &rungs {
        println!("early stopping: rungs {:?}, kill fraction {:.0}%",
                 rc.fractions, rc.kill_fraction * 100.0);
    }
    if !objective.is_makespan() {
        println!("objective: {} ({})", objective.name(), match objective {
            Objective::WeightedTardiness { deadline_weight } => {
                format!("deadline weight {deadline_weight:.2}")
            }
            Objective::WeightedJct { alpha } => {
                format!("alpha {alpha:.2}")
            }
            Objective::Makespan => unreachable!(),
        });
    }
    if drift_mag > 0.0 {
        println!("estimate drift: {:.0}% (seed {drift_seed}), correction \
                  {correction}, re-solve threshold {:.2}, tenant spread \
                  {tenant_spread:.2}",
                 drift_mag * 100.0, threshold.max(0.0));
    }
    if faults_on {
        println!("fault injection: per-node MTBF {mtbf_h:.1} h (seed \
                  {fault_seed}), checkpoint every {checkpoint_interval_s:.0} \
                  s");
    }
    if incremental || resolve_budget_ms.is_some() || node_budget.is_some()
        || coalesce_window_s > 0.0
    {
        println!("incremental re-solve: {}, budget {} / {}, coalesce \
                  window {coalesce_window_s:.1} s",
                 if incremental { "on" } else { "off" },
                 resolve_budget_ms
                     .map_or("no deadline".to_string(),
                             |v| format!("{v:.0} ms")),
                 node_budget
                     .map_or("no node cap".to_string(),
                             |v| format!("{v} nodes")));
    }
    let profiles = profile_trace(&trace, &cluster);
    // tenant class per job (priority k+1 <-> class k) for the
    // per-tenant drift profiles
    let tenant_class: Vec<f64> =
        trace.jobs.iter().map(|o| o.priority - 1.0).collect();
    let make_perf = || match correction.as_str() {
        "off" => PerfModel::with_drift_tenants(
            &profiles, drift_cfg.clone(), false, tenant_class.clone()),
        "oracle" => PerfModel::oracle_tenants(
            &profiles, drift_cfg.clone(), tenant_class.clone()),
        _ => PerfModel::with_drift_tenants(
            &profiles, drift_cfg.clone(), true, tenant_class.clone()),
    };
    // surface memory-infeasible jobs before the event loop would deadlock
    let all_jobs: Vec<(usize, u64)> = trace
        .jobs
        .iter()
        .map(|o| (o.job.id, o.job.total_steps()))
        .collect();
    check_fleet_feasibility(&all_jobs, &profiles, &cluster)
        .map_err(|e| anyhow!(e))?;

    // flight recorder: --trace / --trace-chrome journal ONE system's
    // run (--trace-system, default online-saturn); the others stay at
    // the zero-cost off tracer so the comparison row is undisturbed
    let tracer = tracer_from_args(args);
    let trace_system = args.str_or("trace-system", "online-saturn");
    if tracer.is_enabled()
        && !ONLINE_SYSTEMS.contains(&trace_system.as_str())
    {
        bail!("--trace-system must be one of {ONLINE_SYSTEMS:?}, \
               got '{trace_system}'");
    }
    let mut metrics = Vec::new();
    let mut saturn_result = None;
    for sys in ONLINE_SYSTEMS {
        let mut perf = make_perf();
        let sim_cfg = SimConfig {
            objective,
            faults: fault_cfg.clone(),
            checkpoint_interval_s,
            coalesce_window_s,
            trace: if sys == trace_system {
                tracer.clone()
            } else {
                Tracer::off()
            },
            ..SimConfig::default()
        };
        let (r, m) = run_trace_knobs(&trace, rungs.as_ref(), &mut perf,
                                     &cluster, sys, mode,
                                     Some(drift_threshold), &sim_cfg, knobs);
        if sys == "online-saturn" {
            saturn_result = Some(r);
        }
        metrics.push(m);
    }
    print!("\n{}", exp::format_online_row(&metrics));

    // solver stress + estimate-layer summary (satellite of ISSUE 4: a
    // capped/limit-hit count that climbs under drift-triggered re-solves
    // is the solver degrading, not a silent mystery)
    let sat = &metrics[2];
    println!("\nsolver stress: {} capped node LP(s), {} limit-reached \
              solve(s), {} drift re-solve(s)",
             sat.lp_capped, sat.milp_limit_reached,
             sat.drift_resolves.unwrap_or(0));
    println!("solver factors: {} eta update(s), {} refactorization(s), \
              {} column(s) priced, {} cell(s), shard gap {:.2}%",
             sat.eta_updates.unwrap_or(0),
             sat.refactorizations.unwrap_or(0),
             sat.columns_priced.unwrap_or(0),
             sat.solver_cells.unwrap_or(0),
             100.0 * sat.shard_gap.unwrap_or(0.0));
    if incremental || resolve_budget_ms.is_some() || node_budget.is_some()
        || coalesce_window_s > 0.0
    {
        println!("incremental layer: {} delta / {} full re-solve(s), {} \
                  budget-exhausted, {} coalesced event(s), solve wall p50 \
                  {:.2} ms / p99 {:.2} ms",
                 sat.delta_resolves.unwrap_or(0),
                 sat.full_resolves.unwrap_or(0),
                 sat.budget_exhausted.unwrap_or(0),
                 sat.coalesced_events,
                 1e3 * sat.solve_p50_s.unwrap_or(0.0),
                 1e3 * sat.solve_p99_s.unwrap_or(0.0));
    }
    if drift_mag > 0.0 {
        println!("estimate layer: {} observation(s), mean |ln(obs/est)| \
                  {:.4}", sat.observations, sat.estimate_mae);
    }
    if faults_on {
        println!("fault layer: {} node failure(s), {} fault \
                  preemption(s), {:.1} GPU-h lost, mean recovery {:.0} s, \
                  goodput {:.4} (utilization {:.4}), {} greedy \
                  fallback(s)",
                 sat.failures, sat.fault_preemptions,
                 sat.lost_work_gpu_s / 3600.0, sat.mean_recovery_s,
                 sat.goodput, sat.gpu_utilization,
                 sat.solver_fallbacks.unwrap_or(0));
    }

    // determinism: the acceptance bar is a bit-identical double replay
    // (first replay reused from the comparison loop above)
    let a = saturn_result.expect("online-saturn ran");
    let mut perf = make_perf();
    // the replay runs UNTRACED — passing bit-identity against a traced
    // first run is exactly the recorder's determinism contract
    let replay_cfg = SimConfig {
        objective,
        faults: fault_cfg.clone(),
        checkpoint_interval_s,
        coalesce_window_s,
        ..SimConfig::default()
    };
    let (b, _) = run_trace_knobs(&trace, rungs.as_ref(), &mut perf,
                                 &cluster, "online-saturn", mode,
                                 Some(drift_threshold), &replay_cfg, knobs);
    if resolve_budget_ms.is_some() {
        // a wall-clock deadline makes each re-solve timing-dependent by
        // design (best incumbent at expiry), so bit-identity across
        // replays is not part of the contract; node budgets are.
        println!("\ndeterminism: skipped (wall-clock --resolve-budget-ms \
                  makes replays timing-dependent; {} departures)",
                 a.finish_times.len());
    } else if a.finish_times != b.finish_times || a.jct_s != b.jct_s
        || a.early_stopped != b.early_stopped || a.launches != b.launches {
        bail!("online replay diverged for seed {seed}");
    } else {
        println!("\ndeterminism: OK (two replays produced bit-identical \
                  schedules, {} departures)", a.finish_times.len());
    }

    let p = warm_cold_probe(&trace, &profiles, &cluster);
    println!("warm-start probe ({} -> {} jobs): cold {:.2} ms / {} nodes, \
              warm {:.2} ms / {} nodes",
             p.jobs_before, p.jobs_after, p.cold.wall_s * 1e3,
             p.cold.milp_nodes, p.warm.wall_s * 1e3, p.warm.milp_nodes);

    if let Some(path) = args.get("json") {
        let record = Json::obj(vec![
            ("seed", Json::num(seed as f64)),
            ("multijobs", Json::num(multijobs as f64)),
            ("jobs", Json::num(trace.jobs.len() as f64)),
            ("objective", Json::str(objective.name())),
            ("drift", Json::num(drift_mag)),
            ("drift_correction", Json::str(&correction)),
            ("faults", Json::Bool(faults_on)),
            ("mtbf_hours",
             Json::num(if faults_on { mtbf_h } else { 0.0 })),
            ("checkpoint_interval_s", Json::num(checkpoint_interval_s)),
            ("incremental", Json::Bool(incremental)),
            ("resolve_budget_ms",
             resolve_budget_ms.map_or(Json::Null, Json::num)),
            ("node_budget",
             node_budget.map_or(Json::Null, |v| Json::num(v as f64))),
            ("coalesce_window_s", Json::num(coalesce_window_s)),
            ("systems",
             Json::arr(metrics.iter().map(|m| m.to_json()))),
        ]);
        std::fs::write(path, record.to_string())?;
        println!("wrote {path}");
    }
    write_trace_outputs(args, &tracer)?;
    Ok(())
}

fn cmd_workload(args: &Args) -> Result<()> {
    let which = args.str_or("workload", "all");
    let names: Vec<&str> = match which.as_str() {
        "all" => vec!["wikitext", "imagenet"],
        w => vec![Box::leak(w.to_string().into_boxed_str()) as &str],
    };
    for name in names {
        let jobs = exp::workload_by_name(name);
        println!("== {name}: {} jobs (Table 1 grid) ==", jobs.len());
        println!("{:<24} {:>10} {:>6} {:>8} {:>12}", "job", "params", "bs",
                 "epochs", "steps");
        for j in &jobs {
            println!("{:<24} {:>9.2}B {:>6} {:>8} {:>12}", j.name,
                     j.model.params / 1e9, j.batch, j.epochs, j.total_steps());
        }
        println!();
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let model = args.str_or("model", "tiny");
    let lanes = args.usize_or("lanes", 2);
    let steps = args.u64_or("steps", 60);
    let coord = Coordinator::new(lanes)?;
    let jobs = real_grid(&[(model.as_str(), 8)],
                         &[1e-3, 3e-3, 1e-4], steps);
    println!("e2e model selection: {} jobs x {steps} steps on {lanes} lanes",
             jobs.len());
    let r = coord.run_model_selection(&jobs, 42)?;
    println!("{:<22} {:>10} {:>12} {:>8}", "job", "loss", "ms/step", "lane");
    for o in &r.outcomes {
        println!("{:<22} {:>10.4} {:>12.1} {:>8}", o.job.name(),
                 o.final_loss, o.mean_step_ms, o.lane);
    }
    println!("\nbest config: {} (loss {:.4})",
             r.outcomes[r.best].job.name(), r.outcomes[r.best].final_loss);
    println!("makespan {:.1}s | profiling {:.2}s | solver {:.3}s",
             r.makespan_s, r.profiling_s, r.solver_s);
    Ok(())
}

fn cmd_info() -> Result<()> {
    use saturn::runtime::{Engine, Manifest};
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    match Manifest::load_default() {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!("  {:<22} kind={:<6} P={:>9} file={}", a.name,
                         a.kind, a.padded_params, a.file.display());
            }
        }
        Err(e) => println!("no artifacts: {e:#}"),
    }
    Ok(())
}
