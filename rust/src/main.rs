//! `saturn` CLI: the leader entrypoint.
//!
//! Subcommands:
//!   table2     reproduce paper Table 2 (simulated p4d fleet)
//!   plan       solve one workload and print the joint plan
//!   workload   print the Table 1 HPO grids
//!   e2e        real model selection over the AOT GPT-mini artifacts
//!   info       runtime/artifact diagnostics

use anyhow::Result;
use saturn::cluster::ClusterSpec;
use saturn::coordinator::{real_grid, Coordinator};
use saturn::exp;
use saturn::parallelism::default_library;
use saturn::saturn::solver::{solve_joint, SolverMode};
use saturn::trials::profile_analytic;
use saturn::util::cli::Args;
use saturn::util::logging;

fn main() -> Result<()> {
    logging::init();
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("table2") => cmd_table2(&args),
        Some("plan") => cmd_plan(&args),
        Some("workload") => cmd_workload(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("info") => cmd_info(),
        _ => {
            println!("saturn — efficient multi-large-model deep learning \
                      (reproduction)\n");
            println!("usage: saturn <command> [--flags]\n");
            println!("  table2    [--workload wikitext|imagenet|all] [--seed N]");
            println!("  plan      [--workload ...] [--nodes N] [--mode joint|greedy]");
            println!("  workload  [--workload ...]");
            println!("  e2e       [--model tiny|small] [--lanes N] [--steps N]");
            println!("  info");
            Ok(())
        }
    }
}

fn cmd_table2(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 0);
    let which = args.str_or("workload", "all");
    let workloads: Vec<&str> = match which.as_str() {
        "all" => vec!["wikitext", "imagenet"],
        w => vec![Box::leak(w.to_string().into_boxed_str()) as &str],
    };
    for w in workloads {
        let cells = exp::run_row(w, seed);
        print!("{}", exp::format_row(w, &cells));
        println!();
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let nodes = args.usize_or("nodes", 1) as u32;
    let workload = args.str_or("workload", "wikitext");
    let mode = match args.str_or("mode", "joint").as_str() {
        "greedy" => SolverMode::Heuristic,
        _ => SolverMode::Joint,
    };
    let jobs = exp::workload_by_name(&workload);
    let cluster = ClusterSpec::p4d(nodes);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, &cluster);
    let remaining: Vec<(usize, u64)> =
        jobs.iter().map(|j| (j.id, j.total_steps())).collect();
    let (plan, stats) = solve_joint(&remaining, &profiles, &cluster, mode);
    println!("joint plan for '{workload}' on {nodes} node(s) \
              ({} GPUs):", cluster.total_gpus());
    println!("{:<24} {:>8} {:>6} {:>12}", "job", "tech", "gpus", "runtime");
    for p in &plan.choices {
        let job = &jobs[p.job_id];
        println!("{:<24} {:>8} {:>6} {:>11.1}s", job.name,
                 lib.get(p.tech).name(), p.gpus, p.runtime_s);
    }
    println!("\npredicted makespan: {:.2} h (lower bound {:.2} h)",
             plan.predicted_makespan_s / 3600.0, plan.lower_bound_s / 3600.0);
    println!("solver: {:.1} ms, {} B&B nodes, optimal={}",
             stats.wall_s * 1e3, stats.milp_nodes, stats.proved_optimal);
    Ok(())
}

fn cmd_workload(args: &Args) -> Result<()> {
    let which = args.str_or("workload", "all");
    let names: Vec<&str> = match which.as_str() {
        "all" => vec!["wikitext", "imagenet"],
        w => vec![Box::leak(w.to_string().into_boxed_str()) as &str],
    };
    for name in names {
        let jobs = exp::workload_by_name(name);
        println!("== {name}: {} jobs (Table 1 grid) ==", jobs.len());
        println!("{:<24} {:>10} {:>6} {:>8} {:>12}", "job", "params", "bs",
                 "epochs", "steps");
        for j in &jobs {
            println!("{:<24} {:>9.2}B {:>6} {:>8} {:>12}", j.name,
                     j.model.params / 1e9, j.batch, j.epochs, j.total_steps());
        }
        println!();
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let model = args.str_or("model", "tiny");
    let lanes = args.usize_or("lanes", 2);
    let steps = args.u64_or("steps", 60);
    let coord = Coordinator::new(lanes)?;
    let jobs = real_grid(&[(model.as_str(), 8)],
                         &[1e-3, 3e-3, 1e-4], steps);
    println!("e2e model selection: {} jobs x {steps} steps on {lanes} lanes",
             jobs.len());
    let r = coord.run_model_selection(&jobs, 42)?;
    println!("{:<22} {:>10} {:>12} {:>8}", "job", "loss", "ms/step", "lane");
    for o in &r.outcomes {
        println!("{:<22} {:>10.4} {:>12.1} {:>8}", o.job.name(),
                 o.final_loss, o.mean_step_ms, o.lane);
    }
    println!("\nbest config: {} (loss {:.4})",
             r.outcomes[r.best].job.name(), r.outcomes[r.best].final_loss);
    println!("makespan {:.1}s | profiling {:.2}s | solver {:.3}s",
             r.makespan_s, r.profiling_s, r.solver_s);
    Ok(())
}

fn cmd_info() -> Result<()> {
    use saturn::runtime::{Engine, Manifest};
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    match Manifest::load_default() {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!("  {:<22} kind={:<6} P={:>9} file={}", a.name,
                         a.kind, a.padded_params, a.file.display());
            }
        }
        Err(e) => println!("no artifacts: {e:#}"),
    }
    Ok(())
}
