//! The TRUTH side of the performance-model split: what the hardware
//! actually does, as opposed to what the planner believes (see
//! [`crate::perf::estimate`]).
//!
//! [`TruthModel`] wraps the profiled [`ProfileTable`] with deterministic,
//! seeded drift processes — the adversary the paper's introspection
//! mechanism was designed for (changing cluster conditions, SIGMOD
//! version §introspection):
//!
//!  * **Slow multiplicative ramps** per job: step times drift toward
//!    `1 ± ramp_magnitude` with a per-job time constant (dataloader
//!    warm-up, thermal throttling, gradual input-length shift).
//!  * **Step changes on interference events**: seeded Poisson windows per
//!    GPU class during which every job on that class slows by
//!    `interference_mult` (noisy neighbors on the shared fabric).
//!  * **Per-(job, class) noise**: a static lognormal mis-calibration of
//!    the profiled estimate — the "one or two mini-batches" probe simply
//!    measured wrong for that model/hardware pair.
//!
//! Every query is a pure function of `(job, tech, gpus, class, now)`, so
//! replays are bit-identical no matter what order the simulator asks in.
//! Only `sim::engine` may read truth; planners and baselines see the
//! estimate layer.

use crate::trials::ProfileTable;
use crate::util::rng::Rng;

/// Horizon over which interference windows are pre-drawn (longer sims
/// simply see no further windows; makespans here are tens of hours).
const INTERFERENCE_HORIZON_S: f64 = 60.0 * 24.0 * 3600.0;
const MAX_WINDOWS_PER_CLASS: usize = 256;

/// Knobs of the seeded drift processes. `none()` disables everything:
/// truth then IS the profiled table, bit for bit.
#[derive(Debug, Clone)]
pub struct DriftConfig {
    pub seed: u64,
    /// Asymptotic magnitude of the per-job multiplicative ramps (0.1:
    /// each job drifts toward ±10% of its profiled step time; the sign
    /// and time constant are drawn per job from `seed`).
    pub ramp_magnitude: f64,
    /// Base ramp time constant, seconds (per-job jitter in [0.5x, 2x]).
    pub ramp_tau_s: f64,
    /// Poisson rate of per-class interference windows, events/hour.
    pub interference_per_hour: f64,
    /// Step-change multiplier while a window is active (> 1 slows the
    /// class down).
    pub interference_mult: f64,
    /// Interference window length, seconds.
    pub interference_s: f64,
    /// Sigma of the static lognormal per-(job, class) mis-calibration.
    pub cell_noise: f64,
    /// Per-tenant drift profiles (ROADMAP drift follow-up): a job of
    /// tenant class `k` (see [`TruthModel::with_tenants`]) ramps at
    /// `ramp_magnitude * (1 + tenant_spread * k)`, capped at 0.9 —
    /// noisy tenants drift harder. 0 (the default) keeps every tenant
    /// at the shared magnitude, bit for bit.
    pub tenant_spread: f64,
}

impl DriftConfig {
    /// Zero drift: the truth model returns profiled step times unchanged
    /// (bit-identical to the pre-split simulator).
    pub fn none() -> Self {
        DriftConfig {
            seed: 0,
            ramp_magnitude: 0.0,
            ramp_tau_s: 7200.0,
            interference_per_hour: 0.0,
            interference_mult: 1.0,
            interference_s: 0.0,
            cell_noise: 0.0,
            tenant_spread: 0.0,
        }
    }

    /// The single-knob shape `--drift` and `bench_drift` use: ramps at
    /// full `magnitude`, mis-calibration at half of it, and mild
    /// class-wide interference windows.
    pub fn uniform(seed: u64, magnitude: f64) -> Self {
        DriftConfig {
            seed,
            ramp_magnitude: magnitude,
            ramp_tau_s: 7200.0,
            interference_per_hour: if magnitude > 0.0 { 0.05 } else { 0.0 },
            interference_mult: 1.0 + 0.5 * magnitude,
            interference_s: 1800.0,
            cell_noise: 0.5 * magnitude,
            tenant_spread: 0.0,
        }
    }

    /// Whether any drift process is switched on.
    pub fn is_active(&self) -> bool {
        self.ramp_magnitude > 0.0
            || self.cell_noise > 0.0
            || (self.interference_per_hour > 0.0
                && self.interference_mult != 1.0)
    }
}

/// What the hardware does: profiled step times perturbed by the seeded
/// drift processes. Read ONLY by the simulation engine.
#[derive(Debug, Clone)]
pub struct TruthModel {
    profiles: ProfileTable,
    cfg: DriftConfig,
    /// Per-class interference windows as (start_s, end_s), ascending.
    windows: Vec<Vec<(f64, f64)>>,
    /// Tenant class per job id (`DriftConfig::tenant_spread`); empty =
    /// every job class 0 (the shared ramp magnitude).
    tenant_class: Vec<f64>,
    active: bool,
}

impl TruthModel {
    pub fn new(profiles: ProfileTable, cfg: DriftConfig) -> Self {
        TruthModel::with_tenants(profiles, cfg, Vec::new())
    }

    /// As [`TruthModel::new`] with per-job tenant classes (indexed by
    /// job id; 0.0, 1.0, ... — traces map priority `k + 1` to class
    /// `k`) driving the `tenant_spread` ramp scaling. An empty vector,
    /// or `tenant_spread == 0`, is bit-identical to [`TruthModel::new`].
    pub fn with_tenants(profiles: ProfileTable, cfg: DriftConfig,
                        tenant_class: Vec<f64>) -> Self {
        let active = cfg.is_active();
        let n_classes = profiles.n_classes();
        let windows = (0..n_classes)
            .map(|ci| {
                let mut out = Vec::new();
                if active && cfg.interference_per_hour > 0.0 {
                    let mut rng =
                        Rng::new(cfg.seed ^ 0xC1A5_5E5D).fork(ci as u64);
                    let rate = cfg.interference_per_hour / 3600.0;
                    let mut t = 0.0f64;
                    while out.len() < MAX_WINDOWS_PER_CLASS {
                        t += rng.exp(rate.max(1e-12));
                        if t > INTERFERENCE_HORIZON_S {
                            break;
                        }
                        out.push((t, t + cfg.interference_s));
                    }
                }
                out
            })
            .collect();
        TruthModel { profiles, cfg, windows, tenant_class, active }
    }

    /// The underlying profiled table (the estimate layer's prior).
    pub fn profiles(&self) -> &ProfileTable {
        &self.profiles
    }

    pub fn config(&self) -> &DriftConfig {
        &self.cfg
    }

    /// Effective ramp magnitude for `job`: the configured magnitude,
    /// scaled by the job's tenant class (`DriftConfig::tenant_spread`)
    /// and capped at 0.9. The zero-spread path returns the configured
    /// value UNTOUCHED — no multiply, no cap — so that arm stays
    /// bit-identical to the shared-magnitude model.
    fn ramp_magnitude(&self, job: usize) -> f64 {
        if self.cfg.tenant_spread == 0.0 {
            return self.cfg.ramp_magnitude;
        }
        let class = self.tenant_class.get(job).copied().unwrap_or(0.0);
        let scale = (1.0 + self.cfg.tenant_spread * class).max(0.0);
        (self.cfg.ramp_magnitude * scale).min(0.9)
    }

    /// Per-job slow multiplicative ramp at virtual time `now`.
    fn ramp(&self, job: usize, now: f64) -> f64 {
        if self.cfg.ramp_magnitude <= 0.0 {
            return 1.0;
        }
        let mut rng = Rng::new(self.cfg.seed ^ 0x4A0B_D21F).fork(job as u64);
        let dir = if rng.bool(0.5) { 1.0 } else { -1.0 };
        let tau = self.cfg.ramp_tau_s * (0.5 + 1.5 * rng.f64());
        1.0 + dir
            * self.ramp_magnitude(job)
            * (1.0 - (-now.max(0.0) / tau.max(1.0)).exp())
    }

    /// Static per-(job, class) lognormal mis-calibration of the probe.
    fn noise(&self, job: usize, class: usize) -> f64 {
        if self.cfg.cell_noise <= 0.0 {
            return 1.0;
        }
        let mut rng = Rng::new(self.cfg.seed ^ 0x70D0_5EED)
            .fork(((job as u64) << 8) | class as u64);
        (self.cfg.cell_noise * rng.normal()).exp().clamp(0.5, 2.0)
    }

    /// Step-change multiplier if `now` falls inside an interference
    /// window of `class`.
    fn interference(&self, class: usize, now: f64) -> f64 {
        match self.windows.get(class) {
            Some(ws) if ws.iter().any(|&(a, b)| now >= a && now < b) => {
                self.cfg.interference_mult
            }
            _ => 1.0,
        }
    }

    /// Combined truth multiplier for `(job, class)` at `now`.
    pub fn multiplier(&self, job: usize, class: usize, now: f64) -> f64 {
        if !self.active {
            return 1.0;
        }
        (self.ramp(job, now)
            * self.noise(job, class)
            * self.interference(class, now))
        .clamp(0.25, 4.0)
    }

    /// TRUE step time at `now`. With drift inactive this returns the
    /// profiled value unchanged (no floating-point round trip).
    pub fn step_time(&self, job: usize, tech: usize, gpus: u32,
                     class: usize, now: f64) -> Option<f64> {
        let base = self.profiles.step_time(job, tech, gpus, class)?;
        if !self.active {
            return Some(base);
        }
        Some(base * self.multiplier(job, class, now))
    }

    /// Materialize the whole truth as a `ProfileTable` frozen at `now` —
    /// the oracle-informed planner's table in `bench_drift`. With drift
    /// inactive this is the profiled table itself.
    pub fn table_at(&self, now: f64) -> ProfileTable {
        if !self.active {
            return self.profiles.clone();
        }
        self.profiles.with_scaled_step_times(|job, _tech, _gpus, class, t| {
            t * self.multiplier(job, class, now)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::trials::profile_analytic;
    use crate::workload::toy_workload;

    fn table() -> ProfileTable {
        let jobs = toy_workload(4);
        profile_analytic(&jobs, &default_library(), &ClusterSpec::p4d(1))
    }

    #[test]
    fn inactive_truth_is_the_profiled_table_bit_for_bit() {
        let p = table();
        let t = TruthModel::new(p.clone(), DriftConfig::none());
        for (&(j, ti, g, c), e) in p.cells() {
            let tt = t.step_time(j, ti, g, c, 12345.0).unwrap();
            assert!(tt.to_bits() == e.step_time_s.to_bits());
        }
        assert_eq!(t.table_at(999.0).len(), p.len());
    }

    #[test]
    fn ramps_are_slow_and_bounded() {
        let p = table();
        let cfg = DriftConfig {
            ramp_magnitude: 0.3,
            ..DriftConfig::uniform(7, 0.3)
        };
        let t = TruthModel::new(p.clone(), cfg);
        for j in 0..4 {
            let m0 = t.ramp(j, 0.0);
            let m_inf = t.ramp(j, 1e9);
            assert!((m0 - 1.0).abs() < 1e-12, "ramp starts at 1.0");
            assert!((m_inf - 1.0).abs() <= 0.3 + 1e-9);
            assert!((m_inf - 1.0).abs() >= 0.29, "ramp reaches asymptote");
        }
        // at least one job drifts up and one down over the seed space
        let dirs: Vec<bool> =
            (0..16).map(|j| t.ramp(j, 1e9) > 1.0).collect();
        assert!(dirs.iter().any(|&d| d) && dirs.iter().any(|&d| !d));
    }

    #[test]
    fn tenant_spread_scales_ramp_asymptotes_per_class() {
        let p = table();
        // ramps only: the asymptotic |multiplier - 1| IS the magnitude
        let mut cfg = DriftConfig::none();
        cfg.ramp_magnitude = 0.2;
        cfg.tenant_spread = 1.0;
        // job 0 -> tenant class 0 (magnitude 0.2),
        // job 1 -> tenant class 1 (magnitude 0.4)
        let t = TruthModel::with_tenants(p.clone(), cfg.clone(),
                                         vec![0.0, 1.0]);
        let mag = |job| (t.multiplier(job, 0, 1e12) - 1.0).abs();
        assert!((mag(0) - 0.2).abs() < 1e-9, "class 0: {}", mag(0));
        assert!((mag(1) - 0.4).abs() < 1e-9, "class 1: {}", mag(1));
        // zero spread: tenants are ignored, bit for bit
        cfg.tenant_spread = 0.0;
        let plain = TruthModel::new(p.clone(), cfg.clone());
        let spread0 = TruthModel::with_tenants(p, cfg, vec![0.0, 3.0]);
        for job in 0..2 {
            assert_eq!(plain.multiplier(job, 0, 5e3).to_bits(),
                       spread0.multiplier(job, 0, 5e3).to_bits());
        }
    }

    #[test]
    fn queries_are_pure_and_order_independent() {
        let p = table();
        let t = TruthModel::new(p, DriftConfig::uniform(42, 0.2));
        let a = t.step_time(1, 0, 1, 0, 5000.0);
        let _ = t.step_time(3, 1, 4, 0, 9000.0); // interleaved query
        let b = t.step_time(1, 0, 1, 0, 5000.0);
        assert_eq!(a.map(f64::to_bits), b.map(f64::to_bits));
    }

    #[test]
    fn interference_windows_slow_the_class() {
        let p = table();
        let cfg = DriftConfig {
            seed: 3,
            ramp_magnitude: 0.0,
            cell_noise: 0.0,
            interference_per_hour: 10.0,
            interference_mult: 1.5,
            interference_s: 600.0,
            ramp_tau_s: 7200.0,
            tenant_spread: 0.0,
        };
        let t = TruthModel::new(p, cfg);
        let (start, _) = t.windows[0][0];
        assert!((t.multiplier(0, 0, start + 1.0) - 1.5).abs() < 1e-12);
        assert!((t.multiplier(0, 0, start - 1.0) - 1.0).abs() < 1e-12
                || t.interference(0, start - 1.0) == 1.5); // nested window
    }
}
