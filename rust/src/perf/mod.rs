//! The performance-model layer (DESIGN.md §4.4): *what the planner
//! believes* and *what the hardware does* are distinct models, connected
//! only by observation.
//!
//!  * [`drift::TruthModel`] — the oracle ground truth: the profiled
//!    table plus deterministic, seeded drift processes. ONLY
//!    `sim::engine` may read it; everything that plans (Saturn, the
//!    baselines, the CLI) sees the estimate.
//!  * [`estimate::EstimateModel`] — the planner's belief: starts at the
//!    profiled table and corrects from [`estimate::Observation`] records
//!    the engine emits at rung boundaries, completions, and
//!    introspection checkpoints.
//!  * [`PerfModel`] — the pair, as the simulation engine consumes it.
//!    `exact()` (no drift) reproduces the pre-split simulator bit for
//!    bit; `oracle()` hands the planner the frozen truth at each replan
//!    (the upper bound `bench_drift` measures degradation against).

pub mod drift;
pub mod estimate;

pub use drift::{DriftConfig, TruthModel};
pub use estimate::{EstimateModel, Observation};

use crate::trials::ProfileTable;

/// How the planner-facing table is produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimateSource {
    /// Estimates frozen at the profiled table (correction off).
    Profiled,
    /// Online correction from observations (the default).
    Corrected,
    /// The truth itself, frozen at the current virtual time — an
    /// oracle-informed planner (bench upper bound; unreachable live).
    Oracle,
}

/// Truth + estimate, wired the way `sim::engine` drives them.
#[derive(Debug, Clone)]
pub struct PerfModel {
    truth: TruthModel,
    pub estimate: EstimateModel,
    source: EstimateSource,
    /// Oracle mode: the truth table materialized at `oracle_now`.
    oracle_table: Option<ProfileTable>,
    oracle_now: f64,
}

impl PerfModel {
    /// No drift: truth == estimate == the profiled table. The batch
    /// `simulate`/`simulate_online` wrappers route through this, and it
    /// is bit-identical to the pre-split engine. Correction is off —
    /// with zero drift every factor is exactly 1.0 anyway, and a frozen
    /// model skips the per-event table re-materialization entirely.
    pub fn exact(profiles: &ProfileTable) -> PerfModel {
        PerfModel::with_drift(profiles, DriftConfig::none(), false)
    }

    /// Drifting truth; the planner sees the correcting estimate
    /// (`correction = true`) or the frozen profiled table.
    pub fn with_drift(profiles: &ProfileTable, cfg: DriftConfig,
                      correction: bool) -> PerfModel {
        PerfModel::with_drift_tenants(profiles, cfg, correction, Vec::new())
    }

    /// As [`PerfModel::with_drift`] with per-job tenant classes for the
    /// `DriftConfig::tenant_spread` ramp scaling (see
    /// [`TruthModel::with_tenants`]). An empty vector — or zero spread
    /// — is bit-identical to [`PerfModel::with_drift`].
    pub fn with_drift_tenants(profiles: &ProfileTable, cfg: DriftConfig,
                              correction: bool, tenant_class: Vec<f64>)
        -> PerfModel {
        let source = if correction {
            EstimateSource::Corrected
        } else {
            EstimateSource::Profiled
        };
        PerfModel {
            truth: TruthModel::with_tenants(profiles.clone(), cfg,
                                            tenant_class),
            estimate: EstimateModel::new(profiles.clone(), correction),
            source,
            oracle_table: None,
            oracle_now: f64::NEG_INFINITY,
        }
    }

    /// Drifting truth with an ORACLE planner: every replan reads the
    /// truth frozen at the current virtual time.
    pub fn oracle(profiles: &ProfileTable, cfg: DriftConfig) -> PerfModel {
        PerfModel::oracle_tenants(profiles, cfg, Vec::new())
    }

    /// As [`PerfModel::oracle`] with per-job tenant classes (the
    /// `--drift-tenant-spread` oracle arm drifts the same truth the
    /// live arms face).
    pub fn oracle_tenants(profiles: &ProfileTable, cfg: DriftConfig,
                          tenant_class: Vec<f64>) -> PerfModel {
        let mut m =
            PerfModel::with_drift_tenants(profiles, cfg, false,
                                          tenant_class);
        m.source = EstimateSource::Oracle;
        m.oracle_table = Some(m.truth.table_at(0.0));
        m.oracle_now = 0.0;
        m
    }

    pub fn source(&self) -> EstimateSource {
        self.source
    }

    /// TRUE step time at `now` — the engine's charge. Nothing outside
    /// `sim::engine` should call this: planners read [`PerfModel::table`].
    pub fn true_step_time(&self, job: usize, tech: usize, gpus: u32,
                          class: usize, now: f64) -> Option<f64> {
        self.truth.step_time(job, tech, gpus, class, now)
    }

    /// Fold an observed stint into the estimate layer. A no-op in
    /// oracle mode: the oracle planner reads the truth directly, so
    /// surprise-vs-frozen-profiles bookkeeping would only mislead
    /// (its reported estimate error is genuinely ~0).
    pub fn observe(&mut self, obs: &Observation) {
        if self.source == EstimateSource::Oracle {
            return;
        }
        self.estimate.observe(obs);
    }

    /// Drop a departed job from the drift alarm (see
    /// [`EstimateModel::retire_job`]).
    pub fn retire_job(&mut self, job: usize) {
        self.estimate.retire_job(job);
    }

    /// Bring the planner-facing table up to date for virtual time `now`.
    /// The engine calls this before every policy replan; afterwards
    /// [`PerfModel::table`] borrows immutably.
    pub fn refresh(&mut self, now: f64) {
        match self.source {
            EstimateSource::Oracle => {
                if self.oracle_table.is_none() || self.oracle_now != now {
                    self.oracle_table = Some(self.truth.table_at(now));
                    self.oracle_now = now;
                }
            }
            _ => self.estimate.refresh(),
        }
    }

    /// The planner-facing estimate table (see [`PerfModel::refresh`]).
    pub fn table(&self) -> &ProfileTable {
        match self.source {
            EstimateSource::Oracle => self
                .oracle_table
                .as_ref()
                .expect("refresh() before table() in oracle mode"),
            _ => self.estimate.table(),
        }
    }

    pub fn obs_seen(&self) -> usize {
        self.estimate.obs_seen()
    }

    pub fn drift_alarm(&self) -> f64 {
        self.estimate.drift_alarm()
    }

    pub fn estimate_mae(&self) -> f64 {
        self.estimate.estimate_mae()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::trials::profile_analytic;
    use crate::workload::toy_workload;

    fn profiles() -> ProfileTable {
        let jobs = toy_workload(4);
        profile_analytic(&jobs, &default_library(), &ClusterSpec::p4d(1))
    }

    #[test]
    fn exact_model_truth_equals_estimate_equals_profiles() {
        let p = profiles();
        let mut m = PerfModel::exact(&p);
        m.refresh(0.0);
        for (&(j, ti, g, c), e) in p.cells() {
            let t = m.true_step_time(j, ti, g, c, 7777.0).unwrap();
            let s = m.table().step_time(j, ti, g, c).unwrap();
            assert_eq!(t.to_bits(), e.step_time_s.to_bits());
            assert_eq!(s.to_bits(), e.step_time_s.to_bits());
        }
    }

    #[test]
    fn drifting_truth_diverges_from_frozen_estimate() {
        let p = profiles();
        let mut m =
            PerfModel::with_drift(&p, DriftConfig::uniform(9, 0.3), false);
        m.refresh(36_000.0);
        let mut diverged = 0;
        for (&(j, ti, g, c), _) in p.cells() {
            let t = m.true_step_time(j, ti, g, c, 36_000.0).unwrap();
            let s = m.table().step_time(j, ti, g, c).unwrap();
            if (t / s - 1.0).abs() > 0.02 {
                diverged += 1;
            }
        }
        assert!(diverged > 0, "30% drift moved no cell past 2%");
    }

    #[test]
    fn oracle_table_tracks_the_truth_at_refresh_time() {
        let p = profiles();
        let cfg = DriftConfig::uniform(11, 0.2);
        let mut m = PerfModel::oracle(&p, cfg);
        for &now in &[0.0, 10_000.0, 50_000.0] {
            m.refresh(now);
            for (&(j, ti, g, c), _) in p.cells() {
                let t = m.true_step_time(j, ti, g, c, now).unwrap();
                let s = m.table().step_time(j, ti, g, c).unwrap();
                assert_eq!(t.to_bits(), s.to_bits(),
                           "oracle diverged at t={now}");
            }
        }
    }
}
