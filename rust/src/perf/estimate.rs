//! The ESTIMATE side of the performance-model split: what the planner
//! believes. Starts from the profiled [`ProfileTable`] and updates from
//! observed step completions the simulation engine emits at rung
//! boundaries, completions, and introspection checkpoints.
//!
//! Correction model — hierarchical log-ratio shrinkage:
//! every observation of cell `(job, tech, gpus, class)` contributes
//! `ln(observed / profiled)` to three blenders — the cell itself, the
//! job, and the GPU class — each an exponentially-forgetting
//! inverse-variance mean (weight = steps observed, so long stints count
//! for more). A queried cell's correction factor is the weight-blended
//! mean of the three levels against a pseudo-weight prior anchored at
//! the profiled table, so unvisited cells back off to the per-job and
//! per-class priors and a fresh model returns the profiled table
//! exactly.

use std::collections::HashMap;

use crate::trials::ProfileTable;

/// One observed running stint, emitted by `sim::engine` wherever
/// progress is banked (completion, rung kill, preemption checkpoint).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub job_id: usize,
    pub tech: usize,
    pub gpus: u32,
    pub class: usize,
    /// Steps executed during the stint (fractional for partial stints).
    pub steps: f64,
    /// Realized seconds per step over the stint.
    pub step_time_s: f64,
    /// Virtual time at which the stint ended.
    pub at_s: f64,
}

/// Exponentially-forgetting weighted mean of log ratios.
#[derive(Debug, Clone, Copy, Default)]
struct Blend {
    w: f64,
    mean_log: f64,
}

impl Blend {
    fn update(&mut self, log_ratio: f64, weight: f64, decay: f64) {
        self.w = self.w * decay + weight;
        self.mean_log += weight / self.w * (log_ratio - self.mean_log);
    }
}

/// Correction factors are clamped to this band (a 4x surprise is a
/// pathology to investigate, not something to extrapolate from).
const FACTOR_MIN: f64 = 0.25;
const FACTOR_MAX: f64 = 4.0;

/// Per-observation weight cap: one very long stint must not freeze the
/// estimate forever.
const MAX_OBS_WEIGHT: f64 = 64.0;
const MIN_OBS_WEIGHT: f64 = 0.25;

/// Backoff levels shrink toward the cell evidence: a job-level ratio is
/// weaker evidence for an unvisited cell than a direct observation, and
/// a class-level ratio weaker still.
const JOB_LEVEL_WEIGHT: f64 = 0.5;
const CLASS_LEVEL_WEIGHT: f64 = 0.25;

/// The planner-facing performance model.
#[derive(Debug, Clone)]
pub struct EstimateModel {
    profiled: ProfileTable,
    /// When false the model never corrects: estimates stay frozen at the
    /// profiled table (the ablation arm of `bench_drift`); observation
    /// accounting — drift alarm, error metrics — still runs.
    pub correction: bool,
    /// Per-observation forgetting factor (1.0 = plain inverse-variance
    /// averaging; lower forgets faster under non-stationary drift).
    pub decay: f64,
    /// Pseudo-weight anchoring every factor at the profiled table.
    pub prior_weight: f64,
    cell: HashMap<(usize, usize, u32, usize), Blend>,
    job: HashMap<usize, Blend>,
    class: HashMap<usize, Blend>,
    obs_seen: usize,
    /// Latest pre-update |ln(observed/estimate-in-use)| per job — the
    /// drift alarm the policies' drift-triggered re-solves read.
    mismatch: HashMap<usize, f64>,
    err_sum: f64,
    /// Materialized corrected table served to planners.
    table: ProfileTable,
    dirty: bool,
}

impl EstimateModel {
    pub fn new(profiled: ProfileTable, correction: bool) -> Self {
        let table = profiled.clone();
        EstimateModel {
            profiled,
            correction,
            decay: 0.85,
            prior_weight: 2.0,
            cell: HashMap::new(),
            job: HashMap::new(),
            class: HashMap::new(),
            obs_seen: 0,
            mismatch: HashMap::new(),
            err_sum: 0.0,
            table,
            dirty: false,
        }
    }

    /// Current correction factor for a cell (1.0 when nothing relevant
    /// has been observed yet).
    pub fn factor(&self, job: usize, tech: usize, gpus: u32, class: usize)
        -> f64 {
        let mut num = 0.0;
        let mut den = self.prior_weight;
        if let Some(b) = self.cell.get(&(job, tech, gpus, class)) {
            num += b.w * b.mean_log;
            den += b.w;
        }
        if let Some(b) = self.job.get(&job) {
            num += JOB_LEVEL_WEIGHT * b.w * b.mean_log;
            den += JOB_LEVEL_WEIGHT * b.w;
        }
        if let Some(b) = self.class.get(&class) {
            num += CLASS_LEVEL_WEIGHT * b.w * b.mean_log;
            den += CLASS_LEVEL_WEIGHT * b.w;
        }
        (num / den).exp().clamp(FACTOR_MIN, FACTOR_MAX)
    }

    /// The planner's current belief about a cell's step time.
    pub fn step_time(&self, job: usize, tech: usize, gpus: u32,
                     class: usize) -> Option<f64> {
        let base = self.profiled.step_time(job, tech, gpus, class)?;
        if !self.correction {
            return Some(base);
        }
        Some(base * self.factor(job, tech, gpus, class))
    }

    /// Fold one observed stint into the model. Always updates the drift
    /// alarm and error accounting; updates the correction blenders only
    /// when `correction` is on.
    pub fn observe(&mut self, obs: &Observation) {
        let Some(base) = self
            .profiled
            .step_time(obs.job_id, obs.tech, obs.gpus, obs.class)
        else {
            return; // stint on an unprofiled cell: nothing to anchor to
        };
        if obs.step_time_s <= 0.0
            || !obs.step_time_s.is_finite()
            || obs.steps <= 0.0
        {
            return;
        }
        // the estimate IN USE is the materialized table (refreshed just
        // before the planner's last replan), not the live blenders —
        // several observations banked in one event batch must all be
        // judged against what the planner actually planned with
        let est_in_use = self
            .table
            .step_time(obs.job_id, obs.tech, obs.gpus, obs.class)
            .unwrap_or(base);
        let surprise = (obs.step_time_s / est_in_use).ln().abs();
        self.err_sum += surprise;
        self.obs_seen += 1;
        // the alarm is that PRE-update mismatch: post-update it would
        // already be absorbed and the drift trigger could never fire in
        // exactly the mode that corrects
        self.mismatch.insert(obs.job_id, surprise);

        if self.correction {
            let log_ratio = (obs.step_time_s / base).ln();
            let weight = obs.steps.clamp(MIN_OBS_WEIGHT, MAX_OBS_WEIGHT);
            self.cell
                .entry((obs.job_id, obs.tech, obs.gpus, obs.class))
                .or_default()
                .update(log_ratio, weight, self.decay);
            self.job
                .entry(obs.job_id)
                .or_default()
                .update(log_ratio, weight, self.decay);
            self.class
                .entry(obs.class)
                .or_default()
                .update(log_ratio, weight, self.decay);
            self.dirty = true;
        }
    }

    /// Re-materialize the corrected table if observations arrived since
    /// the last call. Cheap: one multiply per profiled cell.
    pub fn refresh(&mut self) {
        if !self.dirty {
            return;
        }
        self.table = self
            .profiled
            .with_scaled_step_times(|job, tech, gpus, class, t| {
                t * self.factor(job, tech, gpus, class)
            });
        self.dirty = false;
    }

    /// The planner-facing table. Call [`EstimateModel::refresh`] after a
    /// batch of observations; a fresh or correction-off model serves the
    /// profiled table unchanged.
    pub fn table(&self) -> &ProfileTable {
        &self.table
    }

    /// The untouched profiled prior.
    pub fn profiled(&self) -> &ProfileTable {
        &self.profiled
    }

    /// Drop a departed job from the drift alarm: a completed or killed
    /// job will never be observed again, so its last surprise must not
    /// pin the alarm above threshold forever (that would fire a
    /// re-solve on every later observation from anyone).
    pub fn retire_job(&mut self, job: usize) {
        self.mismatch.remove(&job);
    }

    /// Observations folded in so far (monotone; policies snapshot this to
    /// detect "new evidence since my last solve").
    pub fn obs_seen(&self) -> usize {
        self.obs_seen
    }

    /// Worst |ln(observed/estimate-in-use)| across jobs' latest
    /// observations (pre-update). Zero while nothing has been observed;
    /// decays as correction learns (later observations stop surprising);
    /// stays at the true drift level when correction is off.
    pub fn drift_alarm(&self) -> f64 {
        self.mismatch.values().fold(0.0f64, |a, &b| a.max(b))
    }

    /// Mean |ln(observed/estimated-before-update)| across every
    /// observation — the run's estimate error.
    pub fn estimate_mae(&self) -> f64 {
        if self.obs_seen == 0 {
            0.0
        } else {
            self.err_sum / self.obs_seen as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::parallelism::default_library;
    use crate::trials::profile_analytic;
    use crate::workload::toy_workload;

    fn table() -> ProfileTable {
        let jobs = toy_workload(4);
        profile_analytic(&jobs, &default_library(), &ClusterSpec::p4d(1))
    }

    fn obs_for(t: &ProfileTable, job: usize, mult: f64) -> Observation {
        let (tech, step) = t.best_at(job, 1, 0).unwrap();
        Observation {
            job_id: job,
            tech,
            gpus: 1,
            class: 0,
            steps: 10.0,
            step_time_s: step * mult,
            at_s: 100.0,
        }
    }

    #[test]
    fn fresh_model_is_the_profiled_table_bit_for_bit() {
        let p = table();
        let mut m = EstimateModel::new(p.clone(), true);
        m.refresh();
        for (&(j, ti, g, c), e) in p.cells() {
            let s = m.table().step_time(j, ti, g, c).unwrap();
            assert_eq!(s.to_bits(), e.step_time_s.to_bits());
            let q = m.step_time(j, ti, g, c).unwrap();
            assert_eq!(q.to_bits(), e.step_time_s.to_bits());
        }
    }

    #[test]
    fn exact_observations_leave_estimates_bit_identical() {
        // zero drift: observed == estimated, so every log ratio is
        // exactly 0.0 and the materialized table never moves a bit
        let p = table();
        let mut m = EstimateModel::new(p.clone(), true);
        for _ in 0..5 {
            let o = obs_for(&p, 1, 1.0);
            m.observe(&o);
        }
        m.refresh();
        assert_eq!(m.obs_seen(), 5);
        assert_eq!(m.drift_alarm(), 0.0);
        assert_eq!(m.estimate_mae(), 0.0);
        for (&(j, ti, g, c), e) in p.cells() {
            let s = m.table().step_time(j, ti, g, c).unwrap();
            assert_eq!(s.to_bits(), e.step_time_s.to_bits());
        }
    }

    #[test]
    fn repeated_observation_converges_monotonically() {
        let p = table();
        let mut m = EstimateModel::new(p.clone(), true);
        let o = obs_for(&p, 0, 1.3);
        let mut last = f64::INFINITY;
        for _ in 0..12 {
            m.observe(&o);
            let est = m.step_time(0, o.tech, 1, 0).unwrap();
            let err = (o.step_time_s / est).ln().abs();
            assert!(err <= last + 1e-12,
                    "estimate error increased: {err} > {last}");
            last = err;
        }
        assert!(last < 0.1, "did not converge: residual {last}");
    }

    #[test]
    fn unvisited_cells_back_off_to_job_and_class_priors() {
        let p = table();
        let mut m = EstimateModel::new(p.clone(), true);
        m.observe(&obs_for(&p, 1, 1.4));
        // a DIFFERENT cell of the same job drifts in the same direction
        let f = m.factor(1, 0, 4, 0);
        assert!(f > 1.05, "job prior did not propagate: {f}");
        // another job on the same class moves less but not zero
        let g = m.factor(0, 0, 1, 0);
        assert!(g > 1.0 && g < f, "class prior ordering: {g} vs {f}");
    }

    #[test]
    fn correction_off_freezes_estimates_but_keeps_the_alarm() {
        let p = table();
        let mut m = EstimateModel::new(p.clone(), false);
        m.observe(&obs_for(&p, 1, 1.5));
        m.refresh();
        assert_eq!(m.obs_seen(), 1);
        assert!((m.drift_alarm() - 1.5f64.ln()).abs() < 1e-12);
        let (tech, step) = p.best_at(1, 1, 0).unwrap();
        let s = m.table().step_time(1, tech, 1, 0).unwrap();
        assert_eq!(s.to_bits(), step.to_bits());
    }

    #[test]
    fn factors_are_clamped() {
        let p = table();
        let mut m = EstimateModel::new(p.clone(), true);
        let mut o = obs_for(&p, 0, 100.0);
        o.steps = 1e9; // weight cap keeps one stint from dominating
        for _ in 0..50 {
            m.observe(&o);
        }
        assert!(m.factor(0, o.tech, 1, 0) <= FACTOR_MAX + 1e-12);
    }
}
