//! Seeded fault injection (DESIGN.md §4.7): per-node exponential-MTBF
//! failures with transient-vs-permanent repair timers, flapping hosts
//! quarantined by an exponential-backoff blacklist, and per-job crash
//! hazards.
//!
//! Every query is a pure function of `(seed, entity, now)` — exactly the
//! [`crate::perf::drift`] `TruthModel` discipline — so faulted replays
//! are bit-identical and a [`FaultConfig::none`] run takes zero extra
//! float operations on the engine's hot path (`tests/prop_faults.rs`
//! holds the engine to both).
//!
//! The model pre-draws each node's downtime windows at construction
//! (non-overlapping, ascending, quarantine extensions already folded in),
//! so `node_down(class, node, now)` is order-independent: the engine may
//! ask at any instant, in any order, across any replay, and always sees
//! the same fleet. Crash instants are re-derived per query from the job's
//! own stream; crashes that land while a job is not running are harmless
//! (the engine only consults running jobs).

use crate::cluster::ClusterSpec;
use crate::util::rng::Rng;

/// Fault processes are drawn over this horizon of virtual time (60
/// days) — far beyond any simulated trace, mirroring the drift model's
/// interference horizon.
const FAULT_HORIZON_S: f64 = 60.0 * 24.0 * 3600.0;
/// Cap on pre-drawn outage windows per node (with the horizon above,
/// only pathological MTBFs ever hit it).
const MAX_OUTAGES_PER_NODE: usize = 64;
/// Cap on crash instants scanned per job stream.
const MAX_CRASHES_PER_JOB: usize = 64;
/// Floor on outage length: sub-minute blips would thrash the event loop
/// without exercising any interesting recovery behavior.
const MIN_OUTAGE_S: f64 = 60.0;
/// Cap on the blacklist backoff exponent (2^8 * base).
const MAX_BACKOFF_EXP: u32 = 8;

/// Knobs of the seeded fault layer. `none()` (all zeros) disables it;
/// [`FaultConfig::is_active`] gates every engine hook so the disabled
/// path stays bit-identical to the fault-free engine.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    pub seed: u64,
    /// Mean time between failures PER NODE, hours. 0 disables node
    /// failures.
    pub mtbf_hours: f64,
    /// Fraction of node failures that are transient (mean outage
    /// `repair_s`); the rest wait for a replacement (`replace_s`).
    pub transient_fraction: f64,
    /// Mean outage of a transient failure, seconds.
    pub repair_s: f64,
    /// Mean outage of a permanent failure (node replacement), seconds.
    pub replace_s: f64,
    /// Per-job crash hazard while running, events per hour. 0 disables.
    pub crash_per_hour: f64,
    /// Fraction of nodes that flap: their MTBF is divided by
    /// `flaky_accel`.
    pub flaky_fraction: f64,
    /// MTBF acceleration of flaky nodes (>= 1).
    pub flaky_accel: f64,
    /// Blacklist quarantine: a node failing again within
    /// `blacklist_window_s` of its last repair has its outage extended
    /// by `blacklist_base_s * 2^k` (k = consecutive rapid re-failures,
    /// capped) — the scheduler sees a flapping host held out of service
    /// for exponentially longer each time. 0 disables.
    pub blacklist_base_s: f64,
    pub blacklist_window_s: f64,
}

impl FaultConfig {
    /// Faults off. The engine's zero-fault path is bit-identical to the
    /// pre-fault engine under this config.
    pub fn none() -> Self {
        FaultConfig {
            seed: 0,
            mtbf_hours: 0.0,
            transient_fraction: 0.0,
            repair_s: 0.0,
            replace_s: 0.0,
            crash_per_hour: 0.0,
            flaky_fraction: 0.0,
            flaky_accel: 1.0,
            blacklist_base_s: 0.0,
            blacklist_window_s: 0.0,
        }
    }

    /// The standard sweep configuration (`bench_faults`, `saturn online
    /// --faults`): mostly-transient node failures at the given per-node
    /// MTBF, a quarter of the fleet flapping 6x as often, a small crash
    /// hazard, and a 30-minute base quarantine.
    pub fn uniform(seed: u64, mtbf_hours: f64) -> Self {
        FaultConfig {
            seed,
            mtbf_hours: mtbf_hours.max(0.0),
            transient_fraction: 0.8,
            repair_s: 900.0,
            replace_s: 4.0 * 3600.0,
            crash_per_hour: if mtbf_hours > 0.0 { 0.01 } else { 0.0 },
            flaky_fraction: 0.25,
            flaky_accel: 6.0,
            blacklist_base_s: 1800.0,
            blacklist_window_s: 3600.0,
        }
    }

    /// Whether any fault process is enabled.
    pub fn is_active(&self) -> bool {
        self.mtbf_hours > 0.0 || self.crash_per_hour > 0.0
    }
}

/// The pre-drawn fault universe of one run: per-node downtime windows
/// plus per-job crash streams, all pure in `(seed, entity, now)`.
#[derive(Debug, Clone)]
pub struct FaultModel {
    cfg: FaultConfig,
    /// Per class, per node: `(fail_s, back_s)` downtime windows,
    /// ascending and non-overlapping; blacklist quarantine extensions
    /// are already folded into `back_s`.
    outages: Vec<Vec<Vec<(f64, f64)>>>,
    /// Quarantine extensions applied during window generation (flapping
    /// nodes held out of service beyond their repair time).
    quarantines: usize,
    active: bool,
}

impl FaultModel {
    pub fn new(cfg: FaultConfig, cluster: &ClusterSpec) -> Self {
        let active = cfg.is_active();
        let mut quarantines = 0usize;
        let outages: Vec<Vec<Vec<(f64, f64)>>> = (0..cluster.n_classes())
            .map(|ci| {
                let nodes = cluster.class(ci).nodes as usize;
                (0..nodes)
                    .map(|ni| {
                        node_windows(&cfg, ci, ni, &mut quarantines)
                    })
                    .collect()
            })
            .collect();
        FaultModel { cfg, outages, quarantines, active }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Blacklist quarantine extensions drawn across the whole fleet.
    pub fn quarantines(&self) -> usize {
        self.quarantines
    }

    /// The pre-drawn downtime windows of one node (diagnostics/tests).
    pub fn outages(&self, class: usize, node: usize) -> &[(f64, f64)] {
        self.outages
            .get(class)
            .and_then(|c| c.get(node))
            .map(|w| w.as_slice())
            .unwrap_or(&[])
    }

    /// Whether `node` of `class` is out of service at `now`. Pure and
    /// order-independent.
    pub fn node_down(&self, class: usize, node: usize, now: f64) -> bool {
        self.outages
            .get(class)
            .and_then(|c| c.get(node))
            .map(|ws| ws.iter().any(|&(a, b)| now >= a && now < b))
            .unwrap_or(false)
    }

    /// Earliest node fail/repair instant strictly after `now`, across
    /// the fleet. `None` once every pre-drawn window is in the past —
    /// and because every outage has a finite `back_s`, a down node
    /// always has a future repair event, so the engine can never
    /// deadlock waiting on capacity.
    pub fn next_node_event_after(&self, now: f64) -> Option<f64> {
        let mut best = f64::INFINITY;
        for class in &self.outages {
            for node in class {
                for &(a, b) in node {
                    if a > now + 1e-9 && a < best {
                        best = a;
                    }
                    if b > now + 1e-9 && b < best {
                        best = b;
                    }
                }
            }
        }
        best.is_finite().then_some(best)
    }

    /// Next crash instant of `job` strictly after `now` (virtual-time
    /// Poisson stream, re-derived per query).
    pub fn next_crash_after(&self, job: usize, now: f64) -> Option<f64> {
        self.crash_scan(job, |t| t > now + 1e-9)
    }

    /// Whether a crash instant of `job` lands at `now` (within the
    /// engine's event tolerance).
    pub fn crash_due(&self, job: usize, now: f64) -> bool {
        self.crash_scan(job, |t| (t - now).abs() < 1e-9).is_some()
    }

    fn crash_scan(&self, job: usize,
                  pred: impl Fn(f64) -> bool) -> Option<f64> {
        if !self.active || self.cfg.crash_per_hour <= 0.0 {
            return None;
        }
        let mut rng =
            Rng::new(self.cfg.seed ^ 0xC4A5_11E5).fork(job as u64);
        let rate = self.cfg.crash_per_hour / 3600.0;
        let mut t = 0.0f64;
        for _ in 0..MAX_CRASHES_PER_JOB {
            t += rng.exp(rate.max(1e-12));
            if t > FAULT_HORIZON_S {
                return None;
            }
            if pred(t) {
                return Some(t);
            }
        }
        None
    }
}

/// Draw one node's downtime windows: exponential inter-failure gaps at
/// the node's effective MTBF (flaky nodes fail `flaky_accel` times as
/// often), exponential outage lengths (transient repair vs permanent
/// replacement), and the exponential-backoff blacklist — a node failing
/// again within `blacklist_window_s` of its last repair stays
/// quarantined for `base * 2^k` extra seconds.
fn node_windows(cfg: &FaultConfig, class: usize, node: usize,
                quarantines: &mut usize) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    if cfg.mtbf_hours <= 0.0 {
        return out;
    }
    let mut rng = Rng::new(cfg.seed ^ 0xFA17_0BAD)
        .fork(((class as u64) << 20) | node as u64);
    let flaky = cfg.flaky_fraction > 0.0 && rng.bool(cfg.flaky_fraction);
    let accel = if flaky { cfg.flaky_accel.max(1.0) } else { 1.0 };
    let mtbf_s = cfg.mtbf_hours * 3600.0 / accel;
    let mut t = 0.0f64;
    let mut rapid = 0u32;
    let mut last_back = f64::NEG_INFINITY;
    while out.len() < MAX_OUTAGES_PER_NODE {
        t += rng.exp(1.0 / mtbf_s.max(1.0));
        if t > FAULT_HORIZON_S {
            break;
        }
        let transient = rng.bool(cfg.transient_fraction);
        let mean = if transient { cfg.repair_s } else { cfg.replace_s };
        let mut down = rng.exp(1.0 / mean.max(1.0)).max(MIN_OUTAGE_S);
        if cfg.blacklist_base_s > 0.0
            && t - last_back <= cfg.blacklist_window_s
        {
            rapid = (rapid + 1).min(MAX_BACKOFF_EXP);
            down += cfg.blacklist_base_s * (1u64 << rapid) as f64;
            *quarantines += 1;
        } else {
            rapid = 0;
        }
        last_back = t + down;
        out.push((t, t + down));
        t += down;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    fn model(mtbf_h: f64, seed: u64) -> FaultModel {
        FaultModel::new(FaultConfig::uniform(seed, mtbf_h),
                        &ClusterSpec::p4d(2))
    }

    #[test]
    fn none_is_inactive_and_eventless() {
        let m = FaultModel::new(FaultConfig::none(),
                                &ClusterSpec::p4d(2));
        assert!(!m.is_active());
        assert!(m.next_node_event_after(0.0).is_none());
        assert!(m.next_crash_after(0, 0.0).is_none());
        assert!(!m.node_down(0, 0, 1e6));
        assert!(!m.crash_due(0, 1e6));
        assert_eq!(m.quarantines(), 0);
    }

    #[test]
    fn windows_are_ascending_disjoint_and_finite() {
        let m = model(2.0, 7);
        let mut any = false;
        for ni in 0..2 {
            let ws = m.outages(0, ni);
            any |= !ws.is_empty();
            let mut prev_back = f64::NEG_INFINITY;
            for &(a, b) in ws {
                assert!(a > 0.0 && b > a, "degenerate window {a}..{b}");
                assert!(a >= prev_back, "windows overlap");
                assert!(b - a >= MIN_OUTAGE_S - 1e-9);
                prev_back = b;
            }
        }
        assert!(any, "2h MTBF drew no outages over the horizon");
    }

    #[test]
    fn queries_are_pure_and_order_independent() {
        let a = model(2.0, 11);
        let b = model(2.0, 11);
        // interrogate b in reverse order first: answers must not depend
        // on query history
        let probes = [0.0, 9e5, 3e4, 7.7e5, 123.0];
        for &t in probes.iter().rev() {
            let _ = b.node_down(0, 1, t);
            let _ = b.next_crash_after(3, t);
        }
        for &t in &probes {
            assert_eq!(a.node_down(0, 1, t), b.node_down(0, 1, t));
            assert_eq!(a.next_node_event_after(t),
                       b.next_node_event_after(t));
            assert_eq!(a.next_crash_after(3, t), b.next_crash_after(3, t));
        }
    }

    #[test]
    fn node_down_matches_the_windows_and_events_bound_transitions() {
        let m = model(1.0, 3);
        let ws = m.outages(0, 0).to_vec();
        assert!(!ws.is_empty());
        for &(a, b) in &ws {
            assert!(!m.node_down(0, 0, a - 1.0));
            assert!(m.node_down(0, 0, a + 1e-6));
            assert!(m.node_down(0, 0, (a + b) / 2.0));
            assert!(!m.node_down(0, 0, b + 1e-6));
            // while down, the next event is the repair (or earlier on
            // another node) — never past it
            let next = m.next_node_event_after((a + b) / 2.0).unwrap();
            assert!(next <= b + 1e-9);
        }
    }

    #[test]
    fn crash_stream_instants_answer_crash_due() {
        let cfg = FaultConfig {
            crash_per_hour: 2.0,
            ..FaultConfig::uniform(4, 0.0)
        };
        let m = FaultModel::new(cfg, &ClusterSpec::p4d(1));
        let t1 = m.next_crash_after(5, 0.0).expect("2/h crash stream");
        assert!(m.crash_due(5, t1));
        assert!(!m.crash_due(5, t1 + 1.0));
        let t2 = m.next_crash_after(5, t1).expect("second crash");
        assert!(t2 > t1 + 1e-9);
        // distinct jobs get distinct streams
        let other = m.next_crash_after(6, 0.0).expect("stream for job 6");
        assert!((other - t1).abs() > 1e-9);
    }

    #[test]
    fn flapping_quarantine_extends_rapid_refailures() {
        // force flapping everywhere with an enormous blacklist window:
        // every re-failure within the window must extend the outage by
        // at least the base quarantine
        let cfg = FaultConfig {
            seed: 9,
            mtbf_hours: 0.5,
            transient_fraction: 1.0,
            repair_s: 120.0,
            replace_s: 120.0,
            crash_per_hour: 0.0,
            flaky_fraction: 1.0,
            flaky_accel: 4.0,
            blacklist_base_s: 1800.0,
            blacklist_window_s: FAULT_HORIZON_S,
        };
        let m = FaultModel::new(cfg.clone(), &ClusterSpec::p4d(1));
        assert!(m.quarantines() > 0, "no quarantine ever triggered");
        // after the first failure, every window is quarantine-extended:
        // base * 2^1 on top of the drawn outage at minimum
        for ni in 0..1 {
            for (i, &(a, b)) in m.outages(0, ni).iter().enumerate() {
                if i > 0 {
                    assert!(b - a >= 2.0 * cfg.blacklist_base_s,
                            "window {i} not quarantined: {}s", b - a);
                }
            }
        }
        // without the blacklist the same seed yields strictly shorter
        // outages
        let plain = FaultModel::new(
            FaultConfig { blacklist_base_s: 0.0, ..cfg },
            &ClusterSpec::p4d(1));
        assert_eq!(plain.quarantines(), 0);
        let long: f64 = m.outages(0, 0).iter().map(|w| w.1 - w.0).sum();
        let short: f64 =
            plain.outages(0, 0).iter().map(|w| w.1 - w.0).sum();
        assert!(long > short, "quarantine did not lengthen downtime");
    }

    #[test]
    fn flaky_fleet_fails_more_often() {
        // all-flaky vs no-flaky at the same seed: acceleration must
        // produce at least as many outage windows fleet-wide
        let mk = |flaky: f64| {
            FaultModel::new(
                FaultConfig {
                    flaky_fraction: flaky,
                    flaky_accel: 8.0,
                    ..FaultConfig::uniform(13, 8.0)
                },
                &ClusterSpec::p4d(2),
            )
        };
        let count = |m: &FaultModel| -> usize {
            (0..2).map(|ni| m.outages(0, ni).len()).sum()
        };
        assert!(count(&mk(1.0)) > count(&mk(0.0)),
                "8x acceleration did not add outages");
    }
}
