//! Bench E16: pluggable scheduling objectives (DESIGN.md §4.5).
//!
//! Three sections, emitted to `BENCH_objective.json` (override with
//! `SATURN_BENCH_OUT`):
//!
//!  1. **Makespan-arm equivalence probe** — replays EXACTLY the
//!     `bench_online` scenario under `--objective makespan`, through
//!     both the historical path and the objective plumbing. CI asserts
//!     the recorded makespans match `BENCH_online.json`'s online-saturn
//!     row within 1e-6: the refactor is behavior-preserving by
//!     construction.
//!  2. **Objective sweep on a deadline-slack trace** — the same trace
//!     generator with a tight 2 h slack, run under makespan vs
//!     tardiness vs the wjct blend. The tardiness arm must show lower
//!     weighted tardiness and no more deadline misses than the
//!     makespan arm (CI asserts from the record).
//!  3. **256-job rolling-horizon tardiness solve** — the PR 2 scale
//!     bar with the richer objective: epigraph rows per deadlined job
//!     must keep the solve sub-second.
//!
//! Run: `cargo bench --bench bench_objective`

use saturn::bench::{fmt_s, print_header};
use saturn::cluster::ClusterSpec;
use saturn::objective::{JobTerms, Objective};
use saturn::online::{profile_trace, run_trace, run_trace_obj,
                     OnlineMetrics};
use saturn::parallelism::default_library;
use saturn::perf::PerfModel;
use saturn::saturn::solver::{solve_joint_obj, SolverMode};
use saturn::sim::engine::RungConfig;
use saturn::trials::profile_analytic;
use saturn::util::json::Json;
use saturn::workload::{generate_trace, toy_workload, ArrivalProcess,
                       TraceConfig};

// Tight enough that the makespan arm robustly accrues tardiness under
// the trace's queueing (realized JCTs run well past 2 h), so the CI
// comparison against the tardiness arm has signal.
const TIGHT_SLACK_S: f64 = 2.0 * 3600.0;

fn arm_json(tag: &str, m: &OnlineMetrics) -> Json {
    Json::obj(vec![
        ("objective", Json::str(tag)),
        ("makespan_s", Json::num(m.makespan_s)),
        ("avg_jct_s", Json::num(m.avg_jct_s)),
        ("weighted_jct_s", Json::num(m.weighted_jct_s)),
        ("total_tardiness_s", Json::num(m.total_tardiness_s)),
        ("weighted_tardiness_s", Json::num(m.weighted_tardiness_s)),
        ("deadline_misses", Json::num(m.deadline_misses as f64)),
        ("early_stopped", Json::num(m.early_stopped as f64)),
        ("solves", Json::num(m.solves.unwrap_or(0) as f64)),
    ])
}

fn main() {
    let fast = std::env::var("SATURN_BENCH_FAST").as_deref() == Ok("1");

    // ------------------------------------------------------------------
    // 1. makespan-arm equivalence: EXACTLY the bench_online scenario
    // ------------------------------------------------------------------
    let cfg = TraceConfig {
        seed: 42,
        multijobs: 6,
        process: ArrivalProcess::Poisson { rate_per_hour: 2.0 },
        grid_lrs: 2,
        grid_batches: 2,
        epochs: 1,
        tenants: 2,
        deadline_slack_s: Some(24.0 * 3600.0),
        burst_stagger_s: 0.0,
    };
    let trace = generate_trace(&cfg);
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();

    print_header("makespan-arm equivalence (bench_online scenario)");
    let (_, hist) = run_trace(&trace, Some(&rungs), &profiles, &cluster,
                              "online-saturn", SolverMode::Joint);
    let mut perf = PerfModel::exact(&profiles);
    let (_, via_obj) = run_trace_obj(&trace, Some(&rungs), &mut perf,
                                     &cluster, "online-saturn",
                                     SolverMode::Joint, None,
                                     Objective::Makespan);
    println!("historical path makespan: {:.6} h",
             hist.makespan_s / 3600.0);
    println!("objective path makespan:  {:.6} h",
             via_obj.makespan_s / 3600.0);
    assert_eq!(hist.makespan_s.to_bits(), via_obj.makespan_s.to_bits(),
               "objective plumbing changed the makespan path");

    // ------------------------------------------------------------------
    // 2. objective sweep on a deadline-slack trace (tight 2 h slack)
    // ------------------------------------------------------------------
    let tight_cfg = TraceConfig {
        deadline_slack_s: Some(TIGHT_SLACK_S),
        ..cfg.clone()
    };
    let tight = generate_trace(&tight_cfg);
    let tight_profiles = profile_trace(&tight, &cluster);
    print_header(&format!(
        "objective sweep, {} jobs / {} multi-jobs, {:.0} h deadline slack",
        tight.jobs.len(), tight.groups, TIGHT_SLACK_S / 3600.0));
    let objectives = [
        ("makespan", Objective::Makespan),
        ("tardiness",
         Objective::WeightedTardiness { deadline_weight: 1.0 }),
        ("wjct", Objective::WeightedJct { alpha: 0.5 }),
    ];
    let mut arms: Vec<(&str, OnlineMetrics)> = Vec::new();
    println!("{:<12} {:>12} {:>10} {:>10} {:>6} {:>8}", "objective",
             "makespan(h)", "wJCT(h)", "wTard(h)", "miss", "solves");
    for (tag, objective) in objectives {
        let mut perf = PerfModel::exact(&tight_profiles);
        let (_, m) = run_trace_obj(&tight, Some(&rungs), &mut perf,
                                   &cluster, "online-saturn",
                                   SolverMode::Joint, None, objective);
        println!("{:<12} {:>12.3} {:>10.3} {:>10.4} {:>6} {:>8}", tag,
                 m.makespan_s / 3600.0, m.weighted_jct_s / 3600.0,
                 m.weighted_tardiness_s / 3600.0, m.deadline_misses,
                 m.solves.unwrap_or(0));
        arms.push((tag, m));
    }
    let mk = &arms[0].1;
    let td = &arms[1].1;
    println!("\ntardiness vs makespan arm: weighted tardiness \
              {:.4} h -> {:.4} h, misses {} -> {}",
             mk.weighted_tardiness_s / 3600.0,
             td.weighted_tardiness_s / 3600.0, mk.deadline_misses,
             td.deadline_misses);

    // ------------------------------------------------------------------
    // 3. 256-job rolling-horizon tardiness solve (PR 2 scale bar)
    // ------------------------------------------------------------------
    print_header("256-job rolling-horizon solve, tardiness objective");
    let jobs256 = toy_workload(256);
    let big = ClusterSpec::p4d(8);
    let lib = default_library();
    let profiles256 = profile_analytic(&jobs256, &lib, &big);
    let rem: Vec<(usize, u64)> =
        jobs256.iter().map(|j| (j.id, j.total_steps())).collect();
    // heterogeneous deadlines/weights so every epigraph row activates
    let terms: Vec<JobTerms> = rem
        .iter()
        .map(|&(id, _)| JobTerms {
            weight: 1.0 + (id % 3) as f64,
            due_in_s: Some(1800.0 * (1 + id % 16) as f64),
            job_id: id,
        })
        .collect();
    // min-filtered over reps EVEN in fast mode: CI asserts the recorded
    // wall time, and a single sample on a shared runner is too noisy
    let reps = if fast { 3 } else { 5 };
    let mut wall = f64::INFINITY;
    let mut windows = 0usize;
    let mut planned = 0usize;
    for _ in 0..reps {
        let (plan, stats) = solve_joint_obj(
            &rem, &profiles256, &big, SolverMode::rolling_default(), 1.0,
            None, Objective::WeightedTardiness { deadline_weight: 1.0 },
            &terms);
        wall = wall.min(stats.wall_s);
        windows = stats.windows;
        planned = plan.choices.len();
    }
    assert_eq!(planned, 256, "rolling tardiness solve lost jobs");
    println!("{:<44} {:>10}  [{} windows]{}",
             "rolling/jobs=256 (tardiness)", fmt_s(wall), windows,
             if wall < 1.0 { "" } else { "  ** >1s **" });

    // machine-readable perf record
    let out = std::env::var("SATURN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_objective.json".to_string());
    let record = Json::obj(vec![
        ("bench", Json::str("objective")),
        ("seed", Json::num(cfg.seed as f64)),
        ("jobs", Json::num(trace.jobs.len() as f64)),
        ("gpus", Json::num(cluster.total_gpus() as f64)),
        ("deadline_slack_s", Json::num(TIGHT_SLACK_S)),
        ("makespan_probe", Json::obj(vec![
            ("makespan_s", Json::num(hist.makespan_s)),
            ("obj_path_makespan_s", Json::num(via_obj.makespan_s)),
        ])),
        ("arms",
         Json::arr(arms.iter().map(|(tag, m)| arm_json(tag, m)))),
        ("rolling_256", Json::obj(vec![
            ("jobs", Json::num(256.0)),
            ("wall_s", Json::num(wall)),
            ("windows", Json::num(windows as f64)),
            ("sub_second", Json::Bool(wall < 1.0)),
        ])),
    ]);
    std::fs::write(&out, record.to_string()).expect("writing perf record");
    println!("\nwrote {out}");
}
